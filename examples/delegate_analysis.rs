//! Delegate analysis (paper Sec. 3.1 + Figs. 7/8): run the delegate
//! simulator and the pass pipeline over every emitted graph — ours at
//! runtime scale and Stable Diffusion v2.1 at full scale — and report
//! coverage, failures, rewrites, and the modeled latency effect.
//!
//!     cargo run --release --example delegate_analysis

use std::path::Path;

use mobile_diffusion::delegate::{graph_cost, RuleSet, CPU_BIGCORE, GPU_ADRENO740};
use mobile_diffusion::graph::{self, OpType};
use mobile_diffusion::passes;
use mobile_diffusion::planner::{model, plan_graph, registered_devices, schedule_display};

fn main() -> mobile_diffusion::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rules = RuleSet::default();

    for name in [
        "sd_v21_unet",
        "sd_v21_text_encoder",
        "sd_v21_decoder",
        "small_unet",
        "small_text_encoder",
        "small_decoder",
    ] {
        let mut g = graph::load(&dir.join(format!("{name}.graph.json")))?;
        println!("=== {name} ===");
        println!(
            "  {} ops, {} tensors, {:.1} MB weights (f32)",
            g.ops.len(),
            g.tensors.len(),
            g.weight_bytes() as f64 / 1e6
        );

        let before = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE);
        let failures = rules.failures(&g);
        let mut reasons = std::collections::BTreeMap::new();
        for (_, v) in &failures {
            *reasons.entry(format!("{v:?}").split('(').next().unwrap().
                  split('{').next().unwrap().trim().to_string()).or_insert(0) += 1;
        }
        println!(
            "  export form: coverage {:.1}%, {} failing ops {:?}",
            rules.coverage(&g) * 100.0,
            failures.len(),
            reasons
        );

        let report = passes::run_all(&mut g);
        for (pass, n) in &report.applied {
            if *n > 0 {
                println!("    {pass}: {n} site(s)");
            }
        }
        let after = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE);

        // Fig. 7 invariants: no BroadcastTo, nothing above rank 4
        assert_eq!(g.op_histogram().get(&OpType::BroadcastTo), None);
        assert!(g.max_rank() <= 4);
        // Fig. 8 invariant: every GELU now clamps
        let minimums = g.op_histogram().get(&OpType::Minimum).copied().unwrap_or(0);
        println!(
            "  after passes: coverage {:.1}%, {} gamma_M clamps, \
             modeled latency {:.1} ms -> {:.1} ms ({:.2}x)",
            rules.coverage(&g) * 100.0,
            minimums,
            before.total() * 1e3,
            after.total() * 1e3,
            before.total() / after.total()
        );
        println!();
    }

    // Which passes the cost-gated planner actually schedules, per
    // device class and variant: the GPU-delegate class takes the whole
    // pipeline (fusions included), comparator classes keep only what
    // pays on their cost model.
    println!("=== planner pass schedules (cost-gated, per device class) ===");
    for spec in registered_devices() {
        for variant in model::VARIANTS {
            let g = model::unet_graph(variant)?;
            let planned = plan_graph(&g, &rules, &spec);
            println!(
                "  {:<10} {:<7} {:>3} rewrites, {:>6.1} ms modeled   [{}]",
                spec.name,
                variant,
                planned.rewrites,
                planned.cost_s * 1e3,
                schedule_display(&planned.passes_used)
            );
        }
    }
    Ok(())
}
