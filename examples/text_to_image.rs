//! Full text-to-image run (paper Fig. 6): the complete 20-step distilled
//! schedule, both graph variants, with stage-by-stage timings and a
//! numeric variant comparison.
//!
//!     cargo run --release --example text_to_image -- "your prompt here"

use std::path::Path;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::util::image;
use mobile_diffusion::util::stats;

fn main() -> mobile_diffusion::Result<()> {
    let prompt = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "a watercolor painting of a fox in a forest".into());
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir)?;

    let mut results = Vec::new();
    for variant in ["mobile", "base"] {
        let mut ex = PipelinedExecutor::new(
            manifest.clone(),
            ExecOptions { num_steps: 20, ..Default::default() },
        )?;
        println!("== variant: {variant} ==");
        let r = ex.generate(&prompt, 1234, variant)?;
        let t = &r.timings;
        println!("  total        {:>7.2} s", t.total_s);
        println!("  text         {:>7.2} s (load {:.2} + encode {:.2})",
                 t.text_load_s + t.text_encode_s, t.text_load_s, t.text_encode_s);
        println!("  denoise      {:>7.2} s ({} steps, {:.0} ms/step)",
                 t.denoise_s, t.denoise_steps,
                 t.denoise_s / t.denoise_steps as f64 * 1e3);
        println!("  decode       {:>7.2} s (load {:.2} + run {:.2})",
                 t.decoder_load_s + t.decode_s, t.decoder_load_s, t.decode_s);
        println!("  peak memory  {:>7.1} MB", r.peak_memory as f64 / 1e6);

        let out = format!("text_to_image_{variant}.png");
        image::write_png(
            Path::new(&out),
            r.image_size,
            r.image_size,
            &image::float_to_rgb8(&r.image),
        )?;
        println!("  wrote {out}\n");
        results.push(r);
    }

    // Fig.-2-style check: the two variants must agree closely
    let (mobile, base) = (&results[0], &results[1]);
    let peak = base.image.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
    println!(
        "variant agreement: image PSNR {:.1} dB, latent max-abs {:.2e}",
        stats::psnr(&base.image, &mobile.image, peak),
        stats::max_abs_diff(&base.latent, &mobile.latent)
    );
    Ok(())
}
