//! Serving demo on a heterogeneous fleet: a GPU-delegate phone and a
//! CPU-only phone behind one queue.  The planner prices every
//! `(device class, variant)` combination and admission routes each
//! request to the cheapest class that can meet its deadline — tight
//! deadlines land on the Adreno, lax ones on the CPU, impossible ones
//! are rejected before they ever queue.
//!
//!     cargo run --release --example serve

use std::time::Duration;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{Priority, Server, SubmitOptions};

/// (prompt, priority, step override, deadline)
const PROMPTS: &[(&str, Priority, Option<usize>, Option<Duration>)] = &[
    // no deadline: the planner parks these on the cheap CPU class
    ("a photograph of an astronaut riding a horse", Priority::Normal, None, None),
    ("an oil painting of a lighthouse in a storm", Priority::Low, None, None),
    // tight deadlines: only the GPU class's plan fits
    ("a cyberpunk city at night, neon lights", Priority::High, Some(2),
     Some(Duration::from_millis(400))),
    ("a golden retriever puppy in the snow", Priority::High, None,
     Some(Duration::from_millis(400))),
    // lax deadline: the CPU class is feasible and therefore cheapest
    ("a bowl of ramen, studio lighting", Priority::Normal, Some(8),
     Some(Duration::from_secs(600))),
    // impossible deadline: rejected at admission by the planner
    ("the skyline of Seoul at sunset", Priority::Low, Some(2),
     Some(Duration::from_micros(5))),
];

fn main() -> mobile_diffusion::Result<()> {
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.num_steps = 4; // demo default schedule; 20 for the paper's
    cfg.fleet = Some("adreno740:1,bigcore:1".into()); // two-class fleet
    cfg.queue_depth = 16;
    cfg.max_batch = 2; // compatible requests share denoise dispatches

    let mut server = Server::start(&cfg)?;
    println!(
        "serving {} prompts on a planned fleet ({} workers: {}; {} default steps)\n",
        PROMPTS.len(),
        server.num_workers(),
        cfg.fleet.as_deref().unwrap_or("-"),
        cfg.num_steps,
    );

    // submit the whole burst up front: the planner routes per deadline,
    // the queue drains high before normal before low within each class
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for (i, (prompt, priority, steps, deadline)) in PROMPTS.iter().enumerate() {
        let opts = SubmitOptions {
            priority: *priority,
            num_steps: *steps,
            deadline: *deadline,
            ..Default::default()
        };
        match server.submit_with(prompt, i as u64 + 1, opts) {
            Ok(rx) => pending.push((*prompt, *priority, rx)),
            Err(e) => println!("rejected [{:<6}] {e}\n         {prompt}", priority.as_str()),
        }
    }

    for (prompt, priority, rx) in pending {
        match rx.recv() {
            Ok(Ok(resp)) => println!(
                "#{:<2} [{:<6}] {:<9} worker {}  {:>6.2} s (plan {:>6.2} s, {} steps, queue {:>5.3} s)  {prompt}",
                resp.id,
                priority.as_str(),
                resp.device_class,
                resp.worker_id,
                resp.timings.total_s,
                resp.predicted_s.unwrap_or(0.0),
                resp.timings.denoise_steps,
                resp.queue_s,
            ),
            Ok(Err(e)) => println!("failed  [{:<6}] {e}  {prompt}", priority.as_str()),
            Err(_) => println!("dropped [{:<6}] {prompt}", priority.as_str()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nthroughput: {:.2} images/min over {:.1} s",
        PROMPTS.len() as f64 / wall * 60.0,
        wall
    );
    println!("{}", server.metrics_report()?);
    Ok(())
}
