//! Serving demo: push a batch of prompts through the coordinator (FIFO
//! queue in front of the single-device pipelined executor, UNet resident
//! across requests — the paper's app behaviour) and report the metrics.
//!
//!     cargo run --release --example serve

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;

const PROMPTS: &[&str] = &[
    "a photograph of an astronaut riding a horse",
    "a cyberpunk city at night, neon lights",
    "an oil painting of a lighthouse in a storm",
    "a bowl of ramen, studio lighting",
    "a golden retriever puppy in the snow",
    "the skyline of Seoul at sunset",
];

fn main() -> mobile_diffusion::Result<()> {
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.num_steps = 4; // demo schedule; 20 for the paper's

    let mut server = Server::start(&cfg)?;
    println!("serving {} prompts, {} steps each...\n", PROMPTS.len(), cfg.num_steps);

    let t0 = std::time::Instant::now();
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let resp = server.generate(prompt, i as u64 + 1)?;
        println!(
            "#{:<2} {:>6.2} s (queue {:>5.3} s, peak {:>5.1} MB)  {prompt}",
            resp.id,
            resp.timings.total_s,
            resp.queue_s,
            resp.peak_memory as f64 / 1e6
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nthroughput: {:.2} images/min over {:.1} s",
        PROMPTS.len() as f64 / wall * 60.0,
        wall
    );
    println!("{}", server.metrics_report()?);
    Ok(())
}
