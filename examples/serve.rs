//! Serving demo: push a burst of mixed-priority prompts through the
//! worker pool (admission queue -> N device workers, each with its own
//! engine and residency cache) and print the fleet metrics report.
//!
//!     cargo run --release --example serve

use std::time::Duration;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{Priority, Server, SubmitOptions};

/// (prompt, priority, per-request step override)
const PROMPTS: &[(&str, Priority, Option<usize>)] = &[
    ("a photograph of an astronaut riding a horse", Priority::Normal, None),
    ("a cyberpunk city at night, neon lights", Priority::High, Some(2)),
    ("an oil painting of a lighthouse in a storm", Priority::Low, None),
    ("a bowl of ramen, studio lighting", Priority::Normal, Some(8)),
    ("a golden retriever puppy in the snow", Priority::High, None),
    ("the skyline of Seoul at sunset", Priority::Low, Some(2)),
];

fn main() -> mobile_diffusion::Result<()> {
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.num_steps = 4; // demo default schedule; 20 for the paper's
    cfg.num_workers = 2; // a two-phone fleet
    cfg.queue_depth = 16;
    cfg.max_batch = 2; // compatible requests share denoise dispatches

    let mut server = Server::start(&cfg)?;
    println!(
        "serving {} prompts on {} workers ({} default steps, micro-batch up to {})...\n",
        PROMPTS.len(),
        server.num_workers(),
        cfg.num_steps,
        cfg.max_batch
    );

    // submit the whole burst up front: the queue drains high before
    // normal before low, FIFO within each class
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for (i, (prompt, priority, steps)) in PROMPTS.iter().enumerate() {
        let opts = SubmitOptions {
            priority: *priority,
            num_steps: *steps,
            deadline: Some(Duration::from_secs(600)),
            ..Default::default()
        };
        match server.submit_with(prompt, i as u64 + 1, opts) {
            Ok(rx) => pending.push((*prompt, *priority, rx)),
            Err(e) => println!("rejected ({priority:?}): {e}  {prompt}"),
        }
    }

    for (prompt, priority, rx) in pending {
        match rx.recv() {
            Ok(Ok(resp)) => println!(
                "#{:<2} [{:<6}] worker {}  {:>6.2} s ({} steps, queue {:>5.3} s, peak {:>5.1} MB)  {prompt}",
                resp.id,
                priority.as_str(),
                resp.worker_id,
                resp.timings.total_s,
                resp.timings.denoise_steps,
                resp.queue_s,
                resp.peak_memory as f64 / 1e6
            ),
            Ok(Err(e)) => println!("failed  [{:<6}] {e}  {prompt}", priority.as_str()),
            Err(_) => println!("dropped [{:<6}] {prompt}", priority.as_str()),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nthroughput: {:.2} images/min over {:.1} s",
        PROMPTS.len() as f64 / wall * 60.0,
        wall
    );
    println!("{}", server.metrics_report()?);
    Ok(())
}
