use std::collections::BTreeMap;
use std::path::Path;
use mobile_diffusion::delegate::*;
use mobile_diffusion::graph;
use mobile_diffusion::passes;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut g = graph::load(&dir.join("sd_v21_unet.graph.json")).unwrap();
    passes::run_all(&mut g);
    let mut by_type: BTreeMap<&str, (f64, f64, usize)> = BTreeMap::new();
    let mut total_flops = 0.0;
    for op in &g.ops {
        let t = op_latency(&g, op, &GPU_ADRENO740);
        let f = mobile_diffusion::delegate::cost::op_flops(&g, op);
        total_flops += f;
        let e = by_type.entry(op.ty.name()).or_default();
        e.0 += t; e.1 += f; e.2 += 1;
    }
    let fused = single_device_cost(&g, &GPU_ADRENO740);
    println!("total flops {:.1} G, unfused {:.1} ms, fused {:.1} ms",
        total_flops/1e9,
        by_type.values().map(|v| v.0).sum::<f64>()*1e3, fused*1e3);
    for (ty, (t, f, n)) in by_type {
        println!("{:<26} {:>5}  {:>8.1} ms  {:>8.1} GF", ty, n, t*1e3, f/1e9);
    }
}
