//! Memory-constrained deployment (paper Sec. 3.3): run the same request
//! under a device budget that only the pipelined executor can satisfy,
//! and print the Fig.-4 occupancy trace.
//!
//!     cargo run --release --example memory_constrained

use std::path::Path;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::Manifest;

fn main() -> mobile_diffusion::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let m = Manifest::load(&dir)?;

    let unet = m.component("unet_mobile")?.weights["fp32"].bytes;
    let text = m.component("text_encoder")?.weights["fp32"].bytes;
    let dec = m.component("decoder")?.weights["fp32"].bytes;
    // a budget between (unet + max) and (unet + text + dec): the paper's
    // situation — all three do not fit at once
    let budget = unet + text.max(dec) + 1_000_000;
    println!(
        "components: unet {:.1} MB, text {:.1} MB, decoder {:.1} MB; budget {:.1} MB\n",
        unet as f64 / 1e6,
        text as f64 / 1e6,
        dec as f64 / 1e6,
        budget as f64 / 1e6
    );

    // naive executor: must hit the budget wall
    let mut naive = PipelinedExecutor::new(
        m.clone(),
        ExecOptions {
            num_steps: 6,
            pipelined: false,
            memory_budget: budget,
            ..Default::default()
        },
    )?;
    match naive.generate("memory constrained demo", 9, "mobile") {
        Err(e) => println!("naive executor, as expected, fails: {e}\n"),
        Ok(_) => println!("naive executor unexpectedly fit — budget not binding!\n"),
    }

    // pipelined executor: fits
    let mut pipe = PipelinedExecutor::new(
        m,
        ExecOptions {
            num_steps: 6,
            pipelined: true,
            memory_budget: budget,
            ..Default::default()
        },
    )?;
    let r = pipe.generate("memory constrained demo", 9, "mobile")?;
    println!(
        "pipelined executor succeeds: {:.2} s, peak {:.1} MB (budget {:.1} MB)\n",
        r.timings.total_s,
        r.peak_memory as f64 / 1e6,
        budget as f64 / 1e6
    );
    println!("memory occupancy trace (paper Fig. 4):\n");
    println!("{}", pipe.memory_trace().render_ascii(48));

    // under this budget every request evicts the encoder and decoder —
    // but the second request reloads them *warm*: host half from the
    // artifact store, executable from the warm tier, upload only
    let r2 = pipe.generate("memory constrained demo", 9, "mobile")?;
    let p = pipe.load_profile();
    println!(
        "\nsecond request under the same budget: {:.2} s \
         ({} cold loads, {} warm reloads so far; {} disk loads, {} store hits)",
        r2.timings.total_s,
        p.cold_loads,
        p.warm_reloads,
        pipe.store().disk_loads(),
        pipe.store().hits(),
    );

    // int8 weights shrink the whole footprint further (Sec. 3.4)
    let mut int8 = PipelinedExecutor::new(
        Manifest::load(&Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))?,
        ExecOptions {
            num_steps: 6,
            pipelined: true,
            memory_budget: budget,
            unet_weights: "int8".into(),
            ..Default::default()
        },
    )?;
    let r8 = int8.generate("memory constrained demo", 9, "mobile")?;
    println!(
        "with int8 UNet weights: peak {:.1} MB (saves another {:.1} MB)",
        r8.peak_memory as f64 / 1e6,
        (r.peak_memory - r8.peak_memory) as f64 / 1e6
    );
    Ok(())
}
