//! Quickstart: generate one image through the serving API.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Loads the AOT artifacts, starts the single-device server (the
//! paper's pipelined executor behind a FIFO queue), generates one
//! 256x256 image with a short distilled schedule, and writes a PNG.

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::util::image;

fn main() -> mobile_diffusion::Result<()> {
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.num_steps = 4; // quick demo; use 20 for the paper's schedule
    cfg.prompt = "a photograph of an astronaut riding a horse".into();

    let mut server = Server::start(&cfg)?;
    println!("generating \"{}\" ({} steps)...", cfg.prompt, cfg.num_steps);
    let resp = server.generate(&cfg.prompt, 42)?;

    println!(
        "done in {:.2} s (denoise {:.2} s, decode {:.2} s), peak memory {:.1} MB",
        resp.timings.total_s,
        resp.timings.denoise_s,
        resp.timings.decode_s,
        resp.peak_memory as f64 / 1e6
    );
    let out = std::path::PathBuf::from("quickstart.png");
    image::write_png(
        &out,
        resp.image_size,
        resp.image_size,
        &image::float_to_rgb8(&resp.image),
    )?;
    println!("wrote {}", out.display());
    Ok(())
}
