"""Model-module contracts: shapes, variant equivalence, determinism."""

import numpy as np
import pytest

from compile import model
from compile.config import DEFAULT
from compile.params import Init, flatten, unflatten
from compile.modules import resnet, transformer2d, text_encoder, vae, layers

import jax.numpy as jnp


def rand(shape, seed=0, scale=1.0):
    return (scale * np.random.default_rng(seed).normal(size=shape)).astype(
        np.float32)


class TestFlatten:
    def test_round_trip(self):
        p = {"b": {"x": np.ones(3), "a": np.zeros(2)}, "a": np.full(1, 5.0)}
        flat = flatten(p)
        assert [k for k, _ in flat] == ["a", "b/a", "b/x"]
        p2 = unflatten([k for k, _ in flat], [v for _, v in flat])
        np.testing.assert_array_equal(p2["b"]["x"], p["b"]["x"])

    def test_sorted_deterministic(self):
        p1 = {"z": np.ones(1), "a": np.ones(2), "m": {"q": np.ones(3)}}
        assert [k for k, _ in flatten(p1)] == ["a", "m/q", "z"]


class TestTextEncoder:
    def test_output_shape(self):
        out = model.run_component(
            "text_encoder", [np.ones((1, 16), np.int32)])
        assert out.shape == (1, 16, 128)

    def test_deterministic(self):
        toks = np.arange(16, dtype=np.int32).reshape(1, 16) % 100
        a = model.run_component("text_encoder", [toks])
        b = model.run_component("text_encoder", [toks])
        np.testing.assert_array_equal(a, b)

    def test_token_sensitivity(self):
        a = model.run_component(
            "text_encoder", [np.full((1, 16), 5, np.int32)])
        b = model.run_component(
            "text_encoder", [np.full((1, 16), 6, np.int32)])
        assert np.abs(a - b).max() > 1e-3


class TestResBlock:
    def test_shape_and_skip(self):
        rng = Init(0)
        p = resnet.init(rng, 32, 64, 256)
        x = jnp.asarray(rand((2, 8, 8, 32), 1))
        t = jnp.asarray(rand((2, 256), 2))
        out = resnet.apply(p, x, t, 8, "base")
        assert out.shape == (2, 8, 8, 64)
        assert "skip" in p  # channel change requires projection

    def test_no_skip_when_channels_match(self):
        p = resnet.init(Init(0), 64, 64, 256)
        assert "skip" not in p

    def test_bottleneck_variant_matches_base(self):
        """Serialized conv1 (mobile) == plain conv1 (base) numerically."""
        p = resnet.init(Init(3), 192, 64, 256)
        x = jnp.asarray(rand((1, 32, 32, 192), 4))
        t = jnp.asarray(rand((1, 256), 5))
        a = resnet.apply(p, x, t, 8, "base", bottleneck=True)
        b = resnet.apply(p, x, t, 8, "mobile", bottleneck=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


class TestTransformerBlock:
    def test_shape_preserved(self):
        c = 128
        p = transformer2d.init(Init(1), c, 4, 128, 4)
        x = jnp.asarray(rand((1, 16, 16, c), 6))
        ctx = jnp.asarray(rand((1, 16, 128), 7))
        out = transformer2d.apply(p, x, ctx, 8, 4, "base")
        assert out.shape == (1, 16, 16, c)

    def test_context_sensitivity(self):
        """Cross-attention must read the context."""
        c = 128
        p = transformer2d.init(Init(1), c, 4, 128, 4)
        x = jnp.asarray(rand((1, 16, 16, c), 6))
        a = transformer2d.apply(p, x, jnp.asarray(rand((1, 16, 128), 7)),
                                8, 4, "base")
        b = transformer2d.apply(p, x, jnp.asarray(rand((1, 16, 128), 8)),
                                8, 4, "base")
        assert np.abs(np.asarray(a) - np.asarray(b)).max() > 1e-4


class TestUNet:
    def test_output_shape(self):
        lat = rand((2, 32, 32, 4), 1)
        ctx = rand((2, 16, 128), 2)
        out = model.run_component(
            "unet", [lat, np.array([500.0], np.float32), ctx],
            variant="base")
        assert out.shape == (2, 32, 32, 4)

    def test_timestep_sensitivity(self):
        lat = rand((2, 32, 32, 4), 1)
        ctx = rand((2, 16, 128), 2)
        a = model.run_component(
            "unet", [lat, np.array([10.0], np.float32), ctx], variant="base")
        b = model.run_component(
            "unet", [lat, np.array([900.0], np.float32), ctx], variant="base")
        assert np.abs(a - b).max() > 1e-3

    def test_base_vs_mobile_subtle(self):
        """Paper Fig. 2: the mobile rewrites change outputs only subtly.
        We bound the relative deviation of the predicted noise."""
        lat = rand((2, 32, 32, 4), 3)
        ctx = rand((2, 16, 128), 4)
        t = np.array([500.0], np.float32)
        a = model.run_component("unet", [lat, t, ctx], variant="base")
        b = model.run_component("unet", [lat, t, ctx], variant="mobile")
        denom = np.abs(a).mean()
        rel = np.abs(a - b).max() / denom
        assert rel < 1e-3, f"variant deviation too large: {rel}"


class TestDecoder:
    def test_output_shape_and_range(self):
        img = model.run_component("decoder", [rand((1, 32, 32, 4), 9)])
        assert img.shape == (1, 256, 256, 3)
        assert np.isfinite(img).all()


class TestVaeInternals:
    def test_res_apply_shape(self):
        p = vae._res_init(Init(2), 16, 32)
        x = jnp.asarray(rand((1, 8, 8, 16), 10))
        out = vae._res_apply(p, x, 8, "base")
        assert out.shape == (1, 8, 8, 32)

    def test_upsample_nearest(self):
        x = jnp.asarray(np.arange(4, dtype=np.float32).reshape(1, 2, 2, 1))
        up = np.asarray(layers.upsample_nearest_2x(x))
        assert up.shape == (1, 4, 4, 1)
        np.testing.assert_array_equal(
            up[0, :, :, 0],
            [[0, 0, 1, 1], [0, 0, 1, 1], [2, 2, 3, 3], [2, 2, 3, 3]])


class TestBlockW8:
    def test_w8_block_close_to_fp(self):
        """Quantizing the FFN weights perturbs the block output only
        slightly (the paper's Fig. 5 'differences in details')."""
        x = rand((1, 16, 16, 128), 11)
        ctx = rand((1, 16, 128), 12)
        fp = model.run_component("block", [x, ctx], variant="mobile")
        w8 = model.run_component("block_w8", [x, ctx], variant="mobile")
        rel = np.abs(fp - w8).mean() / (np.abs(fp).mean() + 1e-9)
        assert rel < 0.05, rel

    def test_pruned_block_differs_more(self):
        from compile.quantize import reconstruction_error
        x = rand((1, 16, 16, 128), 11)
        ctx = rand((1, 16, 128), 12)
        fp = model.run_component("block", [x, ctx], variant="mobile")
        w8 = model.run_component("block_w8", [x, ctx], variant="mobile")
        fn, paths, arrays, _ = model.build_block_w8(DEFAULT, "mobile", 0.125)
        import jax.numpy as jnp
        w8p = np.asarray(fn([jnp.asarray(a) for a in arrays],
                            jnp.asarray(x), jnp.asarray(ctx)))
        e_q = reconstruction_error(fp, w8)
        e_qp = reconstruction_error(fp, w8p)
        assert e_qp >= e_q    # pruning adds error on top of quantization
