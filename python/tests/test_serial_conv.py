"""Serialized Conv2D (paper Sec. 3.1 / Fig. 1b)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.serial_conv import conv3x3_input_serialized_kernel


def rand(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestInputSerialization:
    @pytest.mark.parametrize("factor", [1, 2, 3, 4, 6, 12])
    def test_ref_serialized_matches_plain(self, factor):
        """Input serialization is a pure reordering of the summation:
        must match the unserialized conv for any factor."""
        x, w, b = rand((1, 8, 8, 12), 1), rand((3, 3, 12, 8), 2), rand((8,), 3)
        np.testing.assert_allclose(
            ref.conv2d_3x3_input_serialized(x, w, b, factor=factor),
            ref.conv2d_3x3(x, w, b), rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("factor", [1, 2, 4, 8])
    def test_ref_output_serialized_matches_plain(self, factor):
        x, w, b = rand((1, 8, 8, 12), 4), rand((3, 3, 12, 16), 5), rand((16,), 6)
        np.testing.assert_allclose(
            ref.conv2d_3x3_output_serialized(x, w, b, factor=factor),
            ref.conv2d_3x3(x, w, b), rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("factor", [1, 2, 3])
    def test_kernel_matches_ref(self, factor):
        x, w, b = rand((1, 8, 8, 12), 7), rand((3, 3, 12, 8), 8), rand((8,), 9)
        np.testing.assert_allclose(
            conv3x3_input_serialized_kernel(x, w, b, factor=factor),
            ref.conv2d_3x3(x, w, b), rtol=1e-4, atol=1e-4)

    def test_kernel_paper_ratio_shape(self):
        """Our bottleneck analog: 192 -> 64 at 32x32, factor 2 — the
        shape the mobile UNet actually runs."""
        x, w = rand((1, 32, 32, 192), 10), rand((3, 3, 192, 64), 11)
        np.testing.assert_allclose(
            conv3x3_input_serialized_kernel(x, w, factor=2),
            ref.conv2d_3x3(x, w), rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        hw=st.sampled_from([4, 8, 16]),
        cin_g=st.sampled_from([2, 4, 8]),
        factor=st.sampled_from([1, 2, 4]),
        cout=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, hw, cin_g, factor, cout, seed):
        cin = cin_g * factor
        x = rand((1, hw, hw, cin), seed)
        w = rand((3, 3, cin, cout), seed + 1)
        np.testing.assert_allclose(
            conv3x3_input_serialized_kernel(x, w, factor=factor),
            ref.conv2d_3x3(x, w), rtol=2e-4, atol=2e-4)


class TestFcToConv:
    """Paper Fig. 1a: FullyConnected == Reshape-Conv2D-Reshape."""

    @pytest.mark.parametrize("s,k,n", [(16, 8, 4), (256, 128, 512),
                                       (64, 320, 320)])
    def test_fc_equals_conv(self, s, k, n):
        x, w, b = rand((s, k), 1), rand((k, n), 2), rand((n,), 3)
        np.testing.assert_allclose(
            ref.fc_as_conv2d(x, w, b), x @ w + b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(s=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_fc_conv(self, s, k, n, seed):
        x, w = rand((s, k), seed), rand((k, n), seed + 1)
        np.testing.assert_allclose(
            ref.fc_as_conv2d(x, w), x @ w, rtol=2e-4, atol=2e-4)
