"""Hash tokenizer: determinism and framing (shared contract with Rust)."""

from compile import tokenizer


class TestWords:
    def test_splits_on_non_alnum(self):
        assert tokenizer.words("Hello, world!") == ["hello", "world"]

    def test_empty(self):
        assert tokenizer.words("") == []
        assert tokenizer.words("!!! ---") == []

    def test_numbers_kept(self):
        assert tokenizer.words("galaxy s23 ultra") == ["galaxy", "s23", "ultra"]


class TestEncode:
    def test_framing(self):
        ids = tokenizer.encode("a b", 4096, 16)
        assert len(ids) == 16
        assert ids[0] == tokenizer.BOS_ID
        assert ids[3:] == [tokenizer.PAD_ID] * 13

    def test_truncation(self):
        text = " ".join(f"w{i}" for i in range(100))
        ids = tokenizer.encode(text, 4096, 16)
        assert len(ids) == 16
        assert tokenizer.PAD_ID not in ids[1:]

    def test_ids_in_range(self):
        ids = tokenizer.encode("the quick brown fox", 4096, 16)
        for t in ids:
            assert 0 <= t < 4096

    def test_deterministic(self):
        a = tokenizer.encode("stable diffusion", 4096, 16)
        b = tokenizer.encode("stable diffusion", 4096, 16)
        assert a == b

    def test_case_insensitive(self):
        assert tokenizer.encode("HELLO", 4096, 16) == \
            tokenizer.encode("hello", 4096, 16)

    def test_fnv_golden(self):
        """FNV-1a 64 known-answer (cross-checked with the Rust impl)."""
        assert tokenizer.fnv1a64(b"") == 0xCBF29CE484222325
        assert tokenizer.fnv1a64(b"a") == 0xAF63DC4C8601EC8C
