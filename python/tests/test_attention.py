"""Fused attention kernel vs reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention_kernel


def rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (scale * np.random.default_rng(seed).normal(size=shape)).astype(np.float32))


class TestKernelVsRef:
    @pytest.mark.parametrize("h,sq,skv,d", [
        (4, 64, 64, 32),     # self-attention at 8x8
        (4, 256, 16, 32),    # cross-attention at 16x16 over 16 tokens
        (1, 16, 16, 128),    # text-encoder head
        (8, 1024, 77, 64),   # SD-scale cross-attention slice
    ])
    def test_matches_ref(self, h, sq, skv, d):
        q, k, v = rand((h, sq, d), 1), rand((h, skv, d), 2), rand((h, skv, d), 3)
        np.testing.assert_allclose(
            attention_kernel(q, k, v), ref.attention(q, k, v),
            rtol=1e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4]),
        sq=st.sampled_from([1, 4, 16, 64]),
        skv=st.sampled_from([1, 4, 16, 64]),
        d=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(0.1, 10.0),
    )
    def test_hypothesis_sweep(self, h, sq, skv, d, seed, scale):
        q = rand((h, sq, d), seed, scale)
        k = rand((h, skv, d), seed + 1, scale)
        v = rand((h, skv, d), seed + 2)
        np.testing.assert_allclose(
            attention_kernel(q, k, v), ref.attention(q, k, v),
            rtol=2e-4, atol=2e-4)


class TestAttentionProperties:
    def test_softmax_rows_sum_to_one_effect(self):
        """With identical values v everywhere, output == v regardless
        of the attention pattern."""
        q, k = rand((2, 8, 16), 1), rand((2, 8, 16), 2)
        v = jnp.broadcast_to(
            jnp.asarray(np.float32(3.25)), (2, 8, 16))
        out = np.asarray(attention_kernel(q, k, v))
        np.testing.assert_allclose(out, 3.25, rtol=1e-5)

    def test_one_hot_attention(self):
        """A query identical to one key (with large scale) attends to
        that key's value."""
        d = 16
        k = rand((1, 4, d), 5, scale=1.0)
        v = rand((1, 4, d), 6)
        q = 50.0 * k[:, 2:3, :]     # enormous logit on key 2
        out = np.asarray(attention_kernel(q, k, v))
        np.testing.assert_allclose(out[0, 0], np.asarray(v)[0, 2],
                                   rtol=1e-3, atol=1e-3)

    def test_softmax_shift_invariance(self):
        """attention(q, k, v) is invariant to adding a constant vector
        offset to every key along q's direction: guarded implicitly by
        the max-subtraction; sanity-check no NaN with large logits."""
        q = 100.0 * rand((2, 8, 16), 7)
        k = 100.0 * rand((2, 8, 16), 8)
        v = rand((2, 8, 16), 9)
        out = np.asarray(attention_kernel(q, k, v))
        assert np.isfinite(out).all()
