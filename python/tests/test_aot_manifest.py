"""Artifact/manifest consistency (runs only after `make artifacts`)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


class TestManifest:
    def test_components_present(self, manifest):
        expect = {"text_encoder", "unet_base", "unet_mobile", "decoder",
                  "block_fp", "block_w8", "block_w8p"}
        assert expect <= set(manifest["components"].keys())

    def test_hlo_files_exist_and_hash(self, manifest):
        import hashlib
        for name, comp in manifest["components"].items():
            path = os.path.join(ART, comp["hlo"])
            assert os.path.exists(path), path
            with open(path) as f:
                text = f.read()
            assert hashlib.sha256(text.encode()).hexdigest() == \
                comp["hlo_sha256"], name
            assert text.startswith("HloModule"), name

    def test_weight_files_exist(self, manifest):
        for name, comp in manifest["components"].items():
            for tag, meta in comp.get("weights", {}).items():
                path = os.path.join(ART, meta["file"])
                assert os.path.exists(path), (name, tag)
                assert os.path.getsize(path) == meta["bytes"]

    def test_int8_compression_ratio(self, manifest):
        w = manifest["components"]["unet_mobile"]["weights"]
        ratio = w["fp32"]["bytes"] / w["int8"]["bytes"]
        assert ratio > 3.0, f"int8 should be ~4x smaller, got {ratio:.2f}x"
        assert w["int8_pruned"]["bytes"] < w["int8"]["bytes"]

    def test_unet_params_match_weights(self, manifest):
        from compile import weightsbin
        comp = manifest["components"]["unet_mobile"]
        loaded = weightsbin.read(
            os.path.join(ART, comp["weights"]["fp32"]["file"]))
        assert len(loaded) == len(comp["params"])
        for p in comp["params"]:
            assert p["path"] in loaded
            assert list(loaded[p["path"]].shape) == p["shape"]

    def test_int8_dequant_close_to_fp32(self, manifest):
        from compile import weightsbin
        comp = manifest["components"]["unet_mobile"]
        fp = weightsbin.read(os.path.join(ART, comp["weights"]["fp32"]["file"]))
        q = weightsbin.read(os.path.join(ART, comp["weights"]["int8"]["file"]))
        # spot-check a conv weight: max error <= scale/2 ~ small
        key = next(k for k in fp if k.endswith("conv_in/w"))
        rel = np.abs(fp[key] - q[key]).max() / np.abs(fp[key]).max()
        assert rel < 0.01, rel

    def test_scheduler_section(self, manifest):
        s = manifest["scheduler"]
        acp = np.asarray(s["alphas_cumprod"])
        assert len(acp) == s["num_train_timesteps"]
        assert np.all(np.diff(acp) < 0)
        assert len(s["timesteps"]) == s["num_inference_steps"]
        assert len(s["golden"]["trace"]) == 5

    def test_tokenizer_goldens(self, manifest):
        from compile import tokenizer
        t = manifest["tokenizer"]
        for g in t["golden"]:
            assert g["ids"] == tokenizer.encode(
                g["text"], t["vocab_size"], t["seq_len"])

    def test_graph_specs_exist(self):
        for scale in ("small", "sd_v21"):
            for comp in ("unet", "text_encoder", "decoder"):
                path = os.path.join(ART, f"{scale}_{comp}.graph.json")
                assert os.path.exists(path)
                with open(path) as f:
                    g = json.load(f)
                assert g["ops"] and g["tensors"]
