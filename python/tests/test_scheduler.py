"""DDIM scheduler reference: math invariants + golden compatibility."""

import numpy as np
import pytest

from compile import scheduler
from compile.config import SchedulerConfig

CFG = SchedulerConfig()


class TestSchedule:
    def test_betas_monotone_and_bounded(self):
        b = scheduler.betas(CFG)
        assert len(b) == 1000
        assert np.all(np.diff(b) > 0)
        assert b[0] == pytest.approx(CFG.beta_start)
        assert b[-1] == pytest.approx(CFG.beta_end)

    def test_alphas_cumprod_decreasing(self):
        a = scheduler.alphas_cumprod(CFG)
        assert np.all(np.diff(a) < 0)
        assert 0 < a[-1] < a[0] < 1

    def test_timesteps_descending_and_count(self):
        ts = scheduler.timesteps(CFG)
        assert len(ts) == 20
        assert ts == sorted(ts, reverse=True)
        assert ts[-1] == 0

    def test_progressive_halving(self):
        assert len(scheduler.progressive_timesteps(CFG, 0)) == 20
        assert len(scheduler.progressive_timesteps(CFG, 1)) == 10
        assert len(scheduler.progressive_timesteps(CFG, 2)) == 5
        with pytest.raises(ValueError):
            scheduler.progressive_timesteps(CFG, 12)


class TestDdimStep:
    def test_zero_eps_converges_to_x0(self):
        """With eps == 0 the DDIM update is x0-preserving rescaling:
        at the final step (t_prev = -1) latent == x0 exactly."""
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([1.0, -2.0, 0.5])
        t = 100
        x0 = latent / np.sqrt(acp[t])
        out = scheduler.ddim_step(latent, np.zeros(3), t, -1, acp)
        np.testing.assert_allclose(out, x0, rtol=1e-12)

    def test_pure_noise_invariant(self):
        """If latent == sqrt(1-a_t) * eps (zero signal), the update maps
        it to sqrt(1-a_prev) * eps."""
        acp = scheduler.alphas_cumprod(CFG)
        eps = np.array([0.3, -1.2, 2.0])
        t, t_prev = 500, 450
        latent = np.sqrt(1 - acp[t]) * eps
        out = scheduler.ddim_step(latent, eps, t, t_prev, acp)
        np.testing.assert_allclose(out, np.sqrt(1 - acp[t_prev]) * eps,
                                   rtol=1e-10)

    def test_identity_when_t_equals_prev(self):
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([0.7, -0.1])
        eps = np.array([0.2, 0.4])
        out = scheduler.ddim_step(latent, eps, 300, 300, acp)
        np.testing.assert_allclose(out, latent, rtol=1e-10)


class TestGuidance:
    def test_scale_one_returns_cond(self):
        u, c = np.array([1.0, 2.0]), np.array([3.0, -1.0])
        np.testing.assert_array_equal(scheduler.guide(u, c, 1.0), c)

    def test_scale_zero_returns_uncond(self):
        u, c = np.array([1.0, 2.0]), np.array([3.0, -1.0])
        np.testing.assert_array_equal(scheduler.guide(u, c, 0.0), u)

    def test_extrapolation(self):
        u, c = np.zeros(2), np.ones(2)
        np.testing.assert_array_equal(scheduler.guide(u, c, 7.5),
                                      np.full(2, 7.5))


class TestSampleLoop:
    def test_sample_with_mock_unet(self):
        """End-to-end loop with a deterministic mock: finite output,
        correct shape, sensitive to guidance scale."""
        rng = np.random.default_rng(0)
        latent = rng.normal(size=(1, 4, 4, 2))
        ctx = np.zeros((2, 3, 8))

        def unet_call(lat2, t):
            # pseudo-eps that differs between the CFG halves
            return np.concatenate([0.1 * lat2[:1], 0.2 * lat2[1:]], axis=0)

        out = scheduler.sample(unet_call, latent.copy(), ctx, CFG)
        assert out.shape == latent.shape
        assert np.isfinite(out).all()

        cfg2 = SchedulerConfig(guidance_scale=1.0)
        out2 = scheduler.sample(unet_call, latent.copy(), ctx, cfg2)
        assert np.abs(out - out2).max() > 1e-6

    def test_fewer_steps_still_finite(self):
        latent = np.ones((1, 2, 2, 1))
        out = scheduler.sample(lambda l, t: 0.05 * l, latent,
                               np.zeros((2, 1, 1)), CFG, num_steps=5)
        assert np.isfinite(out).all()
