"""Sampler reference: math invariants + golden compatibility."""

import math

import numpy as np
import pytest

from compile import scheduler
from compile.config import SchedulerConfig

CFG = SchedulerConfig()


class TestSchedule:
    def test_betas_monotone_and_bounded(self):
        b = scheduler.betas(CFG)
        assert len(b) == 1000
        assert np.all(np.diff(b) > 0)
        assert b[0] == pytest.approx(CFG.beta_start)
        assert b[-1] == pytest.approx(CFG.beta_end)

    def test_alphas_cumprod_decreasing(self):
        a = scheduler.alphas_cumprod(CFG)
        assert np.all(np.diff(a) < 0)
        assert 0 < a[-1] < a[0] < 1

    def test_timesteps_descending_and_count(self):
        ts = scheduler.timesteps(CFG)
        assert len(ts) == 20
        assert ts == sorted(ts, reverse=True)
        assert ts[-1] == 0

    def test_progressive_halving(self):
        assert len(scheduler.progressive_timesteps(CFG, 0)) == 20
        assert len(scheduler.progressive_timesteps(CFG, 1)) == 10
        assert len(scheduler.progressive_timesteps(CFG, 2)) == 5
        with pytest.raises(ValueError):
            scheduler.progressive_timesteps(CFG, 12)

    def test_distilled_timesteps_halve_the_fixed_teacher(self):
        """The distilled family halves a 32-step teacher regardless of
        the configured inference count (matches the Rust samplers)."""
        for halvings, want in [(0, 32), (1, 16), (2, 8), (3, 4)]:
            ts = scheduler.distilled_timesteps(CFG, halvings)
            assert len(ts) == want
            assert ts[-1] == 0
            assert ts == sorted(ts, reverse=True)
        # distilled8 / distilled4 are exactly these levels
        assert len(scheduler.distilled_timesteps(CFG, 2)) == 8
        assert len(scheduler.distilled_timesteps(CFG, 3)) == 4
        with pytest.raises(ValueError):
            scheduler.distilled_timesteps(CFG, 6)


class TestDdimStep:
    def test_zero_eps_converges_to_x0(self):
        """With eps == 0 the DDIM update is x0-preserving rescaling:
        at the final step (t_prev = -1) latent == x0 exactly."""
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([1.0, -2.0, 0.5])
        t = 100
        x0 = latent / np.sqrt(acp[t])
        out = scheduler.ddim_step(latent, np.zeros(3), t, -1, acp)
        np.testing.assert_allclose(out, x0, rtol=1e-12)

    def test_pure_noise_invariant(self):
        """If latent == sqrt(1-a_t) * eps (zero signal), the update maps
        it to sqrt(1-a_prev) * eps."""
        acp = scheduler.alphas_cumprod(CFG)
        eps = np.array([0.3, -1.2, 2.0])
        t, t_prev = 500, 450
        latent = np.sqrt(1 - acp[t]) * eps
        out = scheduler.ddim_step(latent, eps, t, t_prev, acp)
        np.testing.assert_allclose(out, np.sqrt(1 - acp[t_prev]) * eps,
                                   rtol=1e-10)

    def test_identity_when_t_equals_prev(self):
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([0.7, -0.1])
        eps = np.array([0.2, 0.4])
        out = scheduler.ddim_step(latent, eps, 300, 300, acp)
        np.testing.assert_allclose(out, latent, rtol=1e-10)


class TestDpm2mStep:
    def test_no_history_is_exactly_ddim(self):
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([1.0, -2.0, 0.5])
        eps = np.array([0.3, -1.2, 2.0])
        out = scheduler.dpm2m_step(latent, eps, None, 500, 450, -1, acp)
        np.testing.assert_array_equal(
            out, scheduler.ddim_step(latent, eps, 500, 450, acp))

    def test_final_step_is_first_order(self):
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([1.0, -2.0, 0.5])
        eps = np.array([0.3, -1.2, 2.0])
        prev = np.array([0.1, 0.2, 0.3])
        out = scheduler.dpm2m_step(latent, eps, prev, 50, -1, 100, acp)
        np.testing.assert_array_equal(
            out, scheduler.ddim_step(latent, eps, 50, -1, acp))

    def test_constant_eps_collapses_to_first_order(self):
        """With eps_prev == eps the extrapolated estimate D equals eps,
        so the second-order update is the DDIM update exactly."""
        acp = scheduler.alphas_cumprod(CFG)
        latent = np.array([0.9, -1.1])
        eps = np.array([0.7, -0.4])
        out = scheduler.dpm2m_step(latent, eps, eps.copy(), 500, 450, 550, acp)
        np.testing.assert_allclose(
            out, scheduler.ddim_step(latent, eps, 500, 450, acp), rtol=1e-12)

    def test_second_order_matches_reference_formula(self):
        acp = scheduler.alphas_cumprod(CFG)
        t_last, t, t_prev = 550, 500, 450
        latent = np.array([1.0, -2.0])
        eps = np.array([0.3, -1.2])
        prev = np.array([0.5, -1.0])
        out = scheduler.dpm2m_step(latent, eps, prev, t, t_prev, t_last, acp)

        def lam(a):
            return math.log(math.sqrt(a) / math.sqrt(1.0 - a))

        h = lam(acp[t_prev]) - lam(acp[t])
        h_last = lam(acp[t]) - lam(acp[t_last])
        c = h / (2.0 * h_last)
        d = (1.0 + c) * eps - c * prev
        x0 = (latent - math.sqrt(1.0 - acp[t]) * d) / math.sqrt(acp[t])
        want = math.sqrt(acp[t_prev]) * x0 + math.sqrt(1.0 - acp[t_prev]) * d
        np.testing.assert_allclose(out, want, rtol=1e-12)


class TestGuidance:
    def test_scale_one_returns_cond(self):
        u, c = np.array([1.0, 2.0]), np.array([3.0, -1.0])
        np.testing.assert_array_equal(scheduler.guide(u, c, 1.0), c)

    def test_scale_zero_returns_uncond(self):
        u, c = np.array([1.0, 2.0]), np.array([3.0, -1.0])
        np.testing.assert_array_equal(scheduler.guide(u, c, 0.0), u)

    def test_extrapolation(self):
        u, c = np.zeros(2), np.ones(2)
        np.testing.assert_array_equal(scheduler.guide(u, c, 7.5),
                                      np.full(2, 7.5))


class TestSampleLoop:
    def test_sample_with_mock_unet(self):
        """End-to-end loop with a deterministic mock: finite output,
        correct shape, sensitive to guidance scale."""
        rng = np.random.default_rng(0)
        latent = rng.normal(size=(1, 4, 4, 2))
        ctx = np.zeros((2, 3, 8))

        def unet_call(lat2, t):
            # pseudo-eps that differs between the CFG halves
            return np.concatenate([0.1 * lat2[:1], 0.2 * lat2[1:]], axis=0)

        out = scheduler.sample(unet_call, latent.copy(), ctx, CFG)
        assert out.shape == latent.shape
        assert np.isfinite(out).all()

        cfg2 = SchedulerConfig(guidance_scale=1.0)
        out2 = scheduler.sample(unet_call, latent.copy(), ctx, cfg2)
        assert np.abs(out - out2).max() > 1e-6

    def test_fewer_steps_still_finite(self):
        latent = np.ones((1, 2, 2, 1))
        out = scheduler.sample(lambda l, t: 0.05 * l, latent,
                               np.zeros((2, 1, 1)), CFG, num_steps=5)
        assert np.isfinite(out).all()

    def test_multistep_diverges_from_ddim_then_lands_close(self):
        """Same surrogate UNet, 8 steps: the multistep loop must change
        the trajectory (second order is real) yet land near the DDIM
        endpoint (it estimates the same ODE solution)."""
        latent = np.array([[1.0, -0.5, 0.25, 2.0]])
        ctx = np.zeros((2, 1, 1))

        def unet_call(lat2, t):
            return 0.1 * lat2

        a = scheduler.sample(unet_call, latent.copy(), ctx, CFG, num_steps=8)
        b = scheduler.sample_multistep(unet_call, latent.copy(), ctx, CFG,
                                       num_steps=8)
        assert np.abs(a - b).max() > 0, "second order must differ"
        np.testing.assert_allclose(b, a, rtol=0.2)

    def test_multistep_single_step_equals_ddim(self):
        """A one-step schedule never accumulates history, so the two
        loops are identical."""
        latent = np.array([[0.4, -0.7]])
        ctx = np.zeros((2, 1, 1))

        def unet_call(lat2, t):
            return 0.05 * lat2

        a = scheduler.sample(unet_call, latent.copy(), ctx, CFG, num_steps=1)
        b = scheduler.sample_multistep(unet_call, latent.copy(), ctx, CFG,
                                       num_steps=1)
        np.testing.assert_array_equal(a, b)
