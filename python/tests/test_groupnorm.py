"""Broadcast-free group normalization (paper Sec. 3.1 / Fig. 7)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.groupnorm import group_norm_kernel


def rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (scale * np.random.default_rng(seed).normal(size=shape)).astype(np.float32))


class TestEquivalence:
    """The rewrite must be semantics-preserving: naive (rank-5 +
    BroadcastTo) == broadcast-free (rank <= 4) == Pallas kernel."""

    @pytest.mark.parametrize("h,w,c,g", [(8, 8, 32, 8), (16, 16, 64, 8),
                                         (4, 4, 16, 4), (32, 32, 64, 8)])
    def test_naive_vs_bcast_free(self, h, w, c, g):
        x = rand((1, h, w, c), seed=h * w)
        gamma, beta = rand((c,), 1), rand((c,), 2)
        np.testing.assert_allclose(
            ref.group_norm_naive(x, gamma, beta, g),
            ref.group_norm_bcast_free(x, gamma, beta, g),
            rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("h,w,c,g", [(8, 8, 32, 8), (16, 16, 64, 8)])
    def test_kernel_vs_naive(self, h, w, c, g):
        x = rand((1, h, w, c), seed=7)
        gamma, beta = rand((c,), 8), rand((c,), 9)
        np.testing.assert_allclose(
            group_norm_kernel(x, gamma, beta, g),
            ref.group_norm_naive(x, gamma, beta, g),
            rtol=1e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        hw=st.sampled_from([2, 4, 8, 16]),
        cg=st.sampled_from([2, 4, 8, 16]),
        g=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(0.01, 100.0),
    )
    def test_hypothesis_sweep(self, hw, cg, g, seed, scale):
        c = cg * g
        x = rand((1, hw, hw, c), seed=seed, scale=scale)
        gamma, beta = rand((c,), seed + 1), rand((c,), seed + 2)
        np.testing.assert_allclose(
            group_norm_kernel(x, gamma, beta, g),
            ref.group_norm_bcast_free(x, gamma, beta, g),
            rtol=2e-4, atol=2e-4)


class TestNormalization:
    def test_output_statistics(self):
        """With identity affine, each group is ~N(0, 1) after the norm."""
        x = rand((1, 16, 16, 32), seed=3, scale=5.0) + 2.0
        out = np.asarray(group_norm_kernel(
            x, jnp.ones(32), jnp.zeros(32), 8))
        grouped = out.reshape(16 * 16, 8, 4)
        means = grouped.mean(axis=(0, 2))
        stds = grouped.std(axis=(0, 2))
        np.testing.assert_allclose(means, 0.0, atol=1e-4)
        np.testing.assert_allclose(stds, 1.0, atol=1e-3)

    def test_affine_applied(self):
        x = rand((1, 4, 4, 8), seed=4)
        gamma = jnp.asarray(np.full(8, 3.0, np.float32))
        beta = jnp.asarray(np.full(8, -1.0, np.float32))
        base = np.asarray(group_norm_kernel(x, jnp.ones(8), jnp.zeros(8), 4))
        out = np.asarray(group_norm_kernel(x, gamma, beta, 4))
        np.testing.assert_allclose(out, 3.0 * base - 1.0, rtol=1e-4, atol=1e-5)

    def test_scale_invariance(self):
        """GN(a*x) == GN(x) for a > 0 (mean/var cancel the scale)."""
        x = rand((1, 8, 8, 16), seed=5)
        a = 37.5
        np.testing.assert_allclose(
            group_norm_kernel(a * x, jnp.ones(16), jnp.zeros(16), 4),
            group_norm_kernel(x, jnp.ones(16), jnp.zeros(16), 4),
            rtol=1e-3, atol=1e-4)
