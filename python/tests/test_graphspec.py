"""TFLite-level graph spec well-formedness + the paper's critical shapes."""

import numpy as np
import pytest

from compile import graphspec


@pytest.fixture(scope="module")
def small():
    return graphspec.build_all("small")


@pytest.fixture(scope="module")
def sd():
    return graphspec.build_all("sd_v21")


def tensors_by_id(g):
    return {t["id"]: t for t in g["tensors"]}


class TestWellFormed:
    @pytest.mark.parametrize("scale", ["small", "sd_v21"])
    def test_ssa_and_references(self, scale):
        for g in graphspec.build_all(scale).values():
            ids = [t["id"] for t in g["tensors"]]
            assert ids == list(range(len(ids)))
            produced = set()
            for op in g["ops"]:
                for i in op["inputs"]:
                    assert 0 <= i < len(ids)
                for o in op["outputs"]:
                    assert o not in produced, "tensor produced twice"
                    produced.add(o)

    def test_shapes_positive(self, sd):
        for g in sd.values():
            for t in g["tensors"]:
                assert all(d > 0 for d in t["shape"]), t


class TestPaperShapes:
    def test_sd_unet_has_4096x320_fc(self, sd):
        g = sd["unet"]
        tens = tensors_by_id(g)
        hits = [o for o in g["ops"] if o["type"] == "FULLY_CONNECTED"
                and tens[o["inputs"][0]]["shape"] == [1, 4096, 320]]
        assert len(hits) > 0

    def test_sd_unet_has_1920_to_640_conv(self, sd):
        g = sd["unet"]
        tens = tensors_by_id(g)
        hits = [o for o in g["ops"] if o["type"] == "CONV_2D"
                and o["attrs"].get("kernel") == 3
                and tens[o["inputs"][0]]["shape"] == [1, 32, 32, 1920]
                and tens[o["outputs"][0]]["shape"] == [1, 32, 32, 640]]
        assert len(hits) == 1, hits

    def test_small_unet_has_bottleneck_analog(self, small):
        g = small["unet"]
        tens = tensors_by_id(g)
        hits = [o for o in g["ops"] if o["type"] == "CONV_2D"
                and o["attrs"].get("kernel") == 3
                and tens[o["inputs"][0]]["shape"] == [1, 32, 32, 192]
                and tens[o["outputs"][0]]["shape"] == [1, 32, 32, 64]]
        assert len(hits) >= 1

    def test_broadcast_and_rank5_in_export_graphs(self, sd):
        """The stock export contains the delegation blockers."""
        g = sd["unet"]
        types = {o["type"] for o in g["ops"]}
        assert "BROADCAST_TO" in types
        tens = tensors_by_id(g)
        rank5 = [t for t in g["tensors"] if len(t["shape"]) == 5]
        assert rank5, "export group norm must contain rank-5 tensors"


class TestBroadcastFreeEmitter:
    def test_bcast_free_groupnorm_is_clean(self):
        g = graphspec.GraphBuilder("t")
        x = g.tensor("x", [1, 16, 16, 64])
        g.group_norm("gn", x, 8, bcast_free=True)
        types = [o["type"] for o in g.ops]
        assert "BROADCAST_TO" not in types
        for t in g.tensors:
            assert len(t["shape"]) <= 4

    def test_stable_gelu_has_clamp(self):
        g = graphspec.GraphBuilder("t")
        x = g.tensor("x", [1, 256, 512])
        g.gelu("gelu", x, stable=True)
        types = [o["type"] for o in g.ops]
        assert "MINIMUM" in types and "MAXIMUM" in types

    def test_baseline_gelu_no_clamp(self):
        g = graphspec.GraphBuilder("t")
        x = g.tensor("x", [1, 256, 512])
        g.gelu("gelu", x, stable=False)
        types = [o["type"] for o in g.ops]
        assert "MINIMUM" not in types


class TestParamAccounting:
    def test_sd_unet_parameter_count_plausible(self, sd):
        """SD v2.1 UNet has ~865M params; our shape-level spec should be
        in that ballpark (weights only, fp16 ~1.7 GB)."""
        g = sd["unet"]
        n = sum(int(np.prod(t["shape"]))
                for t in g["tensors"] if t["const"])
        assert 6e8 < n < 1.2e9, n

    def test_small_unet_parameter_count(self, small):
        g = small["unet"]
        n = sum(int(np.prod(t["shape"]))
                for t in g["tensors"] if t["const"])
        assert 2e6 < n < 2e7, n
