"""GELU kernel correctness + the paper's Sec. 3.2 float16 instability."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gelu import gelu_stable_kernel, gelu_tanh_kernel

SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)


def exact_gelu(x):
    from math import erf
    return np.array([0.5 * v * (1.0 + erf(v / math.sqrt(2.0))) for v in x])


class TestKernelVsRef:
    @pytest.mark.parametrize("shape", [(4,), (3, 5), (2, 7, 11), (1, 16, 16, 64)])
    def test_tanh_matches_ref(self, shape):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        np.testing.assert_allclose(
            gelu_tanh_kernel(x), ref.gelu_tanh(x), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("shape", [(4,), (3, 5), (2, 7, 11)])
    def test_stable_matches_ref(self, shape):
        rng = np.random.default_rng(1)
        x = jnp.asarray((5 * rng.normal(size=shape)).astype(np.float32))
        np.testing.assert_allclose(
            gelu_stable_kernel(x), ref.gelu_stable(x), rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 2000),
        scale=st.floats(0.1, 30.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, scale, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray((scale * rng.normal(size=n)).astype(np.float32))
        np.testing.assert_allclose(
            gelu_tanh_kernel(x), ref.gelu_tanh(x), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            gelu_stable_kernel(x), ref.gelu_stable(x), rtol=1e-4, atol=1e-5)


class TestApproximationQuality:
    def test_tanh_approx_close_to_exact(self):
        x = np.linspace(-6, 6, 201).astype(np.float64)
        approx = np.asarray(ref.gelu_tanh(jnp.asarray(x)))
        np.testing.assert_allclose(approx, exact_gelu(x), atol=2e-3)

    def test_stable_equals_tanh_inside_clip(self):
        """gamma_M is the identity for |x| <= M, so both approximations
        agree exactly there (paper: 'maintains the image quality')."""
        x = jnp.asarray(np.linspace(-10, 10, 401).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(ref.gelu_stable(x, clip=10.0)),
            np.asarray(ref.gelu_tanh(x)))

    def test_stable_correct_outside_clip(self):
        """For |x| > M, tanh has already saturated: GELU(x) ~= x for
        x >> 0 and ~= 0 for x << 0."""
        x = jnp.asarray(np.array([15.0, 30.0, 100.0], dtype=np.float32))
        np.testing.assert_allclose(ref.gelu_stable(x), x, rtol=1e-6)
        xn = -x
        np.testing.assert_allclose(ref.gelu_stable(xn), 0.0 * xn, atol=1e-6)


class TestFloat16Instability:
    """The paper's core observation: the cubic term overflows float16."""

    def test_cubic_term_overflows_f16(self):
        # x^3 > 65504 for x > ~40.3 -> inf in binary16
        x = jnp.asarray([50.0], dtype=jnp.float16)
        cubic = x * x * x
        assert np.isinf(np.asarray(cubic)).all()

    def test_baseline_gelu_f16_nonfinite_intermediates(self):
        x = jnp.asarray([64.0, 128.0, 1000.0], dtype=jnp.float16)
        inner = jnp.float16(SQRT_2_OVER_PI) * (
            x + jnp.float16(ref.GELU_CUBIC) * x * x * x)
        assert np.isinf(np.asarray(inner)).any()

    def test_stable_gelu_f16_all_finite(self):
        """With the gamma_10 clamp every intermediate is finite in f16:
        max |inner| = sqrt(2/pi)*(10 + 0.044715*1000) ~= 43.7."""
        x = jnp.asarray(
            np.concatenate([np.linspace(-60000, 60000, 997),
                            [-40.4, 40.4, 50.0, -50.0]]).astype(np.float16))
        g = jnp.clip(x, -10.0, 10.0)
        cubic = g * g * g
        inner = jnp.float16(SQRT_2_OVER_PI) * (
            g + jnp.float16(ref.GELU_CUBIC) * cubic)
        out = jnp.float16(0.5) * x * (jnp.float16(1.0) + jnp.tanh(inner))
        for t in (g, cubic, inner, out):
            assert np.isfinite(np.asarray(t)).all()

    def test_instability_threshold(self):
        """Exact f16 overflow threshold of x**3: 65504**(1/3) ~= 40.31."""
        below = jnp.asarray([40.28], dtype=jnp.float16)
        above = jnp.asarray([40.34], dtype=jnp.float16)
        assert np.isfinite(np.asarray(below * below * below)).all()
        assert np.isinf(np.asarray(above * above * above)).all()
