"""W8A16 dequantize-matmul kernel + quantization error bounds."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize
from compile.kernels import ref
from compile.kernels.w8a16_matmul import w8a16_matmul_kernel


def rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        (scale * np.random.default_rng(seed).normal(size=shape)).astype(np.float32))


class TestKernel:
    @pytest.mark.parametrize("m,k,n", [(4, 8, 16), (64, 128, 128),
                                       (256, 128, 512), (7, 24, 16)])
    def test_matches_ref(self, m, k, n):
        x = rand((m, k), 1)
        w_q = jnp.asarray(np.random.default_rng(2).integers(
            -127, 128, size=(k, n)).astype(np.int8))
        scale = jnp.asarray(np.random.default_rng(3).uniform(
            0.001, 0.1, size=n).astype(np.float32))
        np.testing.assert_allclose(
            w8a16_matmul_kernel(x, w_q, scale),
            ref.w8a16_matmul(x, w_q, scale), rtol=1e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 64), k=st.sampled_from([8, 16, 64]),
           n=st.sampled_from([8, 16, 128, 256]),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        w_q = jnp.asarray(rng.integers(-127, 128, size=(k, n)).astype(np.int8))
        scale = jnp.asarray(rng.uniform(0.001, 0.1, size=n).astype(np.float32))
        np.testing.assert_allclose(
            w8a16_matmul_kernel(x, w_q, scale),
            ref.w8a16_matmul(x, w_q, scale), rtol=2e-4, atol=2e-4)


class TestQuantization:
    def test_round_trip_error_bound(self):
        """Per-channel symmetric int8: |w - dq(q(w))| <= scale/2 per elem."""
        w = np.asarray(rand((64, 32), 5, scale=0.2))
        q, scale = quantize.quantize_per_channel(w)
        dq = quantize.dequantize(q, scale)
        assert np.all(np.abs(w - dq) <= scale[None, :] * 0.5 + 1e-8)

    def test_quant_preserves_zero(self):
        w = np.zeros((8, 8), np.float32)
        q, scale = quantize.quantize_per_channel(w)
        assert np.all(q == 0)
        np.testing.assert_array_equal(quantize.dequantize(q, scale), w)

    def test_quant_range_uses_127(self):
        w = np.asarray(rand((128, 16), 6))
        q, _ = quantize.quantize_per_channel(w)
        assert q.max() == 127 or q.min() == -127

    @settings(max_examples=25, deadline=None)
    @given(rows=st.integers(1, 100), cols=st.integers(1, 40),
           seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
    def test_hypothesis_round_trip(self, rows, cols, seed, scale):
        w = np.asarray(rand((rows, cols), seed, scale))
        q, s = quantize.quantize_per_channel(w)
        dq = quantize.dequantize(q, s)
        assert np.all(np.abs(w - dq) <= s[None, :] * 0.5 + 1e-6 * scale)


class TestPruning:
    def test_prune_fraction(self):
        w = np.asarray(rand((3, 3, 16, 32), 7))
        pruned, keep = quantize.prune_structured(w, 0.25)
        assert keep.sum() == 24
        assert np.all(pruned[..., ~keep] == 0)
        np.testing.assert_array_equal(pruned[..., keep], w[..., keep])

    def test_prunes_lowest_norm_channels(self):
        w = np.ones((4, 8), np.float32)
        w[:, 3] = 0.001     # weakest channel
        w[:, 6] = 0.01      # second weakest
        _, keep = quantize.prune_structured(w, 0.25)
        assert not keep[3] and not keep[6]

    def test_prune_targets_only_huge_convs(self):
        paths = ["a/conv/w", "b/conv/w", "c/norm/gamma", "d/lin/w"]
        arrays = [np.zeros((3, 3, 192, 64), np.float32),     # 110k elems
                  np.zeros((3, 3, 4, 8), np.float32),        # small
                  np.zeros(64, np.float32),
                  np.zeros((500, 500), np.float32)]          # not conv
        assert quantize.prune_targets(paths, arrays) == ["a/conv/w"]


class TestWeightsBin:
    def test_round_trip_f32(self, tmp_path):
        from compile import weightsbin
        w = np.asarray(rand((5, 7), 8))
        p = str(tmp_path / "w.bin")
        weightsbin.write(p, [{"path": "x/w", "arr": w}])
        out = weightsbin.read(p)
        np.testing.assert_array_equal(out["x/w"], w)

    def test_round_trip_int8_pruned(self, tmp_path):
        from compile import weightsbin
        w = np.asarray(rand((3, 3, 8, 16), 9))
        pruned, keep = quantize.prune_structured(w, 0.25)
        q, scale = quantize.quantize_per_channel(pruned)
        p = str(tmp_path / "w.bin")
        size = weightsbin.write(
            p, [{"path": "c/w", "q": q, "scale": scale, "keep": keep}])
        out = weightsbin.read(p)["c/w"]
        assert out.shape == w.shape
        assert np.all(out[..., ~keep] == 0)
        np.testing.assert_allclose(out, quantize.dequantize(q, scale),
                                   atol=1e-6)
        # storage is ~1/4 of f32 (int8 payload + f32 scales, minus pruned)
        assert size < w.size * 4 * 0.4

    def test_reconstruction_error_metric(self):
        a = np.zeros(10)
        b = np.full(10, 2.0)
        assert quantize.reconstruction_error(a, b) == pytest.approx(4.0)
