"""Pallas broadcast-free group normalization (paper Sec. 3.1 / Fig. 7).

The TFLite export of group norm materializes a rank-5 reshape and an
explicit ``BroadcastTo`` — the op the GPU delegate cannot run.  The
broadcast-free formulation keeps every tensor rank <= 4 and fuses the
whole normalization into a single VMEM-resident pass per group:

  grid = (groups,); each step stages the (H*W, C/g) slice of the input
  into VMEM, computes mean/var with an in-register reduction, normalizes,
  applies the affine, and writes the slice back — one HBM read + one HBM
  write per element, no broadcast materialization.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _gn_body(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]                       # (HW, Cg) — rank 2 in VMEM
    mean = jnp.mean(x)
    var = jnp.mean(jnp.square(x - mean))
    inv = lax.rsqrt(var + eps)
    # per-channel affine: g/b are (1, Cg) slices of gamma/beta
    o_ref[...] = (x - mean) * inv * g_ref[...] + b_ref[...]


def group_norm_kernel(x, gamma, beta, groups: int, eps: float = 1e-5):
    """x: (N, H, W, C) NHWC with N == 1; gamma/beta: (C,)."""
    n, h, w, c = x.shape
    assert n == 1, "mobile path is batch-1 per grid step"
    assert c % groups == 0, (c, groups)
    cg = c // groups
    hw = h * w

    x2 = x.reshape(hw, c)
    g2 = gamma.reshape(1, c)
    b2 = beta.reshape(1, c)

    out = pl.pallas_call(
        lambda x_ref, g_ref, b_ref, o_ref: _gn_body(
            x_ref, g_ref, b_ref, o_ref, eps=eps),
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((hw, cg), lambda i: (0, i)),
            pl.BlockSpec((1, cg), lambda i: (0, i)),
            pl.BlockSpec((1, cg), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((hw, cg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((hw, c), x.dtype),
        interpret=True,
    )(x2, g2, b2)
    return out.reshape(n, h, w, c)
