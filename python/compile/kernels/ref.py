"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suites compare against.
They are also used directly by the *baseline* (non-mobile) model variant,
so `unet_base` vs `unet_mobile` exercises reference-vs-kernel end to end.
"""

import math

import jax.numpy as jnp
from jax import lax

SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)
GELU_CUBIC = 0.044715


def gelu_tanh(x):
    """The well-known tanh approximation of GELU (paper Sec. 3.2, eq. 1).

    In float16 the cubic term overflows for |x| >~ 40.3 (x**3 > 65504),
    which is exactly the instability the paper observed on mobile GPUs.
    """
    inner = SQRT_2_OVER_PI * (x + GELU_CUBIC * x * x * x)
    return 0.5 * x * (1.0 + jnp.tanh(inner))


def gelu_stable(x, clip: float = 10.0):
    """Numerically stable GELU (paper Sec. 3.2, eq. 2).

    The argument of the cubic tanh term is clipped to [-M, M] first
    (gamma_M in the paper); tanh saturates to +-1 well before |x| = 10,
    so the result is unchanged while every intermediate stays finite in
    float16.
    """
    g = jnp.clip(x, -clip, clip)
    inner = SQRT_2_OVER_PI * (g + GELU_CUBIC * g * g * g)
    return 0.5 * x * (1.0 + jnp.tanh(inner))


def group_norm_naive(x, gamma, beta, groups: int, eps: float = 1e-5):
    """Group normalization the way TF/TFLite emits it: a rank-5 reshape and
    explicit broadcast (paper Fig. 7, left).  x: (N, H, W, C) NHWC."""
    n, h, w, c = x.shape
    cg = c // groups
    x5 = x.reshape(n, h, w, groups, cg)                    # rank-5 tensor
    mean = jnp.mean(x5, axis=(1, 2, 4), keepdims=True)     # (N,1,1,G,1)
    var = jnp.mean(jnp.square(x5 - mean), axis=(1, 2, 4), keepdims=True)
    # BroadcastTo is explicit in the TFLite graph; jnp broadcasts implicitly
    # but the *semantics* (rank-5 broadcast) are identical.
    x5 = (x5 - mean) * lax.rsqrt(var + eps)
    out = x5.reshape(n, h, w, c)
    return out * gamma.reshape(1, 1, 1, c) + beta.reshape(1, 1, 1, c)


def group_norm_bcast_free(x, gamma, beta, groups: int, eps: float = 1e-5):
    """Broadcast-free group normalization (paper Fig. 7, right): all
    intermediate tensors are rank <= 4, no BroadcastTo anywhere."""
    n, h, w, c = x.shape
    cg = c // groups
    x4 = x.reshape(n, h * w, groups, cg)                   # rank-4
    mean = jnp.mean(x4, axis=(1, 3), keepdims=True)        # (N,1,G,1)
    var = jnp.mean(jnp.square(x4 - mean), axis=(1, 3), keepdims=True)
    x4 = (x4 - mean) * lax.rsqrt(var + eps)
    out = x4.reshape(n, h, w, c)
    return out * gamma.reshape(1, 1, 1, c) + beta.reshape(1, 1, 1, c)


def attention(q, k, v, scale=None):
    """Scaled dot-product attention.  q: (H, Sq, D), k/v: (H, Skv, D)."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def conv2d_3x3(x, w, b=None):
    """3x3 same-padding conv, NHWC x HWIO -> NHWC."""
    out = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b.reshape(1, 1, 1, -1)
    return out


def conv2d_3x3_input_serialized(x, w, b=None, factor: int = 2):
    """Input-channel-serialized 3x3 conv (paper Fig. 1b, top path).

    The input channels are split into ``factor`` groups; each group is
    convolved against its slice of the kernel and the partial sums are
    accumulated.  Mathematically identical to conv2d_3x3 up to float
    summation order — the paper verified the output images are near
    identical (Fig. 2).
    """
    cin = x.shape[-1]
    assert cin % factor == 0, (cin, factor)
    cg = cin // factor
    out = None
    for i in range(factor):
        xs = x[..., i * cg:(i + 1) * cg]
        ws = w[:, :, i * cg:(i + 1) * cg, :]
        part = lax.conv_general_dilated(
            xs, ws, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        out = part if out is None else out + part
    if b is not None:
        out = out + b.reshape(1, 1, 1, -1)
    return out


def conv2d_3x3_output_serialized(x, w, b=None, factor: int = 8):
    """Output-channel-serialized 3x3 conv (paper Fig. 1b, bottom path):
    each call produces a slice of the output channels; results concat."""
    cout = w.shape[-1]
    assert cout % factor == 0, (cout, factor)
    cg = cout // factor
    parts = []
    for i in range(factor):
        ws = w[..., i * cg:(i + 1) * cg]
        part = lax.conv_general_dilated(
            x, ws, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if b is not None:
            part = part + b[i * cg:(i + 1) * cg].reshape(1, 1, 1, -1)
        parts.append(part)
    out = jnp.concatenate(parts, axis=-1)
    return out


def w8a16_matmul(x, w_q, scale):
    """Dequantize-then-matmul (paper Sec. 3.4): weights stored int8 with a
    per-output-channel scale, cast up before the matmul.
    x: (M, K) float, w_q: (K, N) int8, scale: (N,) float."""
    w = w_q.astype(x.dtype) * scale.reshape(1, -1)
    return x @ w


def fc_as_conv2d(x, w, b=None):
    """FullyConnected expressed as Reshape -> 1x1 Conv2D -> Reshape
    (paper Fig. 1a).  x: (S, K), w: (K, N).  Must equal x @ w + b."""
    s, k = x.shape
    n = w.shape[1]
    x4 = x.reshape(1, 1, s, k)                 # 1xHxWxC with H=1, W=S
    w4 = w.reshape(1, 1, k, n)                 # 1x1 conv kernel, HWIO
    out = lax.conv_general_dilated(
        x4, w4, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out.reshape(s, n)
    if b is not None:
        out = out + b.reshape(1, -1)
    return out
