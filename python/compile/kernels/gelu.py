"""Pallas GELU kernels — baseline tanh approximation and the paper's
numerically stable clipped variant (Sec. 3.2 / Fig. 8).

Elementwise VPU work: the input is flattened to (rows, LANE) and tiled row
blocks are streamed HBM->VMEM.  The stable variant adds a Minimum/Maximum
clamp (gamma_M) in front of the cubic term — the exact graph of paper
Fig. 8 — which costs two extra VPU ops and keeps every intermediate finite
in float16.
"""

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)
GELU_CUBIC = 0.044715

# VPU-friendly lane width; rows per grid step sized so a block stays well
# under VMEM (BLOCK_ROWS * 128 lanes * 4 B * 2 buffers ~= 256 KiB).
LANE = 128
BLOCK_ROWS = 256


def _gelu_body(x_ref, o_ref, *, clip):
    x = x_ref[...]
    if clip is None:
        g = x
    else:
        # paper Fig. 8: Minimum / Maximum ops ahead of the cubic term
        g = jnp.minimum(jnp.maximum(x, -clip), clip)
    inner = SQRT_2_OVER_PI * (g + GELU_CUBIC * g * g * g)
    o_ref[...] = 0.5 * x * (1.0 + jnp.tanh(inner))


def _run(x, clip):
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.size
    # pad to a whole (BLOCK_ROWS, LANE) tile grid
    per_block = BLOCK_ROWS * LANE
    blocks = max(1, -(-n // per_block))
    padded = blocks * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    x2 = flat.reshape(blocks * BLOCK_ROWS, LANE)

    out = pl.pallas_call(
        lambda x_ref, o_ref: _gelu_body(x_ref, o_ref, clip=clip),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=True,
    )(x2)
    return out.reshape(-1)[:n].reshape(shape)


def gelu_tanh_kernel(x):
    """Baseline tanh-approximated GELU (float16-unstable for |x| > ~40.3)."""
    return _run(x, clip=None)


def gelu_stable_kernel(x, clip: float = 10.0):
    """Numerically stable GELU with the gamma_M clamp (paper M = 10)."""
    return _run(x, clip=clip)
