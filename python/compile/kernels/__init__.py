"""Layer-1 Pallas kernels (build-time; lowered with interpret=True).

Each kernel has a pure-jnp oracle in :mod:`ref` and a pytest/hypothesis
suite in ``python/tests/``.  Real-TPU lowering would emit Mosaic
custom-calls the CPU PJRT plugin cannot execute, so every ``pallas_call``
here passes ``interpret=True`` — structure (BlockSpec tiling, VMEM
footprint) is authored for TPU, numerics are validated on CPU.
"""

from . import ref  # noqa: F401
from .gelu import gelu_stable_kernel, gelu_tanh_kernel  # noqa: F401
from .groupnorm import group_norm_kernel  # noqa: F401
from .attention import attention_kernel  # noqa: F401
from .serial_conv import conv3x3_input_serialized_kernel  # noqa: F401
from .w8a16_matmul import w8a16_matmul_kernel  # noqa: F401
