"""Pallas W8A16-style dequantize-then-matmul (paper Sec. 3.4).

Mobile GPUs have no integer matmul, so the paper stores weights as int8
(4x smaller than f32, 2x smaller than f16) and casts them up to float16
immediately before the matmul.  The TPU phrasing: stream int8 weight
tiles HBM->VMEM (quarter the bandwidth of f32), dequantize on the VPU
with the per-output-channel scale, and feed the MXU.

  grid = (N / BLOCK_N,); per step: (M, K) activations stay resident,
  one (K, BLOCK_N) int8 weight tile + (1, BLOCK_N) scale are staged,
  output block (M, BLOCK_N) written once.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _body(x_ref, w_ref, s_ref, o_ref):
    x = x_ref[...]                                   # (M, K) float
    w = w_ref[...].astype(x.dtype) * s_ref[...]      # dequant on the VPU
    o_ref[...] = jnp.dot(x, w)                       # MXU


def w8a16_matmul_kernel(x, w_q, scale):
    """x: (M, K) float; w_q: (K, N) int8; scale: (N,) float -> (M, N)."""
    m, k = x.shape
    kk, n = w_q.shape
    assert k == kk
    block_n = BLOCK_N if n % BLOCK_N == 0 else n
    grid = n // block_n

    return pl.pallas_call(
        _body,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w_q, scale.reshape(1, n))
