"""Pallas input-channel-serialized 3x3 convolution (paper Sec. 3.1 / Fig. 1b).

The paper splits one over-sized Conv2D into ``factor`` sequential OpenCL
kernel calls over input-channel groups to fit the delegate's buffer limit.
On TPU the same computation reordering is a BlockSpec schedule: the grid
iterates over input-channel groups, each step stages one (H+2, W+2, Cin/f)
input slice and its (3, 3, Cin/f, Cout) kernel slice HBM->VMEM and
accumulates partial sums into the output block (whose index map is
constant, so it stays VMEM-resident across grid steps).

Inside the kernel the 3x3 conv is expressed as 9 shifted (HW, Cg) @
(Cg, Cout) matmuls — MXU-shaped work rather than a scalar stencil.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_body(x_ref, w_ref, o_ref, *, h, w_dim):
    # x_ref: (H+2, W+2, Cg) padded input slice; w_ref: (3, 3, Cg, Cout)
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    wk = w_ref[...]
    cg = x.shape[-1]
    cout = wk.shape[-1]
    acc = jnp.zeros((h * w_dim, cout), dtype=o_ref.dtype)
    for dy in range(3):
        for dx in range(3):
            patch = x[dy:dy + h, dx:dx + w_dim, :].reshape(h * w_dim, cg)
            acc = acc + jnp.dot(patch, wk[dy, dx])          # MXU
    o_ref[...] += acc.reshape(h, w_dim, cout)


def conv3x3_input_serialized_kernel(x, w, b=None, factor: int = 2):
    """x: (1, H, W, Cin) NHWC; w: (3, 3, Cin, Cout) HWIO; same padding.

    ``factor`` input-channel groups are processed sequentially, partial
    sums accumulated in the VMEM-resident output block — numerically the
    input serialization of paper Fig. 1b.
    """
    n, h, wd, cin = x.shape
    assert n == 1
    assert cin % factor == 0, (cin, factor)
    cg = cin // factor
    cout = w.shape[-1]

    xp = jnp.pad(x[0], ((1, 1), (1, 1), (0, 0)))            # (H+2, W+2, Cin)

    out = pl.pallas_call(
        lambda x_ref, w_ref, o_ref: _conv_body(
            x_ref, w_ref, o_ref, h=h, w_dim=wd),
        grid=(factor,),
        in_specs=[
            pl.BlockSpec((h + 2, wd + 2, cg), lambda i: (0, 0, i)),
            pl.BlockSpec((3, 3, cg, cout), lambda i: (0, 0, i, 0)),
        ],
        out_specs=pl.BlockSpec((h, wd, cout), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, wd, cout), x.dtype),
        interpret=True,
    )(xp, w)

    out = out.reshape(1, h, wd, cout)
    if b is not None:
        out = out + b.reshape(1, 1, 1, cout)
    return out
