"""Pallas fused scaled-dot-product attention for the spatial-transformer
blocks of the denoising UNet.

The paper runs these layers through TFLite after converting their
FullyConnected projections to Conv2D; the attention itself is the compute
hot-spot.  On TPU we fuse QK^T -> softmax -> PV per head inside VMEM:

  grid = (heads,); each step stages that head's (Sq, D) query block and
  (Skv, D) key/value blocks into VMEM, runs both matmuls on the MXU and
  the softmax on the VPU, and writes (Sq, D) back.  For the shapes used
  here (Sq <= 1024, Skv <= 1024, D = 32) a head's working set is
  <= ~0.6 MiB — far under VMEM, so no inner K-tiling is needed.
"""

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_body(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[0]                       # (Sq, D)
    k = k_ref[0]                       # (Skv, D)
    v = v_ref[0]                       # (Skv, D)
    logits = jnp.dot(q, k.T) * scale   # MXU
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)           # MXU


def attention_kernel(q, k, v, scale=None):
    """q: (H, Sq, D); k, v: (H, Skv, D) -> (H, Sq, D)."""
    heads, sq, d = q.shape
    _, skv, _ = k.shape
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        lambda q_ref, k_ref, v_ref, o_ref: _attn_body(
            q_ref, k_ref, v_ref, o_ref, scale=scale),
        grid=(heads,),
        in_specs=[
            pl.BlockSpec((1, sq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, sq, d), q.dtype),
        interpret=True,
    )(q, k, v)
