"""DDIM sampler (Song et al. 2021) + distilled step schedules.

This is the *reference* implementation the Rust scheduler
(rust/src/scheduler/) is validated against: ``aot.py`` dumps the full
alphas_cumprod table and a golden 20-step trace into the manifest, and
Rust tests replay them bit-for-bit (f64 -> f32 at the boundary).

The paper reduces to "20 effective denoising steps" via progressive
distillation (Salimans & Ho 2022; Meng et al. 2023).  We do not train a
distilled student (out of scope of the deployment system — see DESIGN.md
substitutions); the schedule machinery below supports both the plain
DDIM stride schedule and the halved progressive schedules the distilled
checkpoints would consume, which is the part the serving system touches.
"""

import math
from typing import List

import numpy as np

from .config import SchedulerConfig


def betas(cfg: SchedulerConfig) -> np.ndarray:
    """Scaled-linear beta schedule (the SD default)."""
    return (
        np.linspace(math.sqrt(cfg.beta_start), math.sqrt(cfg.beta_end),
                    cfg.num_train_timesteps, dtype=np.float64) ** 2
    )


def alphas_cumprod(cfg: SchedulerConfig) -> np.ndarray:
    return np.cumprod(1.0 - betas(cfg))


def timesteps(cfg: SchedulerConfig, num_steps: int = None) -> List[int]:
    """DDIM schedule: exactly ``num_steps`` evenly spaced timesteps,
    descending, ending at 0 (linspace form; the stride form returned
    more than ``num_steps`` entries for non-divisible counts).  Must
    stay bit-identical to ``Ddim::timesteps`` on the Rust side."""
    n = num_steps or cfg.num_inference_steps
    n = max(1, min(n, cfg.num_train_timesteps))
    return [i * cfg.num_train_timesteps // n for i in range(n)][::-1]


def progressive_timesteps(cfg: SchedulerConfig, halvings: int) -> List[int]:
    """Progressive-distillation schedule: each halving doubles the stride
    a distilled student takes (Salimans & Ho 2022)."""
    n = cfg.num_inference_steps >> halvings
    if n < 1:
        raise ValueError("too many halvings")
    return timesteps(cfg, num_steps=n)


def ddim_step(latent: np.ndarray, eps: np.ndarray, t: int, t_prev: int,
              acp: np.ndarray) -> np.ndarray:
    """One deterministic (eta = 0) DDIM update."""
    a_t = acp[t]
    a_prev = acp[t_prev] if t_prev >= 0 else 1.0
    x0 = (latent - math.sqrt(1.0 - a_t) * eps) / math.sqrt(a_t)
    return math.sqrt(a_prev) * x0 + math.sqrt(1.0 - a_prev) * eps


def guide(eps_uncond: np.ndarray, eps_cond: np.ndarray, scale: float) -> np.ndarray:
    """Classifier-free guidance (Ho & Salimans 2022)."""
    return eps_uncond + scale * (eps_cond - eps_uncond)


def sample(unet_call, latent: np.ndarray, context2: np.ndarray,
           cfg: SchedulerConfig, num_steps: int = None) -> np.ndarray:
    """Full deterministic DDIM loop.

    ``unet_call(latent2, t) -> eps2`` runs the CFG-batched UNet where
    ``latent2`` duplicates the latent and ``context2`` stacks the uncond
    and cond embeddings.  Mirrors the Rust denoise loop exactly.
    """
    acp = alphas_cumprod(cfg)
    ts = timesteps(cfg, num_steps)
    for i, t in enumerate(ts):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        latent2 = np.concatenate([latent, latent], axis=0)
        eps2 = unet_call(latent2, t)
        eps = guide(eps2[0:1], eps2[1:2], cfg.guidance_scale)
        latent = ddim_step(latent, eps, t, t_prev, acp)
    return latent
