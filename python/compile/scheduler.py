"""Samplers (DDIM + DPM-Solver++ multistep) + distilled step schedules.

This is the *reference* implementation the Rust scheduler
(rust/src/scheduler/) is validated against: ``aot.py`` dumps the full
alphas_cumprod table, a golden 20-step DDIM trace and a golden 8-step
multistep trace into the manifest, and Rust tests replay them
bit-for-bit (f64 -> f32 at the boundary).

The paper reduces to "20 effective denoising steps" via progressive
distillation (Salimans & Ho 2022; Meng et al. 2023).  We do not train a
distilled student (out of scope of the deployment system — see DESIGN.md
substitutions); the schedule machinery below supports the plain DDIM
stride schedule, the halved progressive schedules the distilled
checkpoints would consume, and a second-order multistep solver
(DPM-Solver++(2M) style, Lu et al. 2022) in eps form — the parts the
serving system touches.
"""

import dataclasses
import math
from typing import List

import numpy as np

from .config import SchedulerConfig

# teacher schedule length of the distilled family: both distilled
# members (8-step, 4-step) are exact halving levels of one 32-step
# teacher.  Must match DISTILL_BASE_STEPS on the Rust side.
DISTILL_BASE_STEPS = 32


def betas(cfg: SchedulerConfig) -> np.ndarray:
    """Scaled-linear beta schedule (the SD default)."""
    return (
        np.linspace(math.sqrt(cfg.beta_start), math.sqrt(cfg.beta_end),
                    cfg.num_train_timesteps, dtype=np.float64) ** 2
    )


def alphas_cumprod(cfg: SchedulerConfig) -> np.ndarray:
    return np.cumprod(1.0 - betas(cfg))


def timesteps(cfg: SchedulerConfig, num_steps: int = None) -> List[int]:
    """DDIM schedule: exactly ``num_steps`` evenly spaced timesteps,
    descending, ending at 0 (linspace form; the stride form returned
    more than ``num_steps`` entries for non-divisible counts).  Must
    stay bit-identical to ``Ddim::timesteps`` on the Rust side."""
    n = num_steps or cfg.num_inference_steps
    n = max(1, min(n, cfg.num_train_timesteps))
    return [i * cfg.num_train_timesteps // n for i in range(n)][::-1]


def progressive_timesteps(cfg: SchedulerConfig, halvings: int) -> List[int]:
    """Progressive-distillation schedule: each halving doubles the stride
    a distilled student takes (Salimans & Ho 2022)."""
    n = cfg.num_inference_steps >> halvings
    if n < 1:
        raise ValueError("too many halvings")
    return timesteps(cfg, num_steps=n)


def distilled_timesteps(cfg: SchedulerConfig, halvings: int) -> List[int]:
    """Schedule of a distilled student: ``halvings`` halving levels of
    the fixed :data:`DISTILL_BASE_STEPS`-step teacher, regardless of the
    configured inference count (the serving side's distilled8 is 2
    halvings, distilled4 is 3).  Mirrors
    ``Ddim::progressive_timesteps_from`` on the Rust side."""
    teacher = dataclasses.replace(cfg, num_inference_steps=DISTILL_BASE_STEPS)
    return progressive_timesteps(teacher, halvings)


def ddim_step(latent: np.ndarray, eps: np.ndarray, t: int, t_prev: int,
              acp: np.ndarray) -> np.ndarray:
    """One deterministic (eta = 0) DDIM update."""
    a_t = acp[t]
    a_prev = acp[t_prev] if t_prev >= 0 else 1.0
    x0 = (latent - math.sqrt(1.0 - a_t) * eps) / math.sqrt(a_t)
    return math.sqrt(a_prev) * x0 + math.sqrt(1.0 - a_prev) * eps


def dpm2m_step(latent: np.ndarray, eps: np.ndarray, eps_prev, t: int,
               t_prev: int, t_last: int, acp: np.ndarray) -> np.ndarray:
    """One DPM-Solver++(2M)-style second-order multistep update, eps
    form.  ``eps_prev`` is the previous step's guided eps prediction
    (``None`` at the schedule head) made at timestep ``t_last``; the
    noise estimate is extrapolated linearly in log-SNR across the last
    two schedule points and applied with the first-order transfer — so
    the history-less path is exactly :func:`ddim_step`, as is the final
    step to t=0 (``t_prev < 0``), whose log-SNR step is unbounded.
    Must stay bit-identical to ``Dpm2mSolver::step`` on the Rust side.
    """
    if eps_prev is None or t_prev < 0 or t_last < 0:
        return ddim_step(latent, eps, t, t_prev, acp)
    a_t = acp[t]
    a_prev = acp[t_prev]
    a_last = acp[t_last]

    def lam(a):
        return math.log(math.sqrt(a) / math.sqrt(1.0 - a))

    h = lam(a_prev) - lam(a_t)
    h_last = lam(a_t) - lam(a_last)
    r = h_last / h
    c = 1.0 / (2.0 * r)
    d = (1.0 + c) * eps - c * eps_prev
    x0 = (latent - math.sqrt(1.0 - a_t) * d) / math.sqrt(a_t)
    return math.sqrt(a_prev) * x0 + math.sqrt(1.0 - a_prev) * d


def guide(eps_uncond: np.ndarray, eps_cond: np.ndarray, scale: float) -> np.ndarray:
    """Classifier-free guidance (Ho & Salimans 2022)."""
    return eps_uncond + scale * (eps_cond - eps_uncond)


def sample(unet_call, latent: np.ndarray, context2: np.ndarray,
           cfg: SchedulerConfig, num_steps: int = None) -> np.ndarray:
    """Full deterministic DDIM loop.

    ``unet_call(latent2, t) -> eps2`` runs the CFG-batched UNet where
    ``latent2`` duplicates the latent and ``context2`` stacks the uncond
    and cond embeddings.  Mirrors the Rust denoise loop exactly.
    """
    acp = alphas_cumprod(cfg)
    ts = timesteps(cfg, num_steps)
    for i, t in enumerate(ts):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        latent2 = np.concatenate([latent, latent], axis=0)
        eps2 = unet_call(latent2, t)
        eps = guide(eps2[0:1], eps2[1:2], cfg.guidance_scale)
        latent = ddim_step(latent, eps, t, t_prev, acp)
    return latent


def sample_multistep(unet_call, latent: np.ndarray, context2: np.ndarray,
                     cfg: SchedulerConfig, num_steps: int = None) -> np.ndarray:
    """Full deterministic DPM-Solver++(2M) loop: :func:`sample` with the
    second-order update and a one-deep eps history.  Mirrors the Rust
    multistep denoise loop exactly (first step and final step run first
    order)."""
    acp = alphas_cumprod(cfg)
    ts = timesteps(cfg, num_steps)
    eps_prev, t_last = None, -1
    for i, t in enumerate(ts):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        latent2 = np.concatenate([latent, latent], axis=0)
        eps2 = unet_call(latent2, t)
        eps = guide(eps2[0:1], eps2[1:2], cfg.guidance_scale)
        latent = dpm2m_step(latent, eps, eps_prev, t, t_prev, t_last, acp)
        eps_prev, t_last = eps, t
    return latent
