"""Layer-2 assembly: the three SD components as flat-parameter functions.

Every builder returns ``(fn, flat_paths, flat_arrays, act_specs)`` where
``fn(param_leaves_list, *activations)`` is the jittable function whose HLO
parameter order is exactly ``flat_paths`` followed by the activations —
the contract the Rust runtime relies on (see params.py).
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, DEFAULT
from .params import Init, flatten, unflatten
from .modules import text_encoder, unet, vae, transformer2d, layers

# CFG batch: uncond + cond halves evaluated in one UNet call
CFG_BATCH = 2

# Distinct, stable seeds per component so artifacts are independent of
# build order.
SEED_TEXT, SEED_UNET, SEED_DECODER, SEED_BLOCK = 101, 202, 303, 404


def _split(params):
    flat = flatten(params)
    paths = [p for p, _ in flat]
    arrays = [a for _, a in flat]
    return paths, arrays


def build_text_encoder(cfg: ModelConfig = DEFAULT, variant: str = "mobile"):
    p = text_encoder.init(Init(cfg.seed + SEED_TEXT), cfg.text)
    paths, arrays = _split(p)

    def fn(leaves: List, tokens):
        pp = unflatten(paths, leaves)
        return text_encoder.apply(pp, tokens, cfg.text, variant)

    act_specs = [jax.ShapeDtypeStruct((1, cfg.text.seq_len), jnp.int32)]
    return fn, paths, arrays, act_specs


def build_unet(cfg: ModelConfig = DEFAULT, variant: str = "mobile"):
    p = unet.init(Init(cfg.seed + SEED_UNET), cfg.unet)
    paths, arrays = _split(p)
    s = cfg.unet.latent_size

    def fn(leaves: List, latent, timestep, context):
        pp = unflatten(paths, leaves)
        return unet.apply(pp, latent, timestep, context, cfg.unet, variant)

    act_specs = [
        jax.ShapeDtypeStruct((CFG_BATCH, s, s, cfg.unet.in_channels), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
        jax.ShapeDtypeStruct((CFG_BATCH, cfg.text.seq_len, cfg.unet.context_dim),
                             jnp.float32),
    ]
    return fn, paths, arrays, act_specs


def build_decoder(cfg: ModelConfig = DEFAULT, variant: str = "mobile"):
    p = vae.init(Init(cfg.seed + SEED_DECODER), cfg.decoder)
    paths, arrays = _split(p)
    s = cfg.unet.latent_size

    def fn(leaves: List, latent):
        pp = unflatten(paths, leaves)
        return vae.apply(pp, latent, cfg.decoder, variant)

    act_specs = [
        jax.ShapeDtypeStruct((1, s, s, cfg.decoder.latent_channels), jnp.float32)
    ]
    return fn, paths, arrays, act_specs


def build_block(cfg: ModelConfig = DEFAULT, variant: str = "mobile"):
    """One spatial-transformer block in isolation — the unit of the
    paper's block-wise reconstruction-error metric (Sec. 3.4, Fig. 5)."""
    c = cfg.unet.base_channels * cfg.unet.channel_mults[-1]
    size = cfg.unet.latent_size // 2      # resolution at the attn level
    p = transformer2d.init(Init(cfg.seed + SEED_BLOCK), c, cfg.unet.n_heads,
                           cfg.unet.context_dim, cfg.unet.ffn_mult)
    paths, arrays = _split(p)

    def fn(leaves: List, x, context):
        pp = unflatten(paths, leaves)
        return transformer2d.apply(pp, x, context, cfg.unet.groups,
                                   cfg.unet.n_heads, variant,
                                   gelu_clip=cfg.unet.gelu_clip)

    act_specs = [
        jax.ShapeDtypeStruct((1, size, size, c), jnp.float32),
        jax.ShapeDtypeStruct((1, cfg.text.seq_len, cfg.unet.context_dim),
                             jnp.float32),
    ]
    return fn, paths, arrays, act_specs


def build_block_w8(cfg: ModelConfig = DEFAULT, variant: str = "mobile",
                   prune_frac: float = 0.0):
    """The same spatial-transformer block with its FFN weights stored as
    int8 + per-channel scale *inputs*, executed through the W8A16 Pallas
    kernel — the paper's on-device compute path for quantized weights."""
    from . import quantize

    c = cfg.unet.base_channels * cfg.unet.channel_mults[-1]
    size = cfg.unet.latent_size // 2
    p = transformer2d.init(Init(cfg.seed + SEED_BLOCK), c, cfg.unet.n_heads,
                           cfg.unet.context_dim, cfg.unet.ffn_mult)
    for key in ("ff1", "ff2"):
        w = p[key].pop("w")
        if prune_frac > 0:
            w, _keep = quantize.prune_structured(w, prune_frac)
        q, scale = quantize.quantize_per_channel(np.asarray(w))
        p[key]["q"] = q
        p[key]["scale"] = scale
    paths, arrays = _split(p)

    def fn(leaves: List, x, context):
        pp = unflatten(paths, leaves)
        return transformer2d.apply(pp, x, context, cfg.unet.groups,
                                   cfg.unet.n_heads, variant,
                                   gelu_clip=cfg.unet.gelu_clip)

    act_specs = [
        jax.ShapeDtypeStruct((1, size, size, c), jnp.float32),
        jax.ShapeDtypeStruct((1, cfg.text.seq_len, cfg.unet.context_dim),
                             jnp.float32),
    ]
    return fn, paths, arrays, act_specs


COMPONENTS = {
    "text_encoder": build_text_encoder,
    "unet": build_unet,
    "decoder": build_decoder,
    "block": build_block,
    "block_w8": build_block_w8,
}


def run_component(name: str, acts: List[np.ndarray],
                  cfg: ModelConfig = DEFAULT, variant: str = "mobile",
                  arrays_override=None):
    """Eager helper for tests: run a component on concrete inputs."""
    fn, _paths, arrays, _specs = COMPONENTS[name](cfg, variant)
    if arrays_override is not None:
        arrays = arrays_override
    leaves = [jnp.asarray(a) for a in arrays]
    return np.asarray(fn(leaves, *[jnp.asarray(a) for a in acts]))
