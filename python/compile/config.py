"""Model configuration for the Mobile Stable Diffusion reproduction.

The architecture mirrors Stable Diffusion v2.1 (CLIP text encoder -> UNet
denoiser with spatial-transformer blocks -> VAE decoder) at laptop scale.
Shape *ratios* of the layers the paper identifies as problematic are kept:

  * the post-skip-concat 3x3 conv at the highest resolution has a 3:1
    input:output channel ratio (paper: 1920 -> 640 at 32x32);
  * spatial-transformer FFN fully-connected layers operate on flattened
    (H*W, C) activations (paper: 1x4096x320).
"""

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class TextEncoderConfig:
    vocab_size: int = 4096
    seq_len: int = 16
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512


@dataclass(frozen=True)
class UNetConfig:
    in_channels: int = 4
    out_channels: int = 4
    latent_size: int = 32           # latent spatial resolution (square)
    base_channels: int = 64
    channel_mults: Tuple[int, ...] = (1, 2)
    # spatial-transformer blocks at these level indices (0 = highest res)
    attn_levels: Tuple[int, ...] = (1,)
    n_res_blocks: int = 2
    n_heads: int = 4
    d_time: int = 256
    context_dim: int = 128
    groups: int = 8
    ffn_mult: int = 4
    # GELU clip constant of the numerically stable approximation (paper M=10)
    gelu_clip: float = 10.0


@dataclass(frozen=True)
class DecoderConfig:
    latent_channels: int = 4
    base_channels: int = 64
    # each upsample doubles resolution: 32 -> 256
    n_upsamples: int = 3
    out_channels: int = 3
    groups: int = 8


@dataclass(frozen=True)
class SchedulerConfig:
    """DDPM beta schedule (scaled-linear, as in Stable Diffusion)."""
    num_train_timesteps: int = 1000
    beta_start: float = 0.00085
    beta_end: float = 0.012
    # effective inference steps after distillation (paper: 20)
    num_inference_steps: int = 20
    guidance_scale: float = 7.5


@dataclass(frozen=True)
class ModelConfig:
    text: TextEncoderConfig = field(default_factory=TextEncoderConfig)
    unet: UNetConfig = field(default_factory=UNetConfig)
    decoder: DecoderConfig = field(default_factory=DecoderConfig)
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    seed: int = 0

    @property
    def image_size(self) -> int:
        return self.unet.latent_size * (2 ** self.decoder.n_upsamples)


DEFAULT = ModelConfig()
