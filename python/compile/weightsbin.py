"""MDWB — the Mobile-Diffusion Weights Binary format.

A purpose-built container shared by the Python build path (writer) and
the Rust coordinator (reader, rust/src/quant/weights.rs).  It exists so
the Rust side can own weight *storage* the way the paper's app does:
full-precision f32, or int8 W8A16 payloads (4x smaller) that are cast up
at load, or int8+structured-pruning payloads where dropped output
channels are not stored at all.

Layout (little-endian):

  magic   4 B  = b"MDWB"
  version u32  = 1
  count   u32  = number of tensors
  per tensor:
    path_len u16, path (utf-8)
    dtype    u8   (0 = f32, 1 = int8-quantized)
    ndim     u8
    dims     u32 * ndim          (logical, unpruned shape)
    if dtype == 1:
      scale  f32 * dims[-1]      (per-output-channel)
      mask   u8  * dims[-1]      (1 = kept channel; all-1 if unpruned)
      payload int8 * (prod(dims[:-1]) * kept)
    else:
      payload f32 * prod(dims)
"""

import struct
from typing import Dict, List, Optional

import numpy as np

MAGIC = b"MDWB"
VERSION = 1
DT_F32 = 0
DT_I8 = 1


def write(path: str, entries: List[dict]) -> int:
    """entries: [{"path": str, "arr": f32 ndarray} |
                 {"path": str, "q": int8 ndarray, "scale": f32 ndarray,
                  "keep": Optional[bool ndarray]}].
    Returns total bytes written."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(entries)))
        for e in entries:
            name = e["path"].encode("utf-8")
            f.write(struct.pack("<H", len(name)))
            f.write(name)
            if "q" in e:
                q: np.ndarray = e["q"]
                scale: np.ndarray = np.asarray(e["scale"], dtype=np.float32)
                keep = e.get("keep")
                if keep is None:
                    keep = np.ones(q.shape[-1], dtype=bool)
                f.write(struct.pack("<BB", DT_I8, q.ndim))
                f.write(struct.pack(f"<{q.ndim}I", *q.shape))
                f.write(scale.tobytes())
                f.write(keep.astype(np.uint8).tobytes())
                kept = q.reshape(-1, q.shape[-1])[:, keep]
                f.write(np.ascontiguousarray(kept, dtype=np.int8).tobytes())
            else:
                arr = np.asarray(e["arr"], dtype=np.float32)
                f.write(struct.pack("<BB", DT_F32, arr.ndim))
                f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
                f.write(np.ascontiguousarray(arr).tobytes())
        return f.tell()


def read(path: str) -> Dict[str, np.ndarray]:
    """Reference reader (used by Python tests to verify round-trip and by
    the Rust implementation as the behavioural oracle).  Dequantizes and
    re-inflates pruned channels to zeros, returning f32 arrays."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (plen,) = struct.unpack("<H", f.read(2))
            name = f.read(plen).decode("utf-8")
            dtype, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            if dtype == DT_I8:
                cout = dims[-1]
                scale = np.frombuffer(f.read(4 * cout), dtype=np.float32)
                keep = np.frombuffer(f.read(cout), dtype=np.uint8).astype(bool)
                rows = int(np.prod(dims[:-1]))
                kept = int(keep.sum())
                payload = np.frombuffer(f.read(rows * kept), dtype=np.int8)
                full = np.zeros((rows, cout), dtype=np.float32)
                full[:, keep] = payload.reshape(rows, kept).astype(np.float32)
                full *= scale[None, :]
                out[name] = full.reshape(dims)
            else:
                n = int(np.prod(dims))
                out[name] = np.frombuffer(
                    f.read(4 * n), dtype=np.float32).reshape(dims).copy()
    return out
