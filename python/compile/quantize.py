"""Weight compression (paper Sec. 3.4): per-channel symmetric int8
quantization and structured output-channel pruning.

The Python side quantizes at artifact-build time and writes the int8
payload + scales; the Rust coordinator stores the 8-bit weights in its
memory ledger (4x smaller) and casts them up at load — the W8A16
deployment scheme (mobile GPUs have no integer matmul).
"""

from typing import Dict, List, Tuple

import numpy as np


def quantizable(path: str, arr: np.ndarray) -> bool:
    """Weights of convs and linears are quantized; biases and norm
    parameters stay float (standard practice, also what the paper's
    block-wise-error tuning implies)."""
    return path.endswith("/w") and arr.ndim >= 2


def quantize_per_channel(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 over the last (output-channel) axis.
    Returns (int8 weights, float32 per-channel scale)."""
    flat = w.reshape(-1, w.shape[-1])
    amax = np.abs(flat).max(axis=0)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


def prune_structured(w: np.ndarray, frac: float) -> Tuple[np.ndarray, np.ndarray]:
    """Zero the lowest-L2 output channels (structured pruning on the
    'huge convolution layers', paper Sec. 3.4).  Returns (pruned weights,
    bool keep-mask over output channels)."""
    flat = w.reshape(-1, w.shape[-1])
    norms = np.sqrt(np.square(flat).sum(axis=0))
    n_prune = int(round(frac * w.shape[-1]))
    keep = np.ones(w.shape[-1], dtype=bool)
    if n_prune > 0:
        drop = np.argsort(norms)[:n_prune]
        keep[drop] = False
    return w * keep.astype(w.dtype), keep


def prune_targets(paths: List[str], arrays: List[np.ndarray],
                  min_elems: int = 100_000) -> List[str]:
    """The paper prunes only the 'huge convolution layers': select conv
    kernels above a size threshold."""
    out = []
    for p, a in zip(paths, arrays):
        if p.endswith("/w") and a.ndim == 4 and a.size >= min_elems:
            out.append(p)
    return out


def compress(paths: List[str], arrays: List[np.ndarray],
             prune_frac: float = 0.0) -> Dict[str, dict]:
    """Quantize (and optionally prune) a flat parameter list.

    Returns ``{path: {"q": int8, "scale": f32, "keep": bool mask | None}}``
    for quantized entries; unquantized entries are omitted (stored f32).
    """
    targets = set(prune_targets(paths, arrays)) if prune_frac > 0 else set()
    out: Dict[str, dict] = {}
    for p, a in zip(paths, arrays):
        if not quantizable(p, a):
            continue
        w = a
        keep = None
        if p in targets:
            w, keep = prune_structured(w, prune_frac)
        q, scale = quantize_per_channel(w)
        out[p] = {"q": q, "scale": scale, "keep": keep}
    return out


def reconstruction_error(y_ref: np.ndarray, y_cmp: np.ndarray) -> float:
    """Block-wise reconstruction error (Li et al. 2021 / Wei et al. 2022):
    mean squared error of the block output vs the full-precision block."""
    return float(np.mean(np.square(y_ref.astype(np.float64) -
                                   y_cmp.astype(np.float64))))
