"""Deterministic hash-vocabulary tokenizer (CLIP stand-in).

The paper's pipeline feeds a CLIP text encoder.  We have no CLIP vocabulary,
so both the Python build path and the Rust request path share this trivial,
fully deterministic tokenizer: lowercase, split on non-alphanumerics, map
each word to ``2 + FNV1a64(word) % (vocab - 2)``.  Token 0 is PAD, token 1
is BOS.  The Rust implementation (rust/src/tokenizer/) must match exactly;
``aot.py`` emits a golden file the Rust tests verify against.
"""

from typing import List

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1

PAD_ID = 0
BOS_ID = 1


def fnv1a64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & MASK64
    return h


def words(text: str) -> List[str]:
    out: List[str] = []
    cur: List[str] = []
    for ch in text.lower():
        if ch.isalnum():
            cur.append(ch)
        elif cur:
            out.append("".join(cur))
            cur = []
    if cur:
        out.append("".join(cur))
    return out


def encode(text: str, vocab_size: int, seq_len: int) -> List[int]:
    """BOS + word ids, truncated / padded with PAD to ``seq_len``."""
    ids = [BOS_ID]
    for w in words(text):
        if len(ids) >= seq_len:
            break
        ids.append(2 + fnv1a64(w.encode("utf-8")) % (vocab_size - 2))
    while len(ids) < seq_len:
        ids.append(PAD_ID)
    return ids[:seq_len]
