"""Parameter initialization and deterministic flattening.

Weights are AOT-time *inputs* of every lowered HLO module, not baked
constants.  This keeps the HLO text small and — crucially for the paper's
Sec. 3.4 — lets the Rust coordinator own weight storage: it memory-maps
``weights_*.bin``, optionally dequantizes int8 (W8A16) or reconstitutes
pruned channels, and feeds the result as PJRT literals.

The contract with Rust: parameters are flattened in sorted-path order and
appear as HLO parameters 0..P-1, followed by the activation inputs.  The
manifest (``artifacts/manifest.json``) records the path, shape, dtype and
byte offset of every parameter.
"""

from typing import Dict, List, Tuple

import numpy as np


Params = Dict[str, object]  # nested str -> ndarray | Params


def flatten(params: Params, prefix: str = "") -> List[Tuple[str, np.ndarray]]:
    """Flatten a nested param dict to sorted (path, array) pairs."""
    out: List[Tuple[str, np.ndarray]] = []
    for key in sorted(params.keys()):
        val = params[key]
        path = f"{prefix}{key}"
        if isinstance(val, dict):
            out.extend(flatten(val, prefix=path + "/"))
        else:
            out.append((path, np.asarray(val)))
    return out


def unflatten(paths: List[str], leaves: List[object]) -> Params:
    """Inverse of :func:`flatten` given the path list."""
    root: Params = {}
    for path, leaf in zip(paths, leaves):
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})  # type: ignore[assignment]
        node[parts[-1]] = leaf
    return root


class Init:
    """Seeded parameter factory (numpy Generator; fully deterministic)."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)

    def linear(self, d_in: int, d_out: int) -> Params:
        std = 1.0 / np.sqrt(d_in)
        return {
            "w": self.rng.normal(0.0, std, size=(d_in, d_out)).astype(np.float32),
            "b": np.zeros(d_out, dtype=np.float32),
        }

    def conv(self, kh: int, kw: int, cin: int, cout: int) -> Params:
        std = 1.0 / np.sqrt(kh * kw * cin)
        return {
            "w": self.rng.normal(0.0, std, size=(kh, kw, cin, cout)).astype(np.float32),
            "b": np.zeros(cout, dtype=np.float32),
        }

    def norm(self, c: int) -> Params:
        return {
            "gamma": np.ones(c, dtype=np.float32),
            "beta": np.zeros(c, dtype=np.float32),
        }

    def embedding(self, n: int, d: int) -> Params:
        return {"table": self.rng.normal(0.0, 0.02, size=(n, d)).astype(np.float32)}
