"""TFLite-level computation-graph specs for the Rust delegate simulator.

The paper's Sec. 3.1 problems live at the *TFLite op* level (which ops the
GPU delegate accepts), below the HLO we lower for execution.  This module
emits that op-level graph as JSON for two scales:

  * ``small``  — the model we actually execute (config.DEFAULT shapes);
  * ``sd_v21`` — Stable Diffusion v2.1 at full scale (latent 64x64x4,
    base 320, mults 1/2/4/4, attention at the three highest resolutions,
    context 1024/seq 77).  At this scale the paper's exact failures
    appear: the 1x4096x320 FULLY_CONNECTED of the level-0 spatial
    transformer and the 1920 -> 640 3x3 conv at 32x32 in the up path.

Graphs are emitted in the *export* form a stock TF->TFLite conversion
produces: FULLY_CONNECTED (not conv) in transformer blocks, group norm
decomposed with a rank-5 reshape + BROADCAST_TO, tanh-cubic GELU without
clamps, unserialized convs.  The Rust pass pipeline (rust/src/passes/)
rewrites them into the paper's mobile form.

JSON schema (consumed by rust/src/graph/):
  {"name": str,
   "activation_dtype": "f16",
   "tensors": [{"id", "name", "shape", "dtype", "const": bool}],
   "ops": [{"id", "type", "name", "inputs": [tid], "outputs": [tid],
            "attrs": {str: int|float|str}}]}
"""

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .config import ModelConfig, DEFAULT

F16 = "f16"
F32 = "f32"
I8 = "i8"
I32 = "i32"


@dataclass
class UNetSpec:
    latent_size: int
    in_channels: int
    base: int
    mults: Tuple[int, ...]
    attn_levels: Tuple[int, ...]
    n_res_blocks: int
    context_dim: int
    seq_len: int
    d_head: int
    groups: int
    ffn_mult: int = 4
    d_time: int = 0

    def __post_init__(self):
        if not self.d_time:
            self.d_time = 4 * self.base


@dataclass
class TextSpec:
    seq_len: int
    d_model: int
    n_layers: int
    d_ff: int
    vocab: int


@dataclass
class DecoderSpec:
    latent_size: int
    latent_channels: int
    channels: Tuple[int, ...]   # per upsample stage, highest first
    out_channels: int
    groups: int


def small_specs(cfg: ModelConfig = DEFAULT):
    u = cfg.unet
    return {
        "unet": UNetSpec(
            latent_size=u.latent_size, in_channels=u.in_channels,
            base=u.base_channels, mults=u.channel_mults,
            attn_levels=u.attn_levels, n_res_blocks=u.n_res_blocks,
            context_dim=u.context_dim, seq_len=cfg.text.seq_len,
            d_head=u.base_channels // u.n_heads * u.channel_mults[0],
            groups=u.groups, ffn_mult=u.ffn_mult, d_time=u.d_time,
        ),
        "text_encoder": TextSpec(
            seq_len=cfg.text.seq_len, d_model=cfg.text.d_model,
            n_layers=cfg.text.n_layers, d_ff=cfg.text.d_ff,
            vocab=cfg.text.vocab_size,
        ),
        "decoder": DecoderSpec(
            latent_size=u.latent_size,
            latent_channels=cfg.decoder.latent_channels,
            channels=(cfg.decoder.base_channels,) * cfg.decoder.n_upsamples,
            out_channels=cfg.decoder.out_channels, groups=cfg.decoder.groups,
        ),
    }


def sd_v21_specs():
    """Stable Diffusion v2.1 architecture (865M-param UNet shape-level)."""
    return {
        "unet": UNetSpec(
            latent_size=64, in_channels=4, base=320, mults=(1, 2, 4, 4),
            attn_levels=(0, 1, 2), n_res_blocks=2, context_dim=1024,
            seq_len=77, d_head=64, groups=32,
        ),
        # OpenCLIP ViT-H/14 text tower
        "text_encoder": TextSpec(
            seq_len=77, d_model=1024, n_layers=23, d_ff=4096, vocab=49408),
        # SD VAE decoder: 64 -> 512 through 512/512/256/128 stages
        "decoder": DecoderSpec(
            latent_size=64, latent_channels=4,
            channels=(512, 256, 128), out_channels=3, groups=32),
    }


class GraphBuilder:
    """Accumulates tensors and ops; mirrors a TFLite flatbuffer layout."""

    def __init__(self, name: str, activation_dtype: str = F16):
        self.name = name
        self.activation_dtype = activation_dtype
        self.tensors: List[dict] = []
        self.ops: List[dict] = []

    # -- tensors ---------------------------------------------------------
    def tensor(self, name: str, shape: List[int], dtype: Optional[str] = None,
               const: bool = False) -> int:
        tid = len(self.tensors)
        self.tensors.append({
            "id": tid, "name": name, "shape": list(shape),
            "dtype": dtype or self.activation_dtype, "const": const,
        })
        return tid

    def weight(self, name: str, shape: List[int], dtype: str = F32) -> int:
        return self.tensor(name, shape, dtype=dtype, const=True)

    def shape_of(self, tid: int) -> List[int]:
        return self.tensors[tid]["shape"]

    # -- ops -------------------------------------------------------------
    def op(self, op_type: str, name: str, inputs: List[int],
           out_shape: List[int], attrs: Optional[Dict] = None,
           out_dtype: Optional[str] = None) -> int:
        out = self.tensor(f"{name}:out", out_shape, dtype=out_dtype)
        self.ops.append({
            "id": len(self.ops), "type": op_type, "name": name,
            "inputs": list(inputs), "outputs": [out], "attrs": attrs or {},
        })
        return out

    # -- composite emitters ----------------------------------------------
    def conv2d(self, name: str, x: int, cin: int, cout: int, k: int = 3,
               stride: int = 1) -> int:
        n, h, w, c = self.shape_of(x)
        assert c == cin, (name, c, cin)
        wt = self.weight(f"{name}/w", [k, k, cin, cout])
        bt = self.weight(f"{name}/b", [cout])
        oh, ow = h // stride, w // stride
        return self.op("CONV_2D", name, [x, wt, bt], [n, oh, ow, cout],
                       attrs={"kernel": k, "stride": stride})

    def fully_connected(self, name: str, x: int, d_in: int, d_out: int) -> int:
        shape = self.shape_of(x)
        assert shape[-1] == d_in, (name, shape, d_in)
        wt = self.weight(f"{name}/w", [d_in, d_out])
        bt = self.weight(f"{name}/b", [d_out])
        return self.op("FULLY_CONNECTED", name, [x, wt, bt],
                       shape[:-1] + [d_out])

    def binary(self, op_type: str, name: str, a: int, b: int) -> int:
        sa, sb = self.shape_of(a), self.shape_of(b)
        out = sa if len(sa) >= len(sb) else sb
        return self.op(op_type, name, [a, b], out)

    def reshape(self, name: str, x: int, shape: List[int]) -> int:
        return self.op("RESHAPE", name, [x], shape)

    def silu(self, name: str, x: int) -> int:
        s = self.op("LOGISTIC", f"{name}/sigmoid", [x], self.shape_of(x))
        return self.binary("MUL", f"{name}/mul", x, s)

    def gelu(self, name: str, x: int, stable: bool = False) -> int:
        """Decomposed tanh GELU (paper Fig. 8 when ``stable``)."""
        sh = self.shape_of(x)
        g = x
        if stable:
            g = self.op("MINIMUM", f"{name}/min", [g], sh)
            g = self.op("MAXIMUM", f"{name}/max", [g], sh)
        c1 = self.op("MUL", f"{name}/sq", [g, g], sh)
        c2 = self.op("MUL", f"{name}/cube", [c1, g], sh)
        c3 = self.op("MUL", f"{name}/scale_cube", [c2], sh)
        s = self.op("ADD", f"{name}/add_cube", [g, c3], sh)
        s = self.op("MUL", f"{name}/scale", [s], sh)
        t = self.op("TANH", f"{name}/tanh", [s], sh)
        t = self.op("ADD", f"{name}/one_plus", [t], sh)
        hx = self.op("MUL", f"{name}/half_x", [x], sh)
        return self.binary("MUL", f"{name}/out", hx, t)

    def group_norm(self, name: str, x: int, groups: int,
                   bcast_free: bool = False) -> int:
        """TFLite group-norm subgraph.

        Export form (paper Fig. 7 left): rank-5 reshape, MEAN,
        SQUARED_DIFFERENCE, explicit BROADCAST_TO of mean/var.
        Broadcast-free form (Fig. 7 right): rank-4 tensors, no broadcast.
        """
        n, h, w, c = self.shape_of(x)
        cg = c // groups
        gamma = self.weight(f"{name}/gamma", [c])
        beta = self.weight(f"{name}/beta", [c])
        if not bcast_free:
            x5 = self.reshape(f"{name}/reshape5", x, [n, h, w, groups, cg])
            mean = self.op("MEAN", f"{name}/mean", [x5], [n, 1, 1, groups, 1])
            mean_b = self.op("BROADCAST_TO", f"{name}/mean_bcast", [mean],
                             [n, h, w, groups, cg])
            sqd = self.op("SQUARED_DIFFERENCE", f"{name}/sqdiff",
                          [x5, mean_b], [n, h, w, groups, cg])
            var = self.op("MEAN", f"{name}/var", [sqd], [n, 1, 1, groups, 1])
            var_eps = self.op("ADD", f"{name}/var_eps", [var],
                              [n, 1, 1, groups, 1])
            rstd = self.op("RSQRT", f"{name}/rsqrt", [var_eps],
                           [n, 1, 1, groups, 1])
            rstd_b = self.op("BROADCAST_TO", f"{name}/rstd_bcast", [rstd],
                             [n, h, w, groups, cg])
            diff = self.op("SUB", f"{name}/center", [x5, mean_b],
                           [n, h, w, groups, cg])
            norm5 = self.op("MUL", f"{name}/normalize", [diff, rstd_b],
                            [n, h, w, groups, cg])
            norm = self.reshape(f"{name}/reshape4", norm5, [n, h, w, c])
        else:
            x4 = self.reshape(f"{name}/reshape4g", x, [n, h * w, groups, cg])
            mean = self.op("MEAN", f"{name}/mean", [x4], [n, 1, groups, 1])
            sqd = self.op("SQUARED_DIFFERENCE", f"{name}/sqdiff",
                          [x4, mean], [n, h * w, groups, cg])
            var = self.op("MEAN", f"{name}/var", [sqd], [n, 1, groups, 1])
            var_eps = self.op("ADD", f"{name}/var_eps", [var],
                              [n, 1, groups, 1])
            rstd = self.op("RSQRT", f"{name}/rsqrt", [var_eps],
                           [n, 1, groups, 1])
            diff = self.op("SUB", f"{name}/center", [x4, mean],
                           [n, h * w, groups, cg])
            norm4 = self.op("MUL", f"{name}/normalize", [diff, rstd],
                            [n, h * w, groups, cg])
            norm = self.reshape(f"{name}/reshape4", norm4, [n, h, w, c])
        scaled = self.op("MUL", f"{name}/gamma_mul", [norm, gamma],
                         [n, h, w, c])
        return self.op("ADD", f"{name}/beta_add", [scaled, beta],
                       [n, h, w, c])

    def layer_norm(self, name: str, x: int) -> int:
        sh = self.shape_of(x)
        red = sh[:-1] + [1]
        gamma = self.weight(f"{name}/gamma", [sh[-1]])
        beta = self.weight(f"{name}/beta", [sh[-1]])
        mean = self.op("MEAN", f"{name}/mean", [x], red)
        sqd = self.op("SQUARED_DIFFERENCE", f"{name}/sqdiff", [x, mean], sh)
        var = self.op("MEAN", f"{name}/var", [sqd], red)
        var_eps = self.op("ADD", f"{name}/var_eps", [var], red)
        rstd = self.op("RSQRT", f"{name}/rsqrt", [var_eps], red)
        diff = self.op("SUB", f"{name}/center", [x, mean], sh)
        norm = self.op("MUL", f"{name}/normalize", [diff, rstd], sh)
        scaled = self.op("MUL", f"{name}/gamma_mul", [norm, gamma], sh)
        return self.op("ADD", f"{name}/beta_add", [scaled, beta], sh)

    def attention(self, name: str, x: int, ctx: int, c: int, d_ctx: int,
                  n_heads: int) -> int:
        """Self- (ctx == x) or cross-attention over (B, S, C)."""
        b, s, _ = self.shape_of(x)
        _, s_kv, _ = self.shape_of(ctx)
        d = c // n_heads
        q = self.fully_connected(f"{name}/q", x, c, c)
        k = self.fully_connected(f"{name}/k", ctx, d_ctx, c)
        v = self.fully_connected(f"{name}/v", ctx, d_ctx, c)
        qh = self.reshape(f"{name}/q_heads", q, [b * n_heads, s, d])
        kh = self.reshape(f"{name}/k_heads", k, [b * n_heads, s_kv, d])
        vh = self.reshape(f"{name}/v_heads", v, [b * n_heads, s_kv, d])
        logits = self.op("BATCH_MATMUL", f"{name}/qk", [qh, kh],
                         [b * n_heads, s, s_kv], attrs={"adj_y": 1})
        probs = self.op("SOFTMAX", f"{name}/softmax", [logits],
                        [b * n_heads, s, s_kv])
        o = self.op("BATCH_MATMUL", f"{name}/pv", [probs, vh],
                    [b * n_heads, s, d])
        o = self.reshape(f"{name}/merge_heads", o, [b, s, c])
        return self.fully_connected(f"{name}/o", o, c, c)

    def transformer_block(self, name: str, x: int, context: int, c: int,
                          d_ctx: int, n_heads: int, groups: int,
                          ffn_mult: int, stable_gelu: bool = False,
                          bcast_free_gn: bool = False) -> int:
        n, h, w, _ = self.shape_of(x)
        y = self.group_norm(f"{name}/gn", x, groups, bcast_free=bcast_free_gn)
        y = self.conv2d(f"{name}/proj_in", y, c, c, k=1)
        t = self.reshape(f"{name}/flatten", y, [n, h * w, c])
        z = self.layer_norm(f"{name}/ln1", t)
        sa = self.attention(f"{name}/self_attn", z, z, c, c, n_heads)
        t = self.binary("ADD", f"{name}/res1", t, sa)
        z = self.layer_norm(f"{name}/ln2", t)
        ca = self.attention(f"{name}/cross_attn", z, context, c, d_ctx, n_heads)
        t = self.binary("ADD", f"{name}/res2", t, ca)
        z = self.layer_norm(f"{name}/ln3", t)
        z = self.fully_connected(f"{name}/ff1", z, c, ffn_mult * c)
        z = self.gelu(f"{name}/gelu", z, stable=stable_gelu)
        z = self.fully_connected(f"{name}/ff2", z, ffn_mult * c, c)
        t = self.binary("ADD", f"{name}/res3", t, z)
        y = self.reshape(f"{name}/unflatten", t, [n, h, w, c])
        y = self.conv2d(f"{name}/proj_out", y, c, c, k=1)
        return self.binary("ADD", f"{name}/res_out", x, y)

    def res_block(self, name: str, x: int, cin: int, cout: int,
                  groups: int, bcast_free_gn: bool = False) -> int:
        n, h, w, _ = self.shape_of(x)
        y = self.group_norm(f"{name}/gn1", x, groups, bcast_free=bcast_free_gn)
        y = self.silu(f"{name}/silu1", y)
        y = self.conv2d(f"{name}/conv1", y, cin, cout)
        # time injection: FC of the time embedding, added per-channel
        y = self.op("ADD", f"{name}/time_add", [y], [n, h, w, cout])
        y = self.group_norm(f"{name}/gn2", y, groups, bcast_free=bcast_free_gn)
        y = self.silu(f"{name}/silu2", y)
        y = self.conv2d(f"{name}/conv2", y, cout, cout)
        if cin != cout:
            x = self.conv2d(f"{name}/skip", x, cin, cout, k=1)
        return self.binary("ADD", f"{name}/res", x, y)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "activation_dtype": self.activation_dtype,
            "tensors": self.tensors,
            "ops": self.ops,
        }


def build_unet_graph(spec: UNetSpec, name: str = "unet") -> GraphBuilder:
    """The stock-export UNet graph (base variant; B = 1 per dispatch,
    as the mobile pipeline unrolls the CFG pair)."""
    g = GraphBuilder(name)
    s = spec.latent_size
    chans = [spec.base * m for m in spec.mults]
    latent = g.tensor("latent", [1, s, s, spec.in_channels])
    context = g.tensor("context", [1, spec.seq_len, spec.context_dim])
    g.tensor("timestep", [1])

    def heads(c):
        return max(1, c // spec.d_head)

    x = g.conv2d("conv_in", latent, spec.in_channels, chans[0])
    skips = [(x, chans[0])]
    ch = chans[0]
    res = s
    for lvl, lch in enumerate(chans):
        for i in range(spec.n_res_blocks):
            x = g.res_block(f"down_{lvl}_{i}/res", x, ch, lch, spec.groups)
            ch = lch
            if lvl in spec.attn_levels:
                x = g.transformer_block(
                    f"down_{lvl}_{i}/attn", x, context, ch,
                    spec.context_dim, heads(ch), spec.groups, spec.ffn_mult)
            skips.append((x, ch))
        if lvl != len(chans) - 1:
            x = g.conv2d(f"downsample_{lvl}", x, ch, ch, stride=2)
            res //= 2
            skips.append((x, ch))

    x = g.res_block("mid/res1", x, ch, ch, spec.groups)
    x = g.transformer_block("mid/attn", x, context, ch, spec.context_dim,
                            heads(ch), spec.groups, spec.ffn_mult)
    x = g.res_block("mid/res2", x, ch, ch, spec.groups)

    for lvl in reversed(range(len(chans))):
        lch = chans[lvl]
        for i in range(spec.n_res_blocks + 1):
            skip, sc = skips.pop()
            n, h, w, c = g.shape_of(x)
            x = g.op("CONCATENATION", f"up_{lvl}_{i}/concat", [x, skip],
                     [n, h, w, c + sc])
            x = g.res_block(f"up_{lvl}_{i}/res", x, c + sc, lch, spec.groups)
            ch = lch
            if lvl in spec.attn_levels:
                x = g.transformer_block(
                    f"up_{lvl}_{i}/attn", x, context, ch,
                    spec.context_dim, heads(ch), spec.groups, spec.ffn_mult)
        if lvl != 0:
            n, h, w, c = g.shape_of(x)
            x = g.op("RESIZE_NEAREST_NEIGHBOR", f"upsample_{lvl}/resize",
                     [x], [n, 2 * h, 2 * w, c])
            x = g.conv2d(f"upsample_{lvl}/conv", x, ch, ch)
    assert not skips

    x = g.group_norm("out_gn", x, spec.groups)
    x = g.silu("out_silu", x)
    g.conv2d("conv_out", x, chans[0], spec.in_channels)
    return g


def build_text_graph(spec: TextSpec, name: str = "text_encoder") -> GraphBuilder:
    g = GraphBuilder(name)
    tokens = g.tensor("tokens", [1, spec.seq_len], dtype=I32)
    table = g.weight("tok_emb/table", [spec.vocab, spec.d_model])
    x = g.op("GATHER", "tok_emb/gather", [table, tokens],
             [1, spec.seq_len, spec.d_model])
    pos = g.weight("pos_emb/table", [spec.seq_len, spec.d_model])
    x = g.op("ADD", "pos_add", [x, pos], [1, spec.seq_len, spec.d_model])
    for i in range(spec.n_layers):
        z = g.layer_norm(f"layer_{i}/ln1", x)
        a = g.attention(f"layer_{i}/attn", z, z, spec.d_model, spec.d_model,
                        max(1, spec.d_model // 64))
        x = g.binary("ADD", f"layer_{i}/res1", x, a)
        z = g.layer_norm(f"layer_{i}/ln2", x)
        z = g.fully_connected(f"layer_{i}/ff1", z, spec.d_model, spec.d_ff)
        z = g.gelu(f"layer_{i}/gelu", z)
        z = g.fully_connected(f"layer_{i}/ff2", z, spec.d_ff, spec.d_model)
        x = g.binary("ADD", f"layer_{i}/res2", x, z)
    g.layer_norm("final_ln", x)
    return g


def build_decoder_graph(spec: DecoderSpec, name: str = "decoder") -> GraphBuilder:
    g = GraphBuilder(name)
    s = spec.latent_size
    latent = g.tensor("latent", [1, s, s, spec.latent_channels])
    ch = spec.channels[0]
    x = g.conv2d("conv_in", latent, spec.latent_channels, ch)
    x = g.res_block("res_in", x, ch, ch, spec.groups)
    for i, cnext in enumerate(spec.channels):
        n, h, w, c = g.shape_of(x)
        x = g.op("RESIZE_NEAREST_NEIGHBOR", f"up_{i}/resize", [x],
                 [n, 2 * h, 2 * w, c])
        x = g.conv2d(f"up_{i}/conv", x, c, cnext)
        x = g.res_block(f"up_{i}/res", x, cnext, cnext, spec.groups)
    n, h, w, c = g.shape_of(x)
    x = g.group_norm("out_gn", x, spec.groups)
    x = g.silu("out_silu", x)
    g.conv2d("conv_out", x, c, spec.out_channels)
    return g


def build_all(scale: str) -> Dict[str, dict]:
    specs = small_specs() if scale == "small" else sd_v21_specs()
    return {
        "unet": build_unet_graph(specs["unet"]).to_json(),
        "text_encoder": build_text_graph(specs["text_encoder"]).to_json(),
        "decoder": build_decoder_graph(specs["decoder"]).to_json(),
    }


def write_graphs(out_dir: str):
    import os
    for scale in ("small", "sd_v21"):
        graphs = build_all(scale)
        for comp, graph in graphs.items():
            path = os.path.join(out_dir, f"{scale}_{comp}.graph.json")
            with open(path, "w") as f:
                json.dump(graph, f)
