"""VAE image decoder (the third SD component).

latent (B, 32, 32, 4) -> RGB (B, 256, 256, 3) through three nearest-
neighbour x2 upsample + conv stages with residual blocks, mirroring the
SD VAE decoder's topology.  Loaded last by the pipelined executor
(Sec. 3.3) after the text encoder has been evicted.
"""

from ..config import DecoderConfig
from ..params import Init, Params
from . import layers


def _res_init(rng: Init, cin: int, cout: int) -> Params:
    p: Params = {
        "gn1": rng.norm(cin),
        "conv1": rng.conv(3, 3, cin, cout),
        "gn2": rng.norm(cout),
        "conv2": rng.conv(3, 3, cout, cout),
    }
    if cin != cout:
        p["skip"] = rng.conv(1, 1, cin, cout)
    return p


def _res_apply(p: Params, x, groups: int, variant: str):
    h = layers.group_norm(p["gn1"], x, groups, variant)
    h = layers.silu(h)
    h = layers.conv2d(p["conv1"], h)
    h = layers.group_norm(p["gn2"], h, groups, variant)
    h = layers.silu(h)
    h = layers.conv2d(p["conv2"], h)
    if "skip" in p:
        x = layers.conv2d(p["skip"], x)
    return x + h


def init(rng: Init, cfg: DecoderConfig) -> Params:
    ch = cfg.base_channels
    p: Params = {
        "conv_in": rng.conv(3, 3, cfg.latent_channels, ch),
        "res_in": _res_init(rng, ch, ch),
        "out_gn": rng.norm(ch),
        "conv_out": rng.conv(3, 3, ch, cfg.out_channels),
    }
    for i in range(cfg.n_upsamples):
        p[f"up_{i}"] = {
            "conv": rng.conv(3, 3, ch, ch),
            "res": _res_init(rng, ch, ch),
        }
    return p


def apply(p: Params, latent, cfg: DecoderConfig, variant: str):
    """latent: (B, H, W, 4) -> image (B, 8H, 8W, 3) in [-1, 1]-ish."""
    x = layers.conv2d(p["conv_in"], latent)
    x = _res_apply(p["res_in"], x, cfg.groups, variant)
    for i in range(cfg.n_upsamples):
        up = p[f"up_{i}"]
        x = layers.upsample_nearest_2x(x)
        x = layers.conv2d(up["conv"], x)
        x = _res_apply(up["res"], x, cfg.groups, variant)
    x = layers.group_norm(p["out_gn"], x, cfg.groups, variant)
    x = layers.silu(x)
    return layers.conv2d(p["conv_out"], x)
