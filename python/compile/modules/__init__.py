"""Layer-2 model modules: a from-scratch latent-diffusion pipeline
(CLIP-like text encoder, UNet denoiser with spatial-transformer blocks,
VAE decoder) mirroring Stable Diffusion v2.1 at laptop scale."""
