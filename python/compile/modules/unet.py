"""Denoising UNet (the second, memory-resident SD component).

Structure mirrors SD v2.1 at laptop scale: conv_in -> down levels (res
blocks, spatial transformers at the attention levels, strided-conv
downsample) -> mid (res / transformer / res) -> up levels with skip
concatenation -> GroupNorm/SiLU/conv_out.

The first up-level-0 res block receives the concat of the upsampled
128-ch stream and the 64-ch skip: its 192 -> 64 conv at 32x32 is the
paper's over-sized conv (1920 -> 640), serialized in the mobile variant.
"""

from typing import List

import jax.numpy as jnp

from ..config import UNetConfig
from ..params import Init, Params
from . import layers, resnet, transformer2d


def _level_channels(cfg: UNetConfig) -> List[int]:
    return [cfg.base_channels * m for m in cfg.channel_mults]


def init(rng: Init, cfg: UNetConfig) -> Params:
    chans = _level_channels(cfg)
    d_t = cfg.d_time
    p: Params = {
        "time_mlp": {
            "l1": rng.linear(cfg.base_channels, d_t),
            "l2": rng.linear(d_t, d_t),
        },
        "conv_in": rng.conv(3, 3, cfg.in_channels, chans[0]),
        "out_gn": rng.norm(chans[0]),
        "conv_out": rng.conv(3, 3, chans[0], cfg.out_channels),
    }

    # --- down path ---
    skip_chs = [chans[0]]
    ch = chans[0]
    for lvl, lch in enumerate(chans):
        for i in range(cfg.n_res_blocks):
            blk: Params = {"res": resnet.init(rng, ch, lch, d_t)}
            if lvl in cfg.attn_levels:
                blk["attn"] = transformer2d.init(
                    rng, lch, cfg.n_heads, cfg.context_dim, cfg.ffn_mult)
            p[f"down_{lvl}_{i}"] = blk
            ch = lch
            skip_chs.append(ch)
        if lvl != len(chans) - 1:
            p[f"downsample_{lvl}"] = rng.conv(3, 3, ch, ch)
            skip_chs.append(ch)

    # --- mid ---
    p["mid_res1"] = resnet.init(rng, ch, ch, d_t)
    p["mid_attn"] = transformer2d.init(
        rng, ch, cfg.n_heads, cfg.context_dim, cfg.ffn_mult)
    p["mid_res2"] = resnet.init(rng, ch, ch, d_t)

    # --- up path ---
    for lvl in reversed(range(len(chans))):
        lch = chans[lvl]
        for i in range(cfg.n_res_blocks + 1):
            sc = skip_chs.pop()
            blk = {"res": resnet.init(rng, ch + sc, lch, d_t)}
            if lvl in cfg.attn_levels:
                blk["attn"] = transformer2d.init(
                    rng, lch, cfg.n_heads, cfg.context_dim, cfg.ffn_mult)
            p[f"up_{lvl}_{i}"] = blk
            ch = lch
        if lvl != 0:
            p[f"upsample_{lvl}"] = rng.conv(3, 3, ch, ch)
    assert not skip_chs
    return p


def apply(p: Params, latent, timestep, context, cfg: UNetConfig, variant: str):
    """latent: (B, H, W, Cin); timestep: (1,) f32; context: (B, S, d_ctx)
    -> predicted noise (B, H, W, Cout).

    B = 2 for classifier-free guidance (uncond/cond halves)."""
    chans = _level_channels(cfg)
    b = latent.shape[0]

    t = jnp.broadcast_to(timestep.reshape(()), (b,))
    t_emb = layers.timestep_embedding(t, cfg.base_channels)
    t_emb = layers.linear(p["time_mlp"]["l1"], t_emb)
    t_emb = layers.silu(t_emb)
    t_emb = layers.linear(p["time_mlp"]["l2"], t_emb)

    def res_attn(blk, x, bottleneck=False):
        x = resnet.apply(blk["res"], x, t_emb, cfg.groups, variant,
                         bottleneck=bottleneck)
        if "attn" in blk:
            x = transformer2d.apply(blk["attn"], x, context, cfg.groups,
                                    cfg.n_heads, variant,
                                    gelu_clip=cfg.gelu_clip)
        return x

    x = layers.conv2d(p["conv_in"], latent)
    skips = [x]
    for lvl in range(len(chans)):
        for i in range(cfg.n_res_blocks):
            x = res_attn(p[f"down_{lvl}_{i}"], x)
            skips.append(x)
        if lvl != len(chans) - 1:
            x = layers.conv2d(p[f"downsample_{lvl}"], x, stride=2)
            skips.append(x)

    x = resnet.apply(p["mid_res1"], x, t_emb, cfg.groups, variant)
    x = transformer2d.apply(p["mid_attn"], x, context, cfg.groups,
                            cfg.n_heads, variant, gelu_clip=cfg.gelu_clip)
    x = resnet.apply(p["mid_res2"], x, t_emb, cfg.groups, variant)

    for lvl in reversed(range(len(chans))):
        for i in range(cfg.n_res_blocks + 1):
            x = jnp.concatenate([x, skips.pop()], axis=-1)
            # the first highest-resolution up block hosts the paper's
            # over-sized conv (input channels = 2 * base + base)
            bott = (lvl == 0 and i == 0)
            x = res_attn(p[f"up_{lvl}_{i}"], x, bottleneck=bott)
        if lvl != 0:
            x = layers.upsample_nearest_2x(x)
            x = layers.conv2d(p[f"upsample_{lvl}"], x)
    assert not skips

    x = layers.group_norm(p["out_gn"], x, cfg.groups, variant)
    x = layers.silu(x)
    return layers.conv2d(p["conv_out"], x)
