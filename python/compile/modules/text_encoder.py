"""CLIP-like text encoder (paper: the first of the three SD components).

A small pre-LN transformer over hash-vocabulary tokens.  Runs once per
prompt on the request path, so the paper's pipelined executor (Sec. 3.3)
loads it, encodes, and evicts it before the denoising loop starts.
"""

import jax.numpy as jnp

from ..config import TextEncoderConfig
from ..params import Init, Params
from . import layers


def init(rng: Init, cfg: TextEncoderConfig) -> Params:
    p: Params = {
        "tok_emb": rng.embedding(cfg.vocab_size, cfg.d_model),
        "pos_emb": rng.embedding(cfg.seq_len, cfg.d_model),
        "final_ln": rng.norm(cfg.d_model),
    }
    for i in range(cfg.n_layers):
        p[f"layer_{i}"] = {
            "ln1": rng.norm(cfg.d_model),
            "q": rng.linear(cfg.d_model, cfg.d_model),
            "k": rng.linear(cfg.d_model, cfg.d_model),
            "v": rng.linear(cfg.d_model, cfg.d_model),
            "o": rng.linear(cfg.d_model, cfg.d_model),
            "ln2": rng.norm(cfg.d_model),
            "ff1": rng.linear(cfg.d_model, cfg.d_ff),
            "ff2": rng.linear(cfg.d_ff, cfg.d_model),
        }
    return p


def apply(p: Params, tokens, cfg: TextEncoderConfig, variant: str):
    """tokens: (B, S) int32 -> (B, S, d_model) context embeddings."""
    b, s = tokens.shape
    x = p["tok_emb"]["table"][tokens] + p["pos_emb"]["table"][jnp.arange(s)][None]
    for i in range(cfg.n_layers):
        lp = p[f"layer_{i}"]
        h = layers.layer_norm(lp["ln1"], x)
        q = layers.linear(lp["q"], h)
        k = layers.linear(lp["k"], h)
        v = layers.linear(lp["v"], h)
        attn = layers.attention(q, k, v, cfg.n_heads, variant)
        x = x + layers.linear(lp["o"], attn)
        h = layers.layer_norm(lp["ln2"], x)
        h = layers.linear(lp["ff1"], h)
        h = layers.gelu(h, variant)
        x = x + layers.linear(lp["ff2"], h)
    return layers.layer_norm(p["final_ln"], x)
