"""Primitive layers shared by every module.

Each layer dispatches on the model *variant*:

  * ``base``   — the graph a stock TF->TFLite export would produce
                 (rank-5 group norm with broadcasts, tanh-cubic GELU,
                 plain convs), built on the pure-jnp references.
  * ``mobile`` — the paper's rewritten graph, built on the L1 Pallas
                 kernels (broadcast-free group norm, clipped GELU,
                 input-serialized bottleneck conv).
"""

import math

import jax.numpy as jnp
from jax import lax

from ..kernels import ref
from ..kernels.gelu import gelu_stable_kernel, gelu_tanh_kernel
from ..kernels.groupnorm import group_norm_kernel
from ..kernels.attention import attention_kernel

BASE = "base"
MOBILE = "mobile"
VARIANTS = (BASE, MOBILE)


def linear(p, x):
    """x: (..., K) @ (K, N) + b."""
    return x @ p["w"] + p["b"]


def conv2d(p, x, stride: int = 1):
    """NHWC 3x3/1x1 same-padding conv; p['w'] is HWIO."""
    out = lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"].reshape(1, 1, 1, -1)


def silu(x):
    """SiLU/Swish: x * sigmoid(x) — the resnet-path nonlinearity of SD."""
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def gelu(x, variant: str, clip: float = 10.0):
    """GELU dispatch: paper Sec. 3.2."""
    if variant == MOBILE:
        return gelu_stable_kernel(x, clip=clip)
    return gelu_tanh_kernel(x)


def group_norm(p, x, groups: int, variant: str, eps: float = 1e-5):
    """GroupNorm dispatch: paper Sec. 3.1 (Fig. 7).

    ``base`` keeps the TFLite-export semantics (rank-5 + broadcast);
    ``mobile`` runs the broadcast-free Pallas kernel per batch element
    (the mobile pipeline is batch-1 per delegate invocation; CFG batch-2
    is unrolled, mirroring two sequential GPU dispatches).
    """
    if variant == MOBILE:
        outs = [
            group_norm_kernel(x[i:i + 1], p["gamma"], p["beta"], groups, eps=eps)
            for i in range(x.shape[0])
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return ref.group_norm_naive(x, p["gamma"], p["beta"], groups, eps=eps)


def layer_norm(p, x, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * lax.rsqrt(var + eps) * p["gamma"] + p["beta"]


def attention(q, k, v, n_heads: int, variant: str):
    """Multi-head attention over (B, S, C) via the fused Pallas kernel
    (mobile) or the jnp reference (base).  Returns (B, S, C)."""
    b, sq, c = q.shape
    skv = k.shape[1]
    d = c // n_heads

    def split(t, s):
        return t.reshape(b, s, n_heads, d).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q, sq), split(k, skv), split(v, skv)
    outs = []
    for i in range(b):
        if variant == MOBILE:
            outs.append(attention_kernel(qh[i], kh[i], vh[i]))
        else:
            outs.append(ref.attention(qh[i], kh[i], vh[i]))
    oh = jnp.stack(outs, axis=0)                   # (B, H, Sq, D)
    return oh.transpose(0, 2, 1, 3).reshape(b, sq, c)


def timestep_embedding(t, dim: int, max_period: float = 10000.0):
    """Sinusoidal timestep embedding; t: (B,) float -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def upsample_nearest_2x(x):
    """(N, H, W, C) -> (N, 2H, 2W, C) nearest-neighbour."""
    n, h, w, c = x.shape
    x = x[:, :, None, :, None, :]
    x = jnp.broadcast_to(x, (n, h, 2, w, 2, c))
    return x.reshape(n, 2 * h, 2 * w, c)
