"""UNet residual block (GroupNorm -> SiLU -> Conv + time injection).

The *bottleneck* flag marks the paper's problematic conv (Sec. 3.1): the
first conv after the highest-resolution skip-concat, whose input:output
channel ratio (3:1) mirrors the paper's 1x32x32x1920 -> 1x32x32x640 layer.
In the ``mobile`` variant that conv runs through the input-channel-
serialized Pallas kernel with the minimal factor (2); every other conv is
small enough to delegate whole.
"""

from ..kernels import ref
from ..kernels.serial_conv import conv3x3_input_serialized_kernel
from ..params import Init, Params
from . import layers

# minimal input-serialization factor found by the delegate search (paper:
# factor 2 for the 1920->640 conv; our 192->64 analog keeps the ratio)
SERIAL_FACTOR = 2


def init(rng: Init, cin: int, cout: int, d_time: int) -> Params:
    p: Params = {
        "gn1": rng.norm(cin),
        "conv1": rng.conv(3, 3, cin, cout),
        "time_proj": rng.linear(d_time, cout),
        "gn2": rng.norm(cout),
        "conv2": rng.conv(3, 3, cout, cout),
    }
    if cin != cout:
        p["skip"] = rng.conv(1, 1, cin, cout)
    return p


def _conv1(p, x, variant: str, bottleneck: bool):
    if bottleneck and variant == layers.MOBILE:
        # batch unrolled: one delegate dispatch per CFG half
        import jax.numpy as jnp
        outs = [
            conv3x3_input_serialized_kernel(
                x[i:i + 1], p["w"], p["b"], factor=SERIAL_FACTOR)
            for i in range(x.shape[0])
        ]
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return ref.conv2d_3x3(x, p["w"], p["b"])


def apply(p: Params, x, t_emb, groups: int, variant: str,
          bottleneck: bool = False):
    """x: (B, H, W, Cin); t_emb: (B, d_time) -> (B, H, W, Cout)."""
    h = layers.group_norm(p["gn1"], x, groups, variant)
    h = layers.silu(h)
    h = _conv1(p["conv1"], h, variant, bottleneck)
    h = h + layers.linear(p["time_proj"], layers.silu(t_emb))[:, None, None, :]
    h = layers.group_norm(p["gn2"], h, groups, variant)
    h = layers.silu(h)
    h = layers.conv2d(p["conv2"], h)
    if "skip" in p:
        x = layers.conv2d(p["skip"], x)
    return x + h
