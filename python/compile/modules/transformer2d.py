"""Spatial-transformer block (the paper's Sec. 3.1 FullyConnected host).

GroupNorm -> 1x1 proj_in -> flatten to (B, H*W, C) -> [self-attention,
cross-attention over the text context, GELU FFN] -> 1x1 proj_out ->
residual.  The FFN fully-connected layers are the layers the paper
converts to Conv2D for delegation; numerically FC == reshape-conv
(ref.fc_as_conv2d; verified in tests), so the lowered compute graph here
keeps plain matmuls while the TFLite-level graph spec (graphspec.py)
records them as FULLY_CONNECTED for the Rust pass pipeline to rewrite.
"""

from ..kernels.w8a16_matmul import w8a16_matmul_kernel
from ..params import Init, Params
from . import layers


def init(rng: Init, c: int, n_heads: int, context_dim: int, ffn_mult: int) -> Params:
    return {
        "gn": rng.norm(c),
        "proj_in": rng.conv(1, 1, c, c),
        "ln1": rng.norm(c),
        "sa_q": rng.linear(c, c),
        "sa_k": rng.linear(c, c),
        "sa_v": rng.linear(c, c),
        "sa_o": rng.linear(c, c),
        "ln2": rng.norm(c),
        "ca_q": rng.linear(c, c),
        "ca_k": rng.linear(context_dim, c),
        "ca_v": rng.linear(context_dim, c),
        "ca_o": rng.linear(c, c),
        "ln3": rng.norm(c),
        "ff1": rng.linear(c, ffn_mult * c),
        "ff2": rng.linear(ffn_mult * c, c),
    }


def _ff(p: Params, x):
    """FFN linear that dispatches to the W8A16 Pallas kernel when the
    params carry int8 weights (paper Sec. 3.4 deployment path)."""
    if "q" in p:
        b, s, k = x.shape
        out = w8a16_matmul_kernel(x.reshape(b * s, k), p["q"], p["scale"])
        return out.reshape(b, s, -1) + p["b"]
    return layers.linear(p, x)


def apply(p: Params, x, context, groups: int, n_heads: int, variant: str,
          gelu_clip: float = 10.0):
    """x: (B, H, W, C); context: (B, S_ctx, d_ctx)."""
    b, h, w, c = x.shape
    res = x
    y = layers.group_norm(p["gn"], x, groups, variant)
    y = layers.conv2d(p["proj_in"], y)
    t = y.reshape(b, h * w, c)

    # self-attention
    z = layers.layer_norm(p["ln1"], t)
    q = layers.linear(p["sa_q"], z)
    k = layers.linear(p["sa_k"], z)
    v = layers.linear(p["sa_v"], z)
    t = t + layers.linear(p["sa_o"], layers.attention(q, k, v, n_heads, variant))

    # cross-attention over the text context
    z = layers.layer_norm(p["ln2"], t)
    q = layers.linear(p["ca_q"], z)
    k = layers.linear(p["ca_k"], context)
    v = layers.linear(p["ca_v"], context)
    t = t + layers.linear(p["ca_o"], layers.attention(q, k, v, n_heads, variant))

    # GELU FFN — the float16-unstable op of paper Sec. 3.2
    z = layers.layer_norm(p["ln3"], t)
    z = _ff(p["ff1"], z)
    z = layers.gelu(z, variant, clip=gelu_clip)
    t = t + _ff(p["ff2"], z)

    return res + t.reshape(b, h, w, c)
