"""AOT build: lower every component to HLO text + write weights and the
manifest.  This is the ONLY Python entry point on the build path; the Rust
binary is self-contained once ``make artifacts`` has run.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import graphspec, model, quantize, scheduler, tokenizer, weightsbin
from .config import DEFAULT

# UNet prune fraction for the int8+pruned artifact (paper: "huge
# convolution layers"); kept modest to preserve output quality.
PRUNE_FRAC = 0.125

GOLDEN_PROMPTS = [
    "a photograph of an astronaut riding a horse",
    "mobile stable diffusion on a galaxy s23",
    "The quick brown fox, jumps over the lazy dog!",
    "",
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_component(fn, arrays, act_specs) -> str:
    param_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays]
    lowered = jax.jit(fn).lower(param_specs, *act_specs)
    return to_hlo_text(lowered)


def spec_json(specs):
    return [{"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
            for s in specs]


def build_component(name: str, builder, variant: str, out_dir: str,
                    manifest: dict, key: str = None):
    key = key or name
    t0 = time.time()
    fn, paths, arrays, act_specs = builder(DEFAULT, variant)
    hlo = lower_component(fn, arrays, act_specs)
    hlo_file = f"{key}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(hlo)
    out_specs = jax.eval_shape(
        fn, [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrays],
        *act_specs)
    manifest["components"][key] = {
        "hlo": hlo_file,
        "variant": variant,
        "params": [
            {"path": p, "shape": list(a.shape), "dtype": str(a.dtype)}
            for p, a in zip(paths, arrays)
        ],
        "activations": spec_json(act_specs),
        "outputs": spec_json(jax.tree_util.tree_leaves(out_specs)),
        "param_bytes_f32": int(sum(a.size * 4 for a in arrays)),
        "hlo_sha256": hashlib.sha256(hlo.encode()).hexdigest(),
    }
    print(f"  lowered {key:<16} ({len(hlo) / 1e6:.1f} MB HLO, "
          f"{time.time() - t0:.1f}s)")
    return paths, arrays


def write_weight_files(out_dir: str, manifest: dict):
    """fp32 weights for every component + int8 / int8-pruned for the UNet."""
    weight_sets = {}
    for comp, builder in (("text_encoder", model.build_text_encoder),
                          ("unet", model.build_unet),
                          ("decoder", model.build_decoder),
                          ("block", model.build_block)):
        _fn, paths, arrays, _ = builder(DEFAULT, "mobile")
        fname = f"weights_{comp}_fp32.bin"
        size = weightsbin.write(
            os.path.join(out_dir, fname),
            [{"path": p, "arr": a} for p, a in zip(paths, arrays)])
        weight_sets.setdefault(comp, {})["fp32"] = {
            "file": fname, "bytes": size}
        if comp != "unet":
            continue
        for tag, frac in (("int8", 0.0), ("int8_pruned", PRUNE_FRAC)):
            qmap = quantize.compress(paths, arrays, prune_frac=frac)
            entries = []
            for p, a in zip(paths, arrays):
                if p in qmap:
                    q = qmap[p]
                    entries.append({"path": p, "q": q["q"],
                                    "scale": q["scale"], "keep": q["keep"]})
                else:
                    entries.append({"path": p, "arr": a})
            fname = f"weights_{comp}_{tag}.bin"
            size = weightsbin.write(os.path.join(out_dir, fname), entries)
            weight_sets[comp][tag] = {"file": fname, "bytes": size}
    # block_w8 params are self-contained: int8 FFN weights live directly in
    # the param list (the Rust side feeds them to the W8A16 kernel as-is,
    # so their scales are separate f32 params, and the int8 payload is
    # stored with identity scale here).
    for key, frac in (("block_w8", 0.0), ("block_w8p", PRUNE_FRAC)):
        _fn, paths, arrays, _ = model.build_block_w8(DEFAULT, "mobile", frac)
        entries = []
        for p, a in zip(paths, arrays):
            if a.dtype == np.int8:
                entries.append({"path": p, "q": a,
                                "scale": np.ones(a.shape[-1], np.float32)})
            else:
                entries.append({"path": p,
                                "arr": np.asarray(a, dtype=np.float32)})
        fname = f"weights_{key}_fp32.bin"
        size = weightsbin.write(os.path.join(out_dir, fname), entries)
        weight_sets[key] = {"fp32": {"file": fname, "bytes": size}}
    # attach weight sets to the manifest components that consume them
    consumers = {
        "text_encoder": ["text_encoder"],
        "unet": ["unet_base", "unet_mobile"],
        "decoder": ["decoder"],
        "block": ["block_fp"],
        "block_w8": ["block_w8"],
        "block_w8p": ["block_w8p"],
    }
    for comp, sets in weight_sets.items():
        for key in consumers.get(comp, []):
            if key in manifest["components"]:
                manifest["components"][key].setdefault(
                    "weights", {}).update(sets)


def scheduler_manifest() -> dict:
    cfg = DEFAULT.scheduler
    acp = scheduler.alphas_cumprod(cfg)
    ts = scheduler.timesteps(cfg)
    # golden DDIM trace: latent0 seeded, eps := 0.1 * latent each step
    latent0 = np.random.default_rng(1234).normal(size=8).astype(np.float64)
    latent = latent0.copy()
    trace = []
    for i, t in enumerate(ts[:5]):
        t_prev = ts[i + 1] if i + 1 < len(ts) else -1
        eps = 0.1 * latent
        latent = scheduler.ddim_step(latent, eps, t, t_prev, acp)
        trace.append([float(v) for v in latent])
    # golden multistep trace: same latent0/surrogate, full 8-step
    # DPM-Solver++(2M) schedule (history accumulates, so the whole
    # schedule is traced — a prefix would not pin the second-order path)
    ms_ts = scheduler.timesteps(cfg, num_steps=8)
    latent = latent0.copy()
    eps_prev, t_last = None, -1
    multistep_trace = []
    for i, t in enumerate(ms_ts):
        t_prev = ms_ts[i + 1] if i + 1 < len(ms_ts) else -1
        eps = 0.1 * latent
        latent = scheduler.dpm2m_step(latent, eps, eps_prev, t, t_prev,
                                      t_last, acp)
        eps_prev, t_last = eps, t
        multistep_trace.append([float(v) for v in latent])
    return {
        "num_train_timesteps": cfg.num_train_timesteps,
        "beta_start": cfg.beta_start,
        "beta_end": cfg.beta_end,
        "num_inference_steps": cfg.num_inference_steps,
        "guidance_scale": cfg.guidance_scale,
        "alphas_cumprod": [float(a) for a in acp],
        "timesteps": ts,
        "golden": {
            "latent0": [float(v) for v in latent0],
            "eps_scale": 0.1,
            "trace": trace,
            "multistep_trace": multistep_trace,
        },
    }


def tokenizer_manifest() -> dict:
    cfg = DEFAULT.text
    return {
        "vocab_size": cfg.vocab_size,
        "seq_len": cfg.seq_len,
        "golden": [
            {"text": p,
             "ids": tokenizer.encode(p, cfg.vocab_size, cfg.seq_len)}
            for p in GOLDEN_PROMPTS
        ],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated component keys to rebuild")
    args = ap.parse_args()
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest = {
        "format_version": 1,
        "model": "mobile-stable-diffusion-small",
        "cfg_batch": model.CFG_BATCH,
        "latent": {"size": DEFAULT.unet.latent_size,
                   "channels": DEFAULT.unet.in_channels},
        "image": {"size": DEFAULT.image_size,
                  "channels": DEFAULT.decoder.out_channels},
        "components": {},
        "scheduler": scheduler_manifest(),
        "tokenizer": tokenizer_manifest(),
    }

    plan = [
        ("text_encoder", model.build_text_encoder, "mobile", "text_encoder"),
        ("unet", model.build_unet, "base", "unet_base"),
        ("unet", model.build_unet, "mobile", "unet_mobile"),
        ("decoder", model.build_decoder, "mobile", "decoder"),
        ("block", model.build_block, "base", "block_fp"),
        ("block_w8", lambda c, v: model.build_block_w8(c, v, 0.0),
         "mobile", "block_w8"),
        ("block_w8", lambda c, v: model.build_block_w8(c, v, PRUNE_FRAC),
         "mobile", "block_w8p"),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("lowering components:")
    for name, builder, variant, key in plan:
        if only and key not in only:
            continue
        build_component(name, builder, variant, out_dir, manifest, key=key)

    print("writing weight files:")
    write_weight_files(out_dir, manifest)

    print("writing graph specs:")
    graphspec.write_graphs(out_dir)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written to {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
