//! Offline stand-in for the `xla-rs` PJRT binding surface used by
//! `mobile-diffusion`.
//!
//! The real crate links against the XLA/PJRT shared library, which is
//! not available in this build environment.  This stub mirrors the API
//! the runtime layer calls, and — new with the micro-batching work —
//! implements a small **deterministic interpreter** so the serving
//! stack can be exercised end-to-end without a device:
//!
//! * Buffers really hold host data (`buffer_from_host_buffer` copies,
//!   `write_from_host` rewrites an existing buffer in place with no
//!   reallocation — the stand-in for PJRT buffer donation).
//! * `compile` accepts artifacts in the tiny `STUBHLO` text format
//!   (produced by `mobile_diffusion::testkit`); executing one computes
//!   a deterministic pseudo-random function of the weights and
//!   activations.  In `rowwise` mode each output row depends only on
//!   the *content* of the corresponding input rows — never on the row
//!   index or the batch size — so a request batched with others
//!   produces bit-identical results to the same request run solo,
//!   which is exactly the property the micro-batcher's tests pin down.
//!   Real (opaque) HLO text still fails to compile with a clear
//!   message, as before.
//! * Every client carries a [`DeviceStats`] counter block (transfers,
//!   in-place writes, per-program dispatches and rows) so tests can
//!   assert "one UNet dispatch per step" and "no new device buffers
//!   after warmup" without instrumenting the hot loop itself.
//!
//! The per-dispatch cost of the interpreter is dominated by a digest
//! over the weight buffers — a deliberate model of the fixed
//! per-dispatch cost (weight reads, kernel launch) that micro-batching
//! amortizes, so throughput comparisons on the stub have the right
//! shape.
//!
//! To run against real hardware, replace the `xla = { path = ... }`
//! dependency in `rust/Cargo.toml` with the actual bindings.  The
//! extensions beyond the classic surface (`write_from_host`,
//! `Literal::copy_into_f32`, `DeviceStats`) are small shims over
//! standard PJRT facilities (donated buffers, literal reads, client
//! metrics).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const STUB_MSG: &str =
    "PJRT unavailable: built against the vendored xla stub (see rust/vendor/xla)";

/// Error type mirroring `xla::Error`.  Injected faults additionally
/// carry a [`FaultKind`] so the runtime can classify them without
/// parsing the message.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
    kind: Option<FaultKind>,
}

impl Error {
    pub fn new(message: impl Into<String>) -> Error {
        Error { message: message.into(), kind: None }
    }

    /// An injected-fault error carrying its classification.
    pub fn fault(message: impl Into<String>, kind: FaultKind) -> Error {
        Error { message: message.into(), kind: Some(kind) }
    }

    /// `Some` when this error came from the fault injector.
    pub fn fault_kind(&self) -> Option<FaultKind> {
        self.kind
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>(detail: &str) -> Result<T, Error> {
    Err(Error::new(format!("{STUB_MSG}: {detail}")))
}

/// Element types accepted by raw-byte buffer uploads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    F32,
}

// --------------------------------------------------------------- faults

/// Classification of an injected fault — the failure classes a real
/// PJRT backend raises on flaky mobile hardware.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Recoverable hiccup: the same operation is expected to succeed
    /// on retry (driver timeout, bus glitch).
    Transient,
    /// Unrecoverable program or argument error; retrying is pointless.
    Fatal,
    /// The device handle is gone; the client must be rebuilt.
    DeviceLost,
    /// Device allocator exhausted.
    Oom,
}

impl FaultKind {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::Transient => "transient",
            FaultKind::Fatal => "fatal",
            FaultKind::DeviceLost => "device_lost",
            FaultKind::Oom => "oom",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "transient" => Some(FaultKind::Transient),
            "fatal" => Some(FaultKind::Fatal),
            "device_lost" => Some(FaultKind::DeviceLost),
            "oom" => Some(FaultKind::Oom),
            _ => None,
        }
    }
}

/// Which client operation a fault targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    Compile,
    Transfer,
    Write,
    Dispatch,
}

impl FaultOp {
    pub fn as_str(self) -> &'static str {
        match self {
            FaultOp::Compile => "compile",
            FaultOp::Transfer => "transfer",
            FaultOp::Write => "write",
            FaultOp::Dispatch => "dispatch",
        }
    }

    pub fn parse(s: &str) -> Option<FaultOp> {
        match s {
            "compile" => Some(FaultOp::Compile),
            "transfer" => Some(FaultOp::Transfer),
            "write" => Some(FaultOp::Write),
            "dispatch" => Some(FaultOp::Dispatch),
            _ => None,
        }
    }

    fn index(self) -> usize {
        match self {
            FaultOp::Compile => 0,
            FaultOp::Transfer => 1,
            FaultOp::Write => 2,
            FaultOp::Dispatch => 3,
        }
    }
}

/// A deterministic fault schedule installed on a client via
/// [`DeviceStats::set_fault_plan`].  Two mechanisms compose:
///
/// * **Scheduled faults** fail exactly the Nth occurrence of an
///   operation (counted from 1, per client) with a chosen kind —
///   tests pin exact failure points with these.
/// * **Rate faults** fail a seeded pseudo-random subset of dispatches
///   with transient errors — chaos runs use these for sustained
///   background failure.  The subset is a pure function of
///   `(seed, dispatch index)`, so the same seed always faults the
///   same dispatches.
///
/// Latency spikes (`spike_every`/`spike_ms`) sleep without failing,
/// modelling thermal throttling.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub seed: u64,
    scheduled: Vec<(FaultOp, u64, FaultKind)>,
    dispatch_fault_rate: f64,
    spike_every: u64,
    spike_ms: u64,
}

impl FaultPlan {
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Fail the `nth` occurrence (1-based) of `op` with `kind`.
    pub fn fail_nth(mut self, op: FaultOp, nth: u64, kind: FaultKind) -> FaultPlan {
        self.scheduled.push((op, nth, kind));
        self
    }

    /// Fail a seeded pseudo-random fraction of dispatches transiently.
    pub fn transient_dispatch_rate(mut self, rate: f64) -> FaultPlan {
        self.dispatch_fault_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// Every `every`-th dispatch sleeps `ms` milliseconds.
    pub fn latency_spike(mut self, every: u64, ms: u64) -> FaultPlan {
        self.spike_every = every;
        self.spike_ms = ms;
        self
    }

    /// Parse a comma-separated spec: `op:nth:kind` entries plus the
    /// pseudo-entries `rate:<f64>` and `spike:<every>:<ms>`, e.g.
    /// `dispatch:5:transient,compile:2:fatal,rate:0.05,spike:8:2`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, Error> {
        let mut plan = FaultPlan::seeded(seed);
        for entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let parts: Vec<&str> = entry.split(':').collect();
            let bad = || Error::new(format!("bad fault spec entry: {entry:?}"));
            match parts.as_slice() {
                ["rate", r] => {
                    plan.dispatch_fault_rate =
                        r.parse::<f64>().map_err(|_| bad())?.clamp(0.0, 1.0);
                }
                ["spike", every, ms] => {
                    plan.spike_every = every.parse().map_err(|_| bad())?;
                    plan.spike_ms = ms.parse().map_err(|_| bad())?;
                }
                [op, nth, kind] => {
                    let op = FaultOp::parse(op).ok_or_else(bad)?;
                    let nth: u64 = nth.parse().map_err(|_| bad())?;
                    let kind = FaultKind::parse(kind).ok_or_else(bad)?;
                    plan.scheduled.push((op, nth, kind));
                }
                _ => return Err(bad()),
            }
        }
        Ok(plan)
    }

    /// True when the plan can never fire.
    pub fn is_empty(&self) -> bool {
        self.scheduled.is_empty() && self.dispatch_fault_rate == 0.0 && self.spike_every == 0
    }
}

/// Installed plan + per-operation attempt counters.
#[derive(Debug)]
struct FaultState {
    plan: FaultPlan,
    attempts: [u64; 4],
}

impl FaultState {
    /// Count an attempt of `op`; returns its 1-based index.
    fn bump(&mut self, op: FaultOp) -> u64 {
        let slot = &mut self.attempts[op.index()];
        *slot += 1;
        *slot
    }
}

// --------------------------------------------------------------- stats

/// Per-client device counters, exposed so tests can verify transfer
/// and dispatch behaviour of the serving hot loop.  Scoped to the
/// client (not global) so parallel tests do not observe each other.
#[derive(Debug, Default)]
pub struct DeviceStats {
    transfers: AtomicU64,
    transfer_bytes: AtomicU64,
    writes: AtomicU64,
    compiles: AtomicU64,
    executions: Mutex<BTreeMap<String, u64>>,
    rows: Mutex<BTreeMap<String, u64>>,
    injected_transient: AtomicU64,
    injected_fatal: AtomicU64,
    injected_spikes: AtomicU64,
    faults: Mutex<Option<FaultState>>,
    /// W8A8 activation quantization toggle: when set, programs that
    /// declare an `aquant` scale round-trip their outputs through int8
    /// (quantize -> dequantize at the graph boundary).  Off by default
    /// — the planner enables it per client where the cost model says
    /// the bandwidth saving pays.
    activation_quant: AtomicBool,
    /// dispatches whose outputs went through the int8 round-trip
    quantized_dispatches: AtomicU64,
    /// Device memory capacity in bytes (0 = unlimited).  With a cap
    /// set, buffer creations and dispatch outputs that would push
    /// accounted usage past it fail with an OOM fault — capacity
    /// pressure, not a scheduled fault, so runs stay reproducible.
    mem_cap: AtomicU64,
    /// Bytes currently held by live buffers on this client.
    mem_used: AtomicU64,
    /// High-water mark of `mem_used`.
    mem_peak: AtomicU64,
    /// Allocations rejected by the capacity accountant (distinct from
    /// the scheduled `injected_*` fault counters).
    oom_rejections: AtomicU64,
}

impl DeviceStats {
    /// Host->device buffer *creations* (uploads allocating a new buffer).
    pub fn transfers(&self) -> u64 {
        self.transfers.load(Ordering::Relaxed)
    }

    pub fn transfer_bytes(&self) -> u64 {
        self.transfer_bytes.load(Ordering::Relaxed)
    }

    /// In-place rewrites of existing buffers (`write_from_host`).
    pub fn writes(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }

    /// Successful program compilations on this client — the counter the
    /// warm-reload tests use to prove a re-acquire skipped the compile.
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Dispatches of the named STUBHLO program.
    pub fn executions_of(&self, name: &str) -> u64 {
        *self.executions.lock().unwrap().get(name).unwrap_or(&0)
    }

    pub fn total_executions(&self) -> u64 {
        self.executions.lock().unwrap().values().sum()
    }

    /// Total batch rows processed by the named program across all of
    /// its dispatches (a B-row dispatch counts B).
    pub fn rows_of(&self, name: &str) -> u64 {
        *self.rows.lock().unwrap().get(name).unwrap_or(&0)
    }

    fn record_transfer(&self, bytes: u64) {
        self.transfers.fetch_add(1, Ordering::Relaxed);
        self.transfer_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    fn record_write(&self) {
        self.writes.fetch_add(1, Ordering::Relaxed);
    }

    fn record_execution(&self, name: &str, rows: u64) {
        *self.executions.lock().unwrap().entry(name.to_string()).or_insert(0) += 1;
        *self.rows.lock().unwrap().entry(name.to_string()).or_insert(0) += rows;
    }

    /// Enable or disable W8A8 activation quantization on this client.
    /// Only programs carrying an `aquant` scale are affected.
    pub fn set_activation_quant(&self, on: bool) {
        self.activation_quant.store(on, Ordering::Relaxed);
    }

    /// Current W8A8 toggle state.
    pub fn activation_quant(&self) -> bool {
        self.activation_quant.load(Ordering::Relaxed)
    }

    /// Dispatches whose outputs were int8 round-tripped.
    pub fn quantized_dispatches(&self) -> u64 {
        self.quantized_dispatches.load(Ordering::Relaxed)
    }

    /// Install (or clear, with `None`) a device memory capacity in
    /// bytes.  Live buffers keep their charge across the change; a cap
    /// below current usage only rejects *new* allocations until drops
    /// free enough.
    pub fn set_device_mem(&self, cap: Option<u64>) {
        self.mem_cap.store(cap.unwrap_or(0), Ordering::Relaxed);
    }

    /// Configured device memory capacity, if any.
    pub fn device_mem(&self) -> Option<u64> {
        match self.mem_cap.load(Ordering::Relaxed) {
            0 => None,
            cap => Some(cap),
        }
    }

    /// Bytes currently held by live buffers.
    pub fn mem_used(&self) -> u64 {
        self.mem_used.load(Ordering::Relaxed)
    }

    /// High-water mark of buffer bytes held at once.
    pub fn mem_peak(&self) -> u64 {
        self.mem_peak.load(Ordering::Relaxed)
    }

    /// Allocations rejected for exceeding the device memory capacity.
    pub fn oom_rejections(&self) -> u64 {
        self.oom_rejections.load(Ordering::Relaxed)
    }

    /// Account `bytes` of device memory for a new allocation.  Usage
    /// and peak are tracked even without a cap so tests can calibrate
    /// real footprints; with a cap, allocations past it are rejected
    /// with an OOM fault and leave usage untouched.
    fn charge(&self, bytes: u64, what: &str) -> Result<(), Error> {
        let used = self.mem_used.fetch_add(bytes, Ordering::Relaxed) + bytes;
        let cap = self.mem_cap.load(Ordering::Relaxed);
        if cap > 0 && used > cap {
            self.mem_used.fetch_sub(bytes, Ordering::Relaxed);
            self.oom_rejections.fetch_add(1, Ordering::Relaxed);
            return Err(Error::fault(
                format!(
                    "device memory exhausted: {what} needs {bytes} B with {} of {cap} B in use",
                    used - bytes
                ),
                FaultKind::Oom,
            ));
        }
        self.mem_peak.fetch_max(used, Ordering::Relaxed);
        Ok(())
    }

    /// Release `bytes` previously charged (buffer drop).
    fn credit(&self, bytes: u64) {
        self.mem_used.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Install (or clear, with `None`) the client's fault schedule.
    /// Attempt counters restart from zero; injected-fault counters are
    /// monotone across plan swaps.
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        *self.faults.lock().unwrap() =
            plan.map(|plan| FaultState { plan, attempts: [0; 4] });
    }

    /// Injected faults classified transient (retry expected to work).
    pub fn injected_transient(&self) -> u64 {
        self.injected_transient.load(Ordering::Relaxed)
    }

    /// Injected faults classified fatal (incl. device-lost and OOM).
    pub fn injected_fatal(&self) -> u64 {
        self.injected_fatal.load(Ordering::Relaxed)
    }

    /// Injected latency spikes (slept, did not fail).
    pub fn injected_spikes(&self) -> u64 {
        self.injected_spikes.load(Ordering::Relaxed)
    }

    /// All injected failures (transient + fatal; spikes excluded).
    pub fn injected_faults(&self) -> u64 {
        self.injected_transient() + self.injected_fatal()
    }

    /// Consult the fault plan before performing `op`.  Sleeps through a
    /// scheduled latency spike, then either fails with the scheduled /
    /// seeded fault or passes.
    fn check_fault(&self, op: FaultOp, what: &str) -> Result<(), Error> {
        let (fault, spike_ms, n) = {
            let mut guard = self.faults.lock().unwrap();
            let Some(state) = guard.as_mut() else { return Ok(()) };
            let n = state.bump(op);
            let mut fault = state
                .plan
                .scheduled
                .iter()
                .find(|&&(o, at, _)| o == op && at == n)
                .map(|&(_, _, k)| k);
            if fault.is_none()
                && op == FaultOp::Dispatch
                && state.plan.dispatch_fault_rate > 0.0
            {
                // seeded Bernoulli draw: pure function of (seed, n)
                let h = fin(mix(mix(FNV_OFFSET, state.plan.seed), n));
                let u = (h >> 11) as f64 / (1u64 << 53) as f64;
                if u < state.plan.dispatch_fault_rate {
                    fault = Some(FaultKind::Transient);
                }
            }
            let spike_ms = if op == FaultOp::Dispatch
                && state.plan.spike_every > 0
                && n % state.plan.spike_every == 0
            {
                state.plan.spike_ms
            } else {
                0
            };
            (fault, spike_ms, n)
        };
        if spike_ms > 0 {
            self.injected_spikes.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_millis(spike_ms));
        }
        if let Some(kind) = fault {
            match kind {
                FaultKind::Transient => {
                    self.injected_transient.fetch_add(1, Ordering::Relaxed)
                }
                _ => self.injected_fatal.fetch_add(1, Ordering::Relaxed),
            };
            return Err(Error::fault(
                format!("injected {} fault: {} #{n} ({what})", kind.as_str(), op.as_str()),
                kind,
            ));
        }
        Ok(())
    }
}

// -------------------------------------------------------------- buffers

/// Typed device-side payload of a stub buffer.
#[derive(Debug, Clone)]
pub enum BufData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    /// Execution result: the output tuple of a dispatch.
    Tuple(Vec<Vec<f32>>),
}

impl BufData {
    fn len(&self) -> usize {
        match self {
            BufData::F32(v) => v.len(),
            BufData::I32(v) => v.len(),
            BufData::I8(v) => v.len(),
            BufData::U8(v) => v.len(),
            BufData::Tuple(outs) => outs.iter().map(|o| o.len()).sum(),
        }
    }

    /// Fold elements `[start, end)` into a running digest.  The digest
    /// depends only on element *values and order*, never on absolute
    /// positions — the property batch-vs-solo bit-parity rests on.
    fn fold(&self, h: u64, start: usize, end: usize) -> u64 {
        match self {
            BufData::F32(v) => v[start..end]
                .iter()
                .fold(h, |h, x| mix(h, x.to_bits() as u64)),
            BufData::I32(v) => v[start..end]
                .iter()
                .fold(h, |h, x| mix(h, *x as u32 as u64)),
            BufData::I8(v) => v[start..end]
                .iter()
                .fold(h, |h, x| mix(h, *x as u8 as u64)),
            BufData::U8(v) => v[start..end].iter().fold(h, |h, x| mix(h, *x as u64)),
            BufData::Tuple(_) => h,
        }
    }
}

/// Host-native types accepted by typed buffer uploads / downloads.
pub trait NativeType: Copy {
    fn to_data(v: &[Self]) -> BufData;
    /// Rewrite `data` in place from `v`; false on dtype/length mismatch.
    fn write_into(data: &mut BufData, v: &[Self]) -> bool;
    fn read_literal(lit: &Literal) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_data(v: &[Self]) -> BufData {
        BufData::F32(v.to_vec())
    }
    fn write_into(data: &mut BufData, v: &[Self]) -> bool {
        match data {
            BufData::F32(d) if d.len() == v.len() => {
                d.copy_from_slice(v);
                true
            }
            _ => false,
        }
    }
    fn read_literal(lit: &Literal) -> Option<Vec<Self>> {
        match lit {
            Literal::F32(v) => Some(v.clone()),
            Literal::Tuple(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn to_data(v: &[Self]) -> BufData {
        BufData::I32(v.to_vec())
    }
    fn write_into(data: &mut BufData, v: &[Self]) -> bool {
        match data {
            BufData::I32(d) if d.len() == v.len() => {
                d.copy_from_slice(v);
                true
            }
            _ => false,
        }
    }
    fn read_literal(_lit: &Literal) -> Option<Vec<Self>> {
        None
    }
}

impl NativeType for i8 {
    fn to_data(v: &[Self]) -> BufData {
        BufData::I8(v.to_vec())
    }
    fn write_into(data: &mut BufData, v: &[Self]) -> bool {
        match data {
            BufData::I8(d) if d.len() == v.len() => {
                d.copy_from_slice(v);
                true
            }
            _ => false,
        }
    }
    fn read_literal(_lit: &Literal) -> Option<Vec<Self>> {
        None
    }
}

impl NativeType for u8 {
    fn to_data(v: &[Self]) -> BufData {
        BufData::U8(v.to_vec())
    }
    fn write_into(data: &mut BufData, v: &[Self]) -> bool {
        match data {
            BufData::U8(d) if d.len() == v.len() => {
                d.copy_from_slice(v);
                true
            }
            _ => false,
        }
    }
    fn read_literal(_lit: &Literal) -> Option<Vec<Self>> {
        None
    }
}

/// A PJRT device handle (opaque; never instantiated by the stub).
#[derive(Debug)]
pub struct PjRtDevice {
    _private: (),
}

/// A device buffer holding real host-side data in the stub.
#[derive(Debug)]
pub struct PjRtBuffer {
    data: BufData,
    dims: Vec<usize>,
    /// Device bytes charged at creation, credited back on drop.
    bytes: u64,
    stats: Arc<DeviceStats>,
}

impl Drop for PjRtBuffer {
    fn drop(&mut self) {
        self.stats.credit(self.bytes);
    }
}

impl PjRtBuffer {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Rewrite the buffer contents in place (the stand-in for a donated
    /// PJRT buffer).  The dtype and element count must match exactly;
    /// no reallocation happens on success.
    pub fn write_from_host<T: NativeType>(&mut self, v: &[T]) -> Result<(), Error> {
        self.stats.check_fault(FaultOp::Write, "write_from_host")?;
        if !T::write_into(&mut self.data, v) {
            return Err(Error::new(format!(
                "write_from_host: dtype/length mismatch (buffer holds {} elements)",
                self.data.len()
            )));
        }
        self.stats.record_write();
        Ok(())
    }

    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match &self.data {
            BufData::Tuple(outs) => Ok(Literal::Tuple(
                outs.iter().map(|o| Literal::F32(o.clone())).collect(),
            )),
            BufData::F32(v) => Ok(Literal::F32(v.clone())),
            _ => stub_err("only f32/tuple buffers can be read back"),
        }
    }
}

/// A host literal.
#[derive(Debug)]
pub enum Literal {
    Tuple(Vec<Literal>),
    F32(Vec<f32>),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(v) => Ok(v),
            lit @ Literal::F32(_) => Ok(vec![lit]),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::read_literal(self)
            .ok_or_else(|| Error::new("to_vec: literal is not of the requested dtype"))
    }

    /// Copy into a caller-owned vector, reusing its capacity (the
    /// zero-realloc read-back used by the serving hot loop).
    pub fn copy_into_f32(&self, out: &mut Vec<f32>) -> Result<(), Error> {
        match self {
            Literal::F32(v) => {
                out.clear();
                out.extend_from_slice(v);
                Ok(())
            }
            Literal::Tuple(_) => Err(Error::new("copy_into_f32: literal is a tuple")),
        }
    }
}

// -------------------------------------------------------------- client

/// A PJRT client.  `cpu()` succeeds; device work runs on the stub
/// interpreter for STUBHLO programs and fails for opaque HLO.
#[derive(Debug)]
pub struct PjRtClient {
    platform: String,
    stats: Arc<DeviceStats>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient {
            platform: "cpu (xla stub)".to_string(),
            stats: Arc::new(DeviceStats::default()),
        })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    /// This client's transfer/dispatch counters.
    pub fn stats(&self) -> Arc<DeviceStats> {
        Arc::clone(&self.stats)
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        self.stats.check_fault(FaultOp::Compile, "compile")?;
        match &comp.program {
            Some(p) => {
                self.stats.compiles.fetch_add(1, Ordering::Relaxed);
                Ok(PjRtLoadedExecutable {
                    program: p.clone(),
                    stats: Arc::clone(&self.stats),
                })
            }
            None => stub_err("opaque HLO cannot compile offline (STUBHLO programs can)"),
        }
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        self.stats.check_fault(FaultOp::Transfer, "buffer_from_host_buffer")?;
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error::new(format!(
                "buffer_from_host_buffer: shape {dims:?} wants {want} elements, got {}",
                data.len()
            )));
        }
        let bytes = std::mem::size_of_val(data) as u64;
        self.stats.charge(bytes, "buffer_from_host_buffer")?;
        self.stats.record_transfer(bytes);
        Ok(PjRtBuffer {
            data: T::to_data(data),
            dims: dims.to_vec(),
            bytes,
            stats: Arc::clone(&self.stats),
        })
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        ty: ElementType,
        data: &[u8],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        self.stats.check_fault(FaultOp::Transfer, "buffer_from_host_raw_bytes")?;
        let want: usize = dims.iter().product();
        let payload = match ty {
            ElementType::S8 => {
                if data.len() != want {
                    return Err(Error::new("raw S8 upload: shape/length mismatch"));
                }
                BufData::I8(data.iter().map(|&b| b as i8).collect())
            }
            ElementType::S32 => {
                if data.len() != want * 4 {
                    return Err(Error::new("raw S32 upload: shape/length mismatch"));
                }
                BufData::I32(
                    data.chunks_exact(4)
                        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            ElementType::F32 => {
                if data.len() != want * 4 {
                    return Err(Error::new("raw F32 upload: shape/length mismatch"));
                }
                BufData::F32(
                    data.chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
        };
        let bytes = data.len() as u64;
        self.stats.charge(bytes, "buffer_from_host_raw_bytes")?;
        self.stats.record_transfer(bytes);
        Ok(PjRtBuffer {
            data: payload,
            dims: dims.to_vec(),
            bytes,
            stats: Arc::clone(&self.stats),
        })
    }
}

// ------------------------------------------------------------- programs

/// Output shape rule of a STUBHLO program.
#[derive(Debug, Clone)]
enum OutSpec {
    /// Output has the same element count (and row structure) as the
    /// given activation argument — the UNet's eps-matches-latent case.
    LikeAct(usize),
    /// Fixed element count, batch-independent.
    Elems(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// One output row per batch row of the first activation; each row
    /// depends only on that row's slice of the batch-major inputs.
    Rowwise,
    /// One output computed from all activations as a whole.
    Whole,
}

/// A parsed STUBHLO program.  Example artifact:
///
/// ```text
/// STUBHLO v1
/// name unet_mobile
/// mode rowwise
/// nweights 1
/// seed 22
/// out like 0
/// ```
#[derive(Debug, Clone)]
struct Program {
    name: String,
    mode: Mode,
    /// leading executable arguments that are weights (rest: activations)
    nweights: usize,
    seed: u64,
    out: OutSpec,
    /// per-tensor symmetric int8 scale for W8A8 activation
    /// quantization: when the client toggle is on, outputs are rounded
    /// to `scale`-sized steps (quantize to int8, dequantize at the
    /// boundary).  None = the program never quantizes.
    aquant: Option<f32>,
}

impl Program {
    fn parse(text: &str) -> Result<Program, Error> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        if header.trim() != "STUBHLO v1" {
            return Err(Error::new(format!("bad STUBHLO header: {header:?}")));
        }
        let mut name = None;
        let mut mode = None;
        let mut nweights = None;
        let mut seed = 0u64;
        let mut out = None;
        let mut aquant = None;
        for line in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut tok = line.split_whitespace();
            let key = tok.next().unwrap_or("");
            let bad = || Error::new(format!("bad STUBHLO line: {line:?}"));
            match key {
                "name" => name = Some(tok.next().ok_or_else(bad)?.to_string()),
                "mode" => {
                    mode = Some(match tok.next().ok_or_else(bad)? {
                        "rowwise" => Mode::Rowwise,
                        "whole" => Mode::Whole,
                        _ => return Err(bad()),
                    })
                }
                "nweights" => {
                    nweights =
                        Some(tok.next().ok_or_else(bad)?.parse::<usize>().map_err(|_| bad())?)
                }
                "seed" => seed = tok.next().ok_or_else(bad)?.parse::<u64>().map_err(|_| bad())?,
                "out" => {
                    out = Some(match tok.next().ok_or_else(bad)? {
                        "like" => OutSpec::LikeAct(
                            tok.next().ok_or_else(bad)?.parse().map_err(|_| bad())?,
                        ),
                        "elems" => OutSpec::Elems(
                            tok.next().ok_or_else(bad)?.parse().map_err(|_| bad())?,
                        ),
                        _ => return Err(bad()),
                    })
                }
                "aquant" => {
                    let s: f32 =
                        tok.next().ok_or_else(bad)?.parse().map_err(|_| bad())?;
                    if !(s.is_finite() && s > 0.0) {
                        return Err(bad());
                    }
                    aquant = Some(s);
                }
                _ => return Err(bad()),
            }
        }
        Ok(Program {
            name: name.ok_or_else(|| Error::new("STUBHLO: missing name"))?,
            mode: mode.ok_or_else(|| Error::new("STUBHLO: missing mode"))?,
            nweights: nweights.ok_or_else(|| Error::new("STUBHLO: missing nweights"))?,
            seed,
            out: out.ok_or_else(|| Error::new("STUBHLO: missing out"))?,
            aquant,
        })
    }
}

// FNV-1a style fold + splitmix finalizer: cheap, deterministic, and
// platform-independent (pure integer ops; floats enter via to_bits).
const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

fn mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x100000001b3)
}

fn fin(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Map a digest to an exactly-representable f32 in [-0.5, 0.5).
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32) / ((1u64 << 24) as f32) - 0.5
}

/// Parsed HLO module: either a STUBHLO program or opaque real HLO.
#[derive(Debug)]
pub struct HloModuleProto {
    program: Option<Program>,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::new(format!("hlo text not found: {}: {e}", p.display())))?;
        if text.starts_with("STUBHLO") {
            Ok(HloModuleProto { program: Some(Program::parse(&text)?) })
        } else {
            Ok(HloModuleProto { program: None })
        }
    }
}

/// An XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    program: Option<Program>,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { program: proto.program.clone() }
    }
}

/// A compiled executable: in the stub, an interpretable program.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    program: Program,
    stats: Arc<DeviceStats>,
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        let p = &self.program;
        self.stats.check_fault(FaultOp::Dispatch, &p.name)?;
        if args.len() <= p.nweights {
            return Err(Error::new(format!(
                "{}: {} args but program declares {} weights",
                p.name,
                args.len(),
                p.nweights
            )));
        }
        let (weights, acts) = args.split_at(p.nweights);

        // Per-dispatch fixed cost: digest every weight buffer.  This is
        // what micro-batching amortizes across the batch.
        let mut wdig = mix(FNV_OFFSET, p.seed);
        for w in weights {
            wdig = w.data.fold(wdig, 0, w.data.len());
        }

        let (rows, rowlen) = match p.mode {
            Mode::Rowwise => {
                let a0 = acts
                    .first()
                    .ok_or_else(|| Error::new(format!("{}: no activations", p.name)))?;
                let b = *a0
                    .dims
                    .first()
                    .ok_or_else(|| Error::new(format!("{}: rank-0 activation", p.name)))?;
                if b == 0 || a0.data.len() % b != 0 {
                    return Err(Error::new(format!(
                        "{}: bad batch dim {b} for {} elements",
                        p.name,
                        a0.data.len()
                    )));
                }
                let rowlen = match p.out {
                    OutSpec::LikeAct(i) => {
                        let a = acts.get(i).ok_or_else(|| {
                            Error::new(format!("{}: out like {i} out of range", p.name))
                        })?;
                        a.data.len() / b
                    }
                    OutSpec::Elems(e) => e,
                };
                (b, rowlen)
            }
            Mode::Whole => {
                let rowlen = match p.out {
                    OutSpec::LikeAct(i) => {
                        acts.get(i)
                            .ok_or_else(|| {
                                Error::new(format!("{}: out like {i} out of range", p.name))
                            })?
                            .data
                            .len()
                    }
                    OutSpec::Elems(e) => e,
                };
                (1usize, rowlen)
            }
        };

        // fp32 digests above are untouched by quantization: the int8
        // round-trip happens at the graph *output* boundary, after the
        // deterministic function of weights and activations.
        let quant = match p.aquant {
            Some(s) if self.stats.activation_quant() => Some(s),
            _ => None,
        };

        let mut out = vec![0f32; rows * rowlen];
        for r in 0..rows {
            let mut rd = FNV_OFFSET;
            for a in acts {
                let al = a.data.len();
                let batched = p.mode == Mode::Rowwise
                    && a.dims.first() == Some(&rows)
                    && al % rows == 0;
                if batched {
                    let rl = al / rows;
                    rd = a.data.fold(rd, r * rl, (r + 1) * rl);
                } else {
                    rd = a.data.fold(rd, 0, al);
                }
            }
            let base = fin(mix(wdig, rd));
            let row = &mut out[r * rowlen..(r + 1) * rowlen];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = unit(fin(base ^ (j as u64).wrapping_mul(GOLDEN)));
            }
        }

        if let Some(s) = quant {
            for v in &mut out {
                *v = (*v / s).round().clamp(-127.0, 127.0) * s;
            }
            self.stats.quantized_dispatches.fetch_add(1, Ordering::Relaxed);
        }

        let bytes = (4 * rows * rowlen) as u64;
        self.stats.charge(bytes, &format!("{} output", p.name))?;
        self.stats.record_execution(&p.name, rows as u64);
        Ok(vec![vec![PjRtBuffer {
            data: BufData::Tuple(vec![out]),
            dims: vec![rows, rowlen],
            bytes,
            stats: Arc::clone(&self.stats),
        }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unet_program() -> Program {
        Program::parse(
            "STUBHLO v1\nname unet\nmode rowwise\nnweights 1\nseed 7\nout like 0\n",
        )
        .unwrap()
    }

    fn client() -> PjRtClient {
        PjRtClient::cpu().unwrap()
    }

    fn exe(c: &PjRtClient, p: Program) -> PjRtLoadedExecutable {
        PjRtLoadedExecutable { program: p, stats: c.stats() }
    }

    #[test]
    fn buffers_hold_data_and_count_transfers() {
        let c = client();
        assert!(c.platform_name().contains("stub"));
        let b = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None)
            .unwrap();
        assert_eq!(b.dims(), &[2]);
        assert_eq!(c.stats().transfers(), 1);
        assert_eq!(c.stats().transfer_bytes(), 8);
        // shape mismatch is rejected
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[2], None).is_err());
    }

    #[test]
    fn write_from_host_rewrites_in_place() {
        let c = client();
        let mut b = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None)
            .unwrap();
        b.write_from_host::<f32>(&[3.0, 4.0]).unwrap();
        assert_eq!(c.stats().writes(), 1);
        assert_eq!(c.stats().transfers(), 1, "no new buffer was created");
        // length and dtype mismatches are rejected
        assert!(b.write_from_host::<f32>(&[1.0]).is_err());
        assert!(b.write_from_host::<i32>(&[1, 2]).is_err());
    }

    #[test]
    fn missing_hlo_file_is_an_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }

    #[test]
    fn stubhlo_parses_and_opaque_hlo_does_not_compile() {
        let p = unet_program();
        assert_eq!(p.name, "unet");
        assert_eq!(p.nweights, 1);
        let c = client();
        let opaque = XlaComputation { program: None };
        assert!(c.compile(&opaque).is_err());
        let ok = XlaComputation { program: Some(p) };
        assert!(c.compile(&ok).is_ok());
        assert!(Program::parse("HloModule m\n").is_err());
        assert!(Program::parse("STUBHLO v1\nname x\n").is_err(), "missing fields");
    }

    #[test]
    fn rowwise_rows_depend_only_on_row_content() {
        let c = client();
        let e = exe(&c, unet_program());
        let w = c.buffer_from_host_buffer::<f32>(&[0.5; 8], &[8], None).unwrap();

        // batch of 2 rows
        let lat2 = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0, 4.0], &[2, 2], None)
            .unwrap();
        let t2 = c
            .buffer_from_host_buffer::<f32>(&[9.0, 9.0], &[2], None)
            .unwrap();
        let out2 = e.execute_b(&[&w, &lat2, &t2]).unwrap();
        let lit = out2[0][0].to_literal_sync().unwrap();
        let tup = lit.to_tuple().unwrap();
        let y2 = tup[0].to_vec::<f32>().unwrap();
        assert_eq!(y2.len(), 4);

        // the same rows run solo reproduce the batched rows bit-for-bit
        for r in 0..2 {
            let lat1 = c
                .buffer_from_host_buffer::<f32>(&[1.0 + 2.0 * r as f32, 2.0 + 2.0 * r as f32], &[1, 2], None)
                .unwrap();
            let t1 = c.buffer_from_host_buffer::<f32>(&[9.0], &[1], None).unwrap();
            let out1 = e.execute_b(&[&w, &lat1, &t1]).unwrap();
            let y1 = out1[0][0]
                .to_literal_sync()
                .unwrap()
                .to_tuple()
                .unwrap()[0]
                .to_vec::<f32>()
                .unwrap();
            assert_eq!(y1, y2[r * 2..(r + 1) * 2].to_vec(), "row {r}");
        }
        assert_eq!(c.stats().executions_of("unet"), 3);
        assert_eq!(c.stats().rows_of("unet"), 4);
    }

    #[test]
    fn outputs_vary_with_weights_inputs_and_seed() {
        let c = client();
        let e = exe(&c, unet_program());
        let run = |wv: f32, lv: f32| {
            let w = c.buffer_from_host_buffer::<f32>(&[wv; 4], &[4], None).unwrap();
            let l = c
                .buffer_from_host_buffer::<f32>(&[lv, lv], &[1, 2], None)
                .unwrap();
            let t = c.buffer_from_host_buffer::<f32>(&[1.0], &[1], None).unwrap();
            e.execute_b(&[&w, &l, &t]).unwrap()[0][0]
                .to_literal_sync()
                .unwrap()
                .to_tuple()
                .unwrap()[0]
                .to_vec::<f32>()
                .unwrap()
        };
        let a = run(0.1, 1.0);
        assert_eq!(a, run(0.1, 1.0), "deterministic");
        assert_ne!(a, run(0.2, 1.0), "weights matter");
        assert_ne!(a, run(0.1, 2.0), "inputs matter");
        assert!(a.iter().all(|v| (-0.5..=0.5).contains(v)));
    }

    #[test]
    fn activation_quant_round_trips_outputs_within_half_a_step() {
        let quant_program = || {
            Program::parse(
                "STUBHLO v1\nname unet\nmode rowwise\nnweights 1\nseed 7\n\
                 out like 0\naquant 0.00390625\n",
            )
            .unwrap()
        };
        let scale = 0.00390625f32;
        let run = |c: &PjRtClient, p: Program| -> Vec<f32> {
            let e = exe(c, p);
            let w = c.buffer_from_host_buffer::<f32>(&[0.5; 4], &[4], None).unwrap();
            let l = c
                .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0, 4.0], &[2, 2], None)
                .unwrap();
            let t = c.buffer_from_host_buffer::<f32>(&[9.0, 9.0], &[2], None).unwrap();
            e.execute_b(&[&w, &l, &t]).unwrap()[0][0]
                .to_literal_sync()
                .unwrap()
                .to_tuple()
                .unwrap()[0]
                .to_vec::<f32>()
                .unwrap()
        };

        // toggle off: an aquant program runs full precision
        let c = client();
        let full = run(&c, quant_program());
        assert_eq!(full, run(&c, unet_program()), "off = bit-identical to fp32");
        assert_eq!(c.stats().quantized_dispatches(), 0);

        // toggle on: outputs snap to the int8 grid, within scale/2
        c.stats().set_activation_quant(true);
        assert!(c.stats().activation_quant());
        let q = run(&c, quant_program());
        assert_ne!(full, q, "quantization changed the bits");
        for (a, b) in full.iter().zip(&q) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-7, "{a} vs {b}");
            let steps = b / scale;
            assert!((steps - steps.round()).abs() < 1e-3, "on the grid: {b}");
        }
        assert_eq!(c.stats().quantized_dispatches(), 1);

        // programs without a scale are untouched even when toggled on
        assert_eq!(run(&c, unet_program()), full);
        assert_eq!(c.stats().quantized_dispatches(), 1);

        // bad scales fail to parse
        assert!(Program::parse(
            "STUBHLO v1\nname x\nmode whole\nnweights 0\nout elems 1\naquant 0\n"
        )
        .is_err());
        assert!(Program::parse(
            "STUBHLO v1\nname x\nmode whole\nnweights 0\nout elems 1\naquant nah\n"
        )
        .is_err());
    }

    #[test]
    fn scheduled_faults_fire_at_exact_attempts() {
        let c = client();
        c.stats().set_fault_plan(Some(
            FaultPlan::seeded(1)
                .fail_nth(FaultOp::Dispatch, 2, FaultKind::Transient)
                .fail_nth(FaultOp::Dispatch, 3, FaultKind::DeviceLost)
                .fail_nth(FaultOp::Transfer, 4, FaultKind::Oom),
        ));
        let e = exe(&c, unet_program());
        let w = c.buffer_from_host_buffer::<f32>(&[0.5; 4], &[4], None).unwrap();
        let l = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[1, 2], None)
            .unwrap();
        let t = c.buffer_from_host_buffer::<f32>(&[9.0], &[1], None).unwrap();

        assert!(e.execute_b(&[&w, &l, &t]).is_ok(), "dispatch #1 passes");
        let err = e.execute_b(&[&w, &l, &t]).unwrap_err();
        assert_eq!(err.fault_kind(), Some(FaultKind::Transient), "#2 faults");
        let err = e.execute_b(&[&w, &l, &t]).unwrap_err();
        assert_eq!(err.fault_kind(), Some(FaultKind::DeviceLost), "#3 faults");
        assert!(e.execute_b(&[&w, &l, &t]).is_ok(), "#4 passes");

        // transfer #4 (three uploads already happened above)
        let err = c
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], None)
            .unwrap_err();
        assert_eq!(err.fault_kind(), Some(FaultKind::Oom));

        assert_eq!(c.stats().injected_transient(), 1);
        assert_eq!(c.stats().injected_fatal(), 2);
        assert_eq!(c.stats().injected_faults(), 3);
        // only successful dispatches were counted as executions
        assert_eq!(c.stats().executions_of("unet"), 2);

        // clearing the plan stops injection
        c.stats().set_fault_plan(None);
        assert!(e.execute_b(&[&w, &l, &t]).is_ok());
    }

    #[test]
    fn rate_faults_are_seed_deterministic() {
        let faulted = |seed: u64| -> Vec<bool> {
            let c = client();
            c.stats().set_fault_plan(Some(
                FaultPlan::seeded(seed).transient_dispatch_rate(0.3),
            ));
            let e = exe(&c, unet_program());
            let w =
                c.buffer_from_host_buffer::<f32>(&[0.5; 4], &[4], None).unwrap();
            let l = c
                .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[1, 2], None)
                .unwrap();
            let t =
                c.buffer_from_host_buffer::<f32>(&[9.0], &[1], None).unwrap();
            (0..32).map(|_| e.execute_b(&[&w, &l, &t]).is_err()).collect()
        };
        let a = faulted(7);
        assert_eq!(a, faulted(7), "same seed, same schedule");
        assert_ne!(a, faulted(8), "different seed, different schedule");
        assert!(a.iter().any(|&f| f), "rate 0.3 over 32 dispatches fires");
        assert!(!a.iter().all(|&f| f), "and lets most through");
    }

    #[test]
    fn fault_spec_parses_and_rejects_garbage() {
        let p = FaultPlan::parse("dispatch:5:transient,compile:2:fatal,rate:0.1,spike:8:2", 3)
            .unwrap();
        assert_eq!(p.seed, 3);
        assert_eq!(p.scheduled.len(), 2);
        assert_eq!(p.scheduled[0], (FaultOp::Dispatch, 5, FaultKind::Transient));
        assert_eq!(p.dispatch_fault_rate, 0.1);
        assert_eq!((p.spike_every, p.spike_ms), (8, 2));
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
        assert!(FaultPlan::parse("dispatch:x:transient", 0).is_err());
        assert!(FaultPlan::parse("poke:1:transient", 0).is_err());
        assert!(FaultPlan::parse("dispatch:1:weird", 0).is_err());
    }

    #[test]
    fn memory_accounting_tracks_live_buffers_even_uncapped() {
        let c = client();
        assert_eq!(c.stats().device_mem(), None);
        let a = c
            .buffer_from_host_buffer::<f32>(&[0.0; 4], &[4], None)
            .unwrap();
        let b = c
            .buffer_from_host_buffer::<f32>(&[0.0; 2], &[2], None)
            .unwrap();
        assert_eq!(c.stats().mem_used(), 24);
        assert_eq!(c.stats().mem_peak(), 24);
        drop(a);
        assert_eq!(c.stats().mem_used(), 8);
        assert_eq!(c.stats().mem_peak(), 24, "peak is a high-water mark");
        drop(b);
        assert_eq!(c.stats().mem_used(), 0);
        assert_eq!(c.stats().oom_rejections(), 0);
    }

    #[test]
    fn capacity_cap_rejects_with_oom_and_recovers_on_drop() {
        let c = client();
        c.stats().set_device_mem(Some(24));
        assert_eq!(c.stats().device_mem(), Some(24));
        let a = c
            .buffer_from_host_buffer::<f32>(&[0.0; 4], &[4], None)
            .unwrap();
        // 16 of 24 B in use: a 12 B upload must fail, organically
        let err = c
            .buffer_from_host_buffer::<f32>(&[0.0; 3], &[3], None)
            .unwrap_err();
        assert_eq!(err.fault_kind(), Some(FaultKind::Oom));
        assert_eq!(c.stats().oom_rejections(), 1);
        assert_eq!(c.stats().mem_used(), 16, "rejected alloc left no charge");
        assert_eq!(
            c.stats().injected_fatal(),
            0,
            "capacity OOM is not a scheduled fault"
        );
        // dropping the resident buffer restores headroom
        drop(a);
        assert!(c.buffer_from_host_buffer::<f32>(&[0.0; 3], &[3], None).is_ok());
        // clearing the cap lifts the limit but keeps accounting
        c.stats().set_device_mem(None);
        assert!(c.buffer_from_host_buffer::<f32>(&[0.0; 64], &[64], None).is_ok());
    }

    #[test]
    fn dispatch_outputs_are_charged_and_can_oom() {
        let c = client();
        let e = exe(&c, unet_program());
        let w = c.buffer_from_host_buffer::<f32>(&[0.5; 4], &[4], None).unwrap();
        let l = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[1, 2], None)
            .unwrap();
        let t = c.buffer_from_host_buffer::<f32>(&[9.0], &[1], None).unwrap();
        let inputs = c.stats().mem_used();
        // out like 0 => 2 elements = 8 B for the output tuple
        c.stats().set_device_mem(Some(inputs + 4));
        let err = e.execute_b(&[&w, &l, &t]).unwrap_err();
        assert_eq!(err.fault_kind(), Some(FaultKind::Oom));
        assert_eq!(c.stats().executions_of("unet"), 0, "OOM'd dispatch not counted");
        c.stats().set_device_mem(Some(inputs + 8));
        let out = e.execute_b(&[&w, &l, &t]).unwrap();
        assert_eq!(c.stats().mem_used(), inputs + 8);
        drop(out);
        assert_eq!(c.stats().mem_used(), inputs);
    }

    #[test]
    fn copy_into_reuses_capacity() {
        let lit = Literal::F32(vec![1.0, 2.0, 3.0]);
        let mut out = Vec::with_capacity(8);
        lit.copy_into_f32(&mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        assert!(out.capacity() >= 8, "capacity retained");
        assert!(Literal::Tuple(vec![]).copy_into_f32(&mut out).is_err());
    }
}
