//! Offline stub of the `xla-rs` PJRT binding surface used by
//! `mobile-diffusion`.
//!
//! The real crate links against the XLA/PJRT shared library, which is
//! not available in this build environment.  This stub mirrors the
//! exact API the runtime layer calls so the workspace type-checks and
//! every non-device test runs; any call that would need a real device
//! (compile, buffer upload, execute) returns [`Error`] with a clear
//! message.  The integration tests gate themselves on the presence of
//! built artifacts, so they skip cleanly under the stub.
//!
//! To run against real hardware, replace the `xla = { path = ... }`
//! dependency in `rust/Cargo.toml` with the actual bindings; no source
//! change in `mobile-diffusion` is required.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str =
    "PJRT unavailable: built against the vendored xla stub (see rust/vendor/xla)";

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    pub fn new(message: impl Into<String>) -> Error {
        Error { message: message.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>() -> Result<T, Error> {
    Err(Error::new(STUB_MSG))
}

/// Element types accepted by raw-byte buffer uploads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S8,
    S32,
    F32,
}

/// Host-native types accepted by typed buffer uploads / downloads.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i8 {}
impl NativeType for u8 {}

/// A PJRT device handle (opaque; never instantiated by the stub).
#[derive(Debug)]
pub struct PjRtDevice {
    _private: (),
}

/// A PJRT client.  `cpu()` succeeds so hosts can construct engines and
/// report a platform name; all device work fails with a stub error.
#[derive(Debug)]
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { platform: "cpu (xla stub)".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        stub_err()
    }

    pub fn buffer_from_host_raw_bytes(
        &self,
        _ty: ElementType,
        _data: &[u8],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        stub_err()
    }
}

/// Parsed HLO module (the stub only checks the file is readable).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error::new(format!("hlo text not found: {}", p.display())));
        }
        Ok(HloModuleProto { _private: () })
    }
}

/// An XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

/// A device buffer (never constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

/// A host literal (never constructed by the stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_device_calls_fail() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[1], None).is_err());
        assert!(c
            .buffer_from_host_raw_bytes(ElementType::S8, &[1u8], &[1], None)
            .is_err());
    }

    #[test]
    fn missing_hlo_file_is_an_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
