//! Property tests for the planner's core contract, over generated
//! graphs and every registered device class:
//!
//! * the raw pass pipeline never *decreases* delegation coverage;
//! * the planner's cost-gated plan never decreases coverage **and**
//!   never increases modeled latency (the gate enforces it per pass,
//!   whatever the pipeline does on a given device class).

use mobile_diffusion::delegate::RuleSet;
use mobile_diffusion::graph::builder::random_graph;
use mobile_diffusion::passes;
use mobile_diffusion::planner::{modeled_cost_s, plan_graph, registered_devices};
use mobile_diffusion::util::miniprop::forall;
use mobile_diffusion::util::rng::Rng;

#[test]
fn pass_pipeline_never_decreases_coverage_on_any_device() {
    let rules = RuleSet::default();
    forall("pipeline coverage monotone", 30, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        for spec in registered_devices() {
            let mut g = random_graph(&mut Rng::new(seed), n_ops);
            let before = rules.coverage(&g);
            let report = passes::run_all_for(&mut g, &spec.delegate);
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(
                report.coverage_after >= before - 1e-12,
                "device {}: coverage {} -> {} (seed {seed:#x}, {n_ops} ops)",
                spec.name,
                before,
                report.coverage_after
            );
        }
    });
}

#[test]
fn planner_never_increases_modeled_latency_on_any_device() {
    let rules = RuleSet::default();
    forall("plan never worse", 30, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        let g = random_graph(&mut Rng::new(seed), n_ops);
        for spec in registered_devices() {
            let cost_before = modeled_cost_s(&g, &rules, &spec);
            let cov_before = rules.coverage(&g);
            let planned = plan_graph(&g, &rules, &spec);
            planned
                .graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(
                planned.coverage >= cov_before - 1e-12,
                "device {}: planned coverage {} < {} (seed {seed:#x}, {n_ops} ops)",
                spec.name,
                planned.coverage,
                cov_before
            );
            assert!(
                planned.cost_s <= cost_before + 1e-12,
                "device {}: planned cost {} > {} (seed {seed:#x}, {n_ops} ops, passes {:?})",
                spec.name,
                planned.cost_s,
                cost_before,
                planned.passes_used
            );
        }
    });
}

#[test]
fn planner_beats_the_unplanned_graph_where_it_matters() {
    // not just "never worse": on the GPU-delegate class the planner
    // must actually claw back the paper's islands
    let rules = RuleSet::default();
    let spec = registered_devices()
        .into_iter()
        .find(|d| d.name == "adreno740")
        .unwrap();
    let g = mobile_diffusion::planner::model::unet_graph("base").unwrap();
    let planned = plan_graph(&g, &rules, &spec);
    assert!(planned.cost_s < modeled_cost_s(&g, &rules, &spec));
    assert_eq!(planned.coverage, 1.0);
    assert!(planned.rewrites > 0);
}
