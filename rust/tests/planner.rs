//! Property tests for the planner's core contract, over generated
//! graphs and every registered device class:
//!
//! * the raw pass pipeline never *decreases* delegation coverage;
//! * the planner's cost-gated plan never decreases coverage **and**
//!   never increases modeled latency (the gate enforces it per pass,
//!   whatever the pipeline does on a given device class);
//! * the calibration fit recovers any plausible true roofline from
//!   roofline-exact dispatch observations;
//! * the same never-worse contract holds under *any* calibrated
//!   overlay, not just the shipped constants.

use mobile_diffusion::delegate::{OpClass, RoofParams, RuleSet, GPU_ADRENO740};
use mobile_diffusion::graph::builder::random_graph;
use mobile_diffusion::passes;
use mobile_diffusion::planner::{
    modeled_cost_cal, modeled_cost_s, plan_graph, plan_graph_cal, registered_devices,
    CalibratedProfile, Calibrator, Observation,
};
use mobile_diffusion::util::miniprop::forall;
use mobile_diffusion::util::rng::Rng;

#[test]
fn pass_pipeline_never_decreases_coverage_on_any_device() {
    let rules = RuleSet::default();
    forall("pipeline coverage monotone", 30, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        for spec in registered_devices() {
            let mut g = random_graph(&mut Rng::new(seed), n_ops);
            let before = rules.coverage(&g);
            let report = passes::run_all_for(&mut g, &spec.delegate);
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(
                report.coverage_after >= before - 1e-12,
                "device {}: coverage {} -> {} (seed {seed:#x}, {n_ops} ops)",
                spec.name,
                before,
                report.coverage_after
            );
        }
    });
}

#[test]
fn planner_never_increases_modeled_latency_on_any_device() {
    let rules = RuleSet::default();
    forall("plan never worse", 30, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        let g = random_graph(&mut Rng::new(seed), n_ops);
        for spec in registered_devices() {
            let cost_before = modeled_cost_s(&g, &rules, &spec);
            let cov_before = rules.coverage(&g);
            let planned = plan_graph(&g, &rules, &spec);
            planned
                .graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(
                planned.coverage >= cov_before - 1e-12,
                "device {}: planned coverage {} < {} (seed {seed:#x}, {n_ops} ops)",
                spec.name,
                planned.coverage,
                cov_before
            );
            assert!(
                planned.cost_s <= cost_before + 1e-12,
                "device {}: planned cost {} > {} (seed {seed:#x}, {n_ops} ops, passes {:?})",
                spec.name,
                planned.cost_s,
                cost_before,
                planned.passes_used
            );
        }
    });
}

#[test]
fn calibration_fit_recovers_any_plausible_true_roofline() {
    // synthesize roofline-exact dispatch observations from a random
    // "true" device triple and check the alternating fit walks from
    // the shipped constants to the truth
    forall("calibration fit converges", 30, |prop| {
        let truth = RoofParams {
            flops: prop.f64_in(1e10, 1e12),
            bandwidth: prop.f64_in(1e9, 1e11),
            dispatch: prop.f64_in(1e-6, 1e-4),
        };
        let mut cal = Calibrator::new(GPU_ADRENO740);
        for i in 0..48 {
            // alternate compute-bound, memory-bound and near-pure
            // dispatch work, scaled to the truth so every parameter
            // is identified whatever triple was drawn
            let (flops, bytes) = match i % 3 {
                0 => (truth.flops * 1e-3 * (1.0 + i as f64), 1.0),
                1 => (1.0, truth.bandwidth * 1e-3 * (1.0 + i as f64)),
                _ => (1.0, 1.0),
            };
            let seconds =
                truth.dispatch + (flops / truth.flops).max(bytes / truth.bandwidth);
            cal.record(Observation { class: OpClass::Matmul, flops, bytes, seconds });
        }
        let fitted = cal
            .fit()
            .fitted(OpClass::Matmul)
            .expect("48 samples clear the per-class minimum");
        assert!(
            (fitted.flops - truth.flops).abs() / truth.flops < 0.05,
            "flops: fitted {fitted:?} vs truth {truth:?}"
        );
        assert!(
            (fitted.bandwidth - truth.bandwidth).abs() / truth.bandwidth < 0.05,
            "bandwidth: fitted {fitted:?} vs truth {truth:?}"
        );
        assert!(
            (fitted.dispatch - truth.dispatch).abs() / truth.dispatch < 0.10,
            "dispatch: fitted {fitted:?} vs truth {truth:?}"
        );
    });
}

#[test]
fn planner_never_worse_under_any_calibrated_overlay() {
    // the never-worse contract must hold when the cost gate prices
    // ops through an arbitrary calibrated overlay, not just the
    // shipped constants — calibration can flip *which* passes pay
    // off, never make the plan regress
    let rules = RuleSet::default();
    let registry = passes::PassRegistry::standard();
    forall("calibrated plan never worse", 30, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        let params = RoofParams {
            flops: prop.f64_in(1e10, 2e12),
            bandwidth: prop.f64_in(1e9, 1e11),
            dispatch: prop.f64_in(1e-7, 1e-4),
        };
        let g = random_graph(&mut Rng::new(seed), n_ops);
        for spec in registered_devices() {
            let cal = CalibratedProfile::uniform(spec.delegate.clone(), params);
            let cost_before = modeled_cost_cal(&g, &rules, &spec, Some(&cal));
            let cov_before = rules.coverage(&g);
            let planned = plan_graph_cal(&g, &rules, &spec, &registry, Some(&cal));
            planned
                .graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert!(
                planned.coverage >= cov_before - 1e-12,
                "device {}: calibrated coverage {} < {} (seed {seed:#x}, {params:?})",
                spec.name,
                planned.coverage,
                cov_before
            );
            assert!(
                planned.cost_s <= cost_before + 1e-12,
                "device {}: calibrated cost {} > {} (seed {seed:#x}, {params:?}, passes {:?})",
                spec.name,
                planned.cost_s,
                cost_before,
                planned.passes_used
            );
        }
    });
}

#[test]
fn planner_beats_the_unplanned_graph_where_it_matters() {
    // not just "never worse": on the GPU-delegate class the planner
    // must actually claw back the paper's islands
    let rules = RuleSet::default();
    let spec = registered_devices()
        .into_iter()
        .find(|d| d.name == "adreno740")
        .unwrap();
    let g = mobile_diffusion::planner::model::unet_graph("base").unwrap();
    let planned = plan_graph(&g, &rules, &spec);
    assert!(planned.cost_s < modeled_cost_s(&g, &rules, &spec));
    assert_eq!(planned.coverage, 1.0);
    assert!(planned.rewrites > 0);
}
