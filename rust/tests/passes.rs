//! Contract tests for the pattern-rewrite engine and the registered
//! pass set:
//!
//! * miniprop properties: every registered pass — alone, built for
//!   every registered device class — preserves `Graph::validate`,
//!   preserves graph-output shapes/dtypes, and never increases the
//!   delegate-rule failure count (absolute coverage; the fraction is
//!   denominator-sensitive when a fusion deletes delegable ops, which
//!   is exactly why the planner's cost gate judges the fraction and
//!   these tests judge the failure count);
//! * the cost-gated plan never decreases coverage on any registered
//!   device, fusions included;
//! * the migrated passes report bit-identical rewrite counts vs. the
//!   seed pipeline on the SD variant graphs;
//! * the new fusions strictly reduce modeled latency on the
//!   GPU-delegate class without reducing coverage anywhere.

use mobile_diffusion::delegate::RuleSet;
use mobile_diffusion::graph::builder::random_graph;
use mobile_diffusion::graph::{DType, Graph, TensorId};
use mobile_diffusion::passes::{self, PassRegistry};
use mobile_diffusion::planner::{
    model, modeled_cost_s, plan_graph, plan_graph_with, registered_devices,
};
use mobile_diffusion::util::miniprop::forall;
use mobile_diffusion::util::rng::Rng;

/// Graph outputs: produced, unconsumed, non-const tensors.
fn graph_outputs(g: &Graph) -> Vec<(TensorId, Vec<usize>, DType)> {
    let producers = g.producers();
    let consumers = g.consumers();
    g.tensors
        .iter()
        .filter(|t| {
            !t.is_const && producers[t.id].is_some() && consumers[t.id].is_empty()
        })
        .map(|t| (t.id, t.shape.clone(), t.dtype))
        .collect()
}

#[test]
fn every_pass_preserves_validity_outputs_and_failure_count() {
    let rules = RuleSet::default();
    forall("pass contract", 24, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 22);
        for spec in registered_devices() {
            for pass_spec in PassRegistry::standard().specs() {
                let mut g = random_graph(&mut Rng::new(seed), n_ops);
                let outputs_before = graph_outputs(&g);
                let failures_before = rules.failures(&g).len();

                let pass = pass_spec.build(&rules, &spec.delegate);
                pass.run(&mut g);

                g.validate().unwrap_or_else(|e| {
                    panic!("{} on {}: {e} (seed {seed:#x})", pass_spec.name, spec.name)
                });
                // graph outputs keep identity, shape, and dtype
                let producers = g.producers();
                for (t, shape, dtype) in &outputs_before {
                    assert!(
                        producers[*t].is_some(),
                        "{} on {}: output {t} unproduced (seed {seed:#x})",
                        pass_spec.name,
                        spec.name
                    );
                    assert_eq!(
                        &g.tensor(*t).shape, shape,
                        "{} on {}: output {t} shape (seed {seed:#x})",
                        pass_spec.name, spec.name
                    );
                    assert_eq!(
                        g.tensor(*t).dtype, *dtype,
                        "{} on {}: output {t} dtype (seed {seed:#x})",
                        pass_spec.name, spec.name
                    );
                }
                // delegate coverage in absolute terms never regresses
                assert!(
                    rules.failures(&g).len() <= failures_before,
                    "{} on {}: failures {} -> {} (seed {seed:#x}, {n_ops} ops)",
                    pass_spec.name,
                    spec.name,
                    failures_before,
                    rules.failures(&g).len()
                );
            }
        }
    });
}

#[test]
fn cost_gated_plans_never_decrease_coverage_on_any_device() {
    let rules = RuleSet::default();
    forall("plan coverage monotone with fusions", 20, |prop| {
        let seed = prop.seed();
        let n_ops = prop.usize_in(5, 20);
        let g = random_graph(&mut Rng::new(seed), n_ops);
        for spec in registered_devices() {
            let cov_before = rules.coverage(&g);
            let cost_before = modeled_cost_s(&g, &rules, &spec);
            let planned = plan_graph(&g, &rules, &spec);
            assert!(
                planned.coverage >= cov_before - 1e-12,
                "{}: coverage {} -> {} (seed {seed:#x})",
                spec.name,
                cov_before,
                planned.coverage
            );
            assert!(
                planned.cost_s <= cost_before + 1e-12,
                "{}: cost {} -> {} (seed {seed:#x}, passes {:?})",
                spec.name,
                cost_before,
                planned.cost_s,
                planned.passes_used
            );
        }
    });
}

/// The seed pipeline's per-pass rewrite counts on the SD variant
/// component graphs, pinned: the migrated engine must reproduce them
/// bit-identically.  Counts are definitionally what the hand-rolled
/// traversals rewrote — one per FC, one per naive group-norm island,
/// one per unstable GELU, one per delegate-rejected k>1 conv — plus
/// the two new fusions' sites on the attention export debris.
fn expected_counts(graph_name: &str) -> Vec<(&'static str, usize)> {
    match graph_name {
        "unet_base" => vec![
            ("groupnorm-broadcast-free", 2),
            ("fc-to-conv", 6),
            ("serialize-conv", 1),
            ("stable-gelu", 1),
            ("fused-softmax", 1),
            ("attention-reshape-elim", 2),
        ],
        "unet_mobile" => vec![
            ("groupnorm-broadcast-free", 2),
            ("fc-to-conv", 6),
            ("serialize-conv", 0),
            ("stable-gelu", 1),
            ("fused-softmax", 1),
            // K-path transpose pair, V-path reshape pair, and the
            // proj/ff1 round trip fc_to_conv leaves behind
            ("attention-reshape-elim", 3),
        ],
        "text_encoder" => vec![
            ("groupnorm-broadcast-free", 0),
            ("fc-to-conv", 2),
            ("serialize-conv", 0),
            ("stable-gelu", 1),
            ("fused-softmax", 0),
            ("attention-reshape-elim", 0),
        ],
        "decoder" => vec![
            ("groupnorm-broadcast-free", 1),
            ("fc-to-conv", 0),
            ("serialize-conv", 0),
            ("stable-gelu", 0),
            ("fused-softmax", 0),
            ("attention-reshape-elim", 0),
        ],
        other => panic!("no expected counts for {other}"),
    }
}

#[test]
fn migrated_passes_report_bit_identical_counts_on_the_variant_graphs() {
    for variant in model::VARIANTS {
        let (unet, text, dec) = model::component_graphs(variant).unwrap();
        for mut g in [unet, text, dec] {
            let expected = expected_counts(&g.name.clone());
            let report = passes::run_all(&mut g);
            assert_eq!(
                report.applied, expected,
                "rewrite counts changed on {}",
                g.name
            );
            g.validate().unwrap();
        }
    }
}

#[test]
fn op_histograms_are_stable_on_the_variant_graphs() {
    // the full pipeline's output shape, pinned coarsely: no BroadcastTo,
    // no FullyConnected, no rank-5, exactly one fused softmax on the
    // unets, and no leftover exp/sum/div island
    use mobile_diffusion::graph::OpType;
    for variant in model::VARIANTS {
        let mut g = model::unet_graph(variant).unwrap();
        passes::run_all(&mut g);
        let hist = g.op_histogram();
        assert_eq!(hist.get(&OpType::BroadcastTo), None, "{variant}");
        assert_eq!(hist.get(&OpType::FullyConnected), None, "{variant}");
        assert_eq!(hist[&OpType::FusedSoftmax], 1, "{variant}");
        assert_eq!(hist.get(&OpType::Exp), None, "{variant}");
        assert_eq!(hist.get(&OpType::Sum), None, "{variant}");
        assert_eq!(hist.get(&OpType::Div), None, "{variant}");
        assert!(g.max_rank() <= 4, "{variant}");
    }
}

#[test]
fn fusions_strictly_reduce_modeled_latency_without_coverage_loss() {
    let rules = RuleSet::default();
    let fusions = ["fused_softmax", "attention_reshape_elim"];
    let gpu = registered_devices()
        .into_iter()
        .find(|d| d.name == "adreno740")
        .unwrap();
    for variant in model::VARIANTS {
        let g = model::unet_graph(variant).unwrap();
        let without = plan_graph_with(
            &g,
            &rules,
            &gpu,
            &PassRegistry::standard().without(&fusions),
        );
        let with = plan_graph(&g, &rules, &gpu);
        // strictly faster on the GPU-delegate class...
        assert!(
            with.cost_s < without.cost_s,
            "{variant}: fused {} !< unfused {}",
            with.cost_s,
            without.cost_s
        );
        assert!(with.passes_used.contains(&"fused_softmax"), "{variant}");
        assert!(
            with.passes_used.contains(&"attention_reshape_elim"),
            "{variant}"
        );
        // ...without losing coverage there or anywhere else (the cost
        // gate rejects a fusion wherever it would)
        assert!(with.coverage >= without.coverage - 1e-12, "{variant}");
        for spec in registered_devices() {
            let planned = plan_graph(&g, &rules, &spec);
            assert!(
                planned.coverage >= rules.coverage(&g) - 1e-12,
                "{variant} on {}",
                spec.name
            );
        }
    }
}

#[test]
fn unplanned_pipeline_still_reaches_complete_delegation_on_base() {
    // the unconditional CLI pipeline (fusions included) keeps the
    // paper's headline: complete delegation on the base UNet
    let rules = RuleSet::default();
    let mut g = model::unet_graph("base").unwrap();
    assert!(rules.coverage(&g) < 1.0);
    let report = passes::run_all(&mut g);
    assert_eq!(report.coverage_after, 1.0, "{:?}", report.applied);
}
