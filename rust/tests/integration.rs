//! Integration tests over the real AOT artifacts.
//!
//! These require `make artifacts` to have run; every test is skipped
//! (with a message) when artifacts/ is absent so `cargo test` stays
//! green on a fresh checkout.

use std::path::{Path, PathBuf};

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{Server, SubmitOptions};
use mobile_diffusion::delegate::{RuleSet, Verdict};
use mobile_diffusion::graph;
use mobile_diffusion::passes;
use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::quant::WeightFile;
use mobile_diffusion::runtime::{ActInput, Component, Engine, Manifest};
use mobile_diffusion::scheduler::{Ddim, Sampler};
use mobile_diffusion::tokenizer;
use mobile_diffusion::util::stats;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

// ---------------------------------------------------------------- manifest

#[test]
fn manifest_loads_and_is_complete() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for comp in [
        "text_encoder",
        "unet_base",
        "unet_mobile",
        "decoder",
        "block_fp",
        "block_w8",
        "block_w8p",
    ] {
        let c = m.component(comp).unwrap();
        assert!(m.hlo_path(c).exists(), "{comp} hlo missing");
        assert!(!c.params.is_empty(), "{comp} has params");
    }
    assert_eq!(m.scheduler.alphas_cumprod.len(), 1000);
}

#[test]
fn tokenizer_matches_python_goldens() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    assert!(!m.tokenizer.golden.is_empty());
    for (text, want) in &m.tokenizer.golden {
        let got = tokenizer::encode(text, m.tokenizer.vocab_size, m.tokenizer.seq_len);
        assert_eq!(&got, want, "prompt {text:?}");
    }
}

#[test]
fn scheduler_matches_python_golden_trace() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let ddim = Ddim::from_alphas(m.scheduler.params.clone(), m.scheduler.alphas_cumprod.clone());

    // the Rust beta schedule must agree with the manifest's table
    let own = Ddim::new(m.scheduler.params.clone());
    for (a, b) in own.alphas_cumprod.iter().zip(&m.scheduler.alphas_cumprod) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }
    assert_eq!(
        ddim.timesteps(m.scheduler.params.num_inference_steps),
        m.scheduler.timesteps
    );

    // golden DDIM replay: eps := 0.1 * latent per step
    let g = &m.scheduler.golden;
    let mut latent: Vec<f32> = g.latent0.iter().map(|&v| v as f32).collect();
    let ts = &m.scheduler.timesteps;
    for (i, row) in g.trace.iter().enumerate() {
        let eps: Vec<f32> = latent.iter().map(|&v| v * g.eps_scale as f32).collect();
        let t_prev = ts.get(i + 1).copied();
        ddim.step(&mut latent, &eps, ts[i], t_prev);
        for (a, &b) in latent.iter().zip(row) {
            assert!((*a as f64 - b).abs() < 1e-4, "step {i}: {a} vs {b}");
        }
    }
}

#[test]
fn scheduler_matches_python_golden_multistep_trace() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let g = &m.scheduler.golden;
    if g.multistep_trace.is_empty() {
        eprintln!("skipping: manifest predates the sampler family");
        return;
    }
    let ddim = Ddim::from_alphas(m.scheduler.params.clone(), m.scheduler.alphas_cumprod.clone());

    // golden DPM-Solver++(2M) replay: the full 8-step schedule with the
    // same eps := eps_scale * latent surrogate; the whole schedule is
    // checked because the eps history makes later rows depend on every
    // earlier one
    let sampler = Sampler::Dpm2m;
    let ts = sampler.schedule(&ddim, 8);
    assert_eq!(g.multistep_trace.len(), ts.len());
    let mut latent: Vec<f32> = g.latent0.iter().map(|&v| v as f32).collect();
    let mut history: Vec<Vec<f32>> = Vec::new();
    for (i, row) in g.multistep_trace.iter().enumerate() {
        let eps: Vec<f32> = latent.iter().map(|&v| v * g.eps_scale as f32).collect();
        let t_prev = ts.get(i + 1).copied();
        let t_last = if i > 0 { Some(ts[i - 1]) } else { None };
        sampler.step(&ddim, &mut latent, &eps, &history, ts[i], t_prev, t_last);
        sampler.remember(&mut history, &eps);
        for (a, &b) in latent.iter().zip(row) {
            assert!((*a as f64 - b).abs() < 1e-4, "step {i}: {a} vs {b}");
        }
    }
}

// ---------------------------------------------------------------- weights

#[test]
fn weight_files_parse_and_int8_is_smaller() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let c = m.component("unet_mobile").unwrap();
    let fp = WeightFile::load(&m.weight_path(c, "fp32").unwrap()).unwrap();
    let q = WeightFile::load(&m.weight_path(c, "int8").unwrap()).unwrap();
    assert_eq!(fp.tensors.len(), q.tensors.len());
    assert_eq!(fp.tensors.len(), c.params.len());
    let ratio = fp.stored_bytes() as f64 / q.stored_bytes() as f64;
    assert!(ratio > 3.0, "int8 should be ~4x smaller, got {ratio:.2}");

    // dequantized int8 close to fp32 on a conv weight
    let key = fp
        .tensors
        .keys()
        .find(|k| k.ends_with("conv_in/w"))
        .unwrap()
        .clone();
    let a = fp.tensors[&key].to_f32();
    let b = q.tensors[&key].to_f32();
    let rel = stats::max_abs_diff(&a, &b)
        / a.iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
    assert!(rel < 0.01, "dequant error {rel}");
}

// ---------------------------------------------------------------- graphs

#[test]
fn sd_v21_graph_reproduces_paper_failures() {
    let dir = require_artifacts!();
    let g = graph::load(&dir.join("sd_v21_unet.graph.json")).unwrap();
    let rules = RuleSet::default();
    let failures = rules.failures(&g);

    // exactly one failing k>1 conv: the paper's 1920 -> 640 at 32x32
    let conv_fails: Vec<_> = failures
        .iter()
        .filter(|(_, v)| matches!(v, Verdict::ConvTooLarge { .. }))
        .collect();
    assert_eq!(conv_fails.len(), 1, "{conv_fails:?}");
    let (op, _) = conv_fails[0];
    let x = g.tensor(op.inputs[0]);
    assert_eq!(x.shape, vec![1, 32, 32, 1920]);

    // the paper's FC failure exists
    assert!(failures
        .iter()
        .any(|(_, v)| matches!(v, Verdict::FcTooManyRows(4096))));
}

#[test]
fn passes_fully_delegate_all_export_graphs() {
    let dir = require_artifacts!();
    for name in [
        "sd_v21_unet",
        "sd_v21_text_encoder",
        "sd_v21_decoder",
        "small_unet",
        "small_text_encoder",
        "small_decoder",
    ] {
        let mut g = graph::load(&dir.join(format!("{name}.graph.json"))).unwrap();
        let report = passes::run_all(&mut g);
        g.validate().unwrap();
        // GATHER (embedding lookup) legitimately stays on CPU in the text
        // encoders (true of the real delegate); everything else delegates.
        let rules = RuleSet::default();
        let non_gather: Vec<_> = rules
            .failures(&g)
            .into_iter()
            .filter(|(op, _)| op.ty != mobile_diffusion::graph::OpType::Gather)
            .map(|(op, v)| (op.name.clone(), v))
            .collect();
        assert!(non_gather.is_empty(), "{name}: {non_gather:?}");
        assert!(report.coverage_after >= report.coverage_before, "{name}");
    }
}

// ---------------------------------------------------------------- runtime

#[test]
fn text_encoder_round_trip() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::new().unwrap();
    let comp = m.component("text_encoder").unwrap();
    let te = Component::load(&engine, &m, comp, "fp32").unwrap();
    let ids = tokenizer::encode("hello world", m.tokenizer.vocab_size, m.tokenizer.seq_len);
    let out = te.run(&engine, &[ActInput::i32(ids.clone())]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m.tokenizer.seq_len * 128);
    assert!(out[0].iter().all(|v| v.is_finite()));
    // determinism
    let out2 = te.run(&engine, &[ActInput::i32(ids)]).unwrap();
    assert_eq!(out[0], out2[0]);
    // different prompt -> different embedding
    let ids3 = tokenizer::encode("something else", m.tokenizer.vocab_size, m.tokenizer.seq_len);
    let out3 = te.run(&engine, &[ActInput::i32(ids3)]).unwrap();
    assert!(stats::max_abs_diff(&out[0], &out3[0]) > 1e-4);
}

#[test]
fn unet_variants_agree_subtly() {
    // paper Fig. 2: serialized conv + stable GELU + broadcast-free GN
    // change the output only subtly
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::new().unwrap();
    let base = Component::load(&engine, &m, m.component("unet_base").unwrap(), "fp32").unwrap();
    let mobile =
        Component::load(&engine, &m, m.component("unet_mobile").unwrap(), "fp32").unwrap();

    let n = m.latent_size * m.latent_size * m.latent_channels;
    let mut rng = mobile_diffusion::util::rng::Rng::new(42);
    let latent2: Vec<f32> = rng.normal_f32_vec(2 * n);
    let ctx: Vec<f32> = rng.normal_f32_vec(2 * m.tokenizer.seq_len * 128);
    let acts = |l: &Vec<f32>, c: &Vec<f32>| {
        vec![
            ActInput::F32(l.clone()),
            ActInput::F32(vec![500.0]),
            ActInput::F32(c.clone()),
        ]
    };
    let a = base.run(&engine, &acts(&latent2, &ctx)).unwrap();
    let b = mobile.run(&engine, &acts(&latent2, &ctx)).unwrap();
    assert_eq!(a[0].len(), 2 * n);
    let scale = a[0].iter().fold(0f32, |m, v| m.max(v.abs())) as f64;
    let diff = stats::max_abs_diff(&a[0], &b[0]);
    assert!(diff / scale < 1e-3, "variants diverge: {diff} / {scale}");
    assert!(diff > 0.0, "variants must not be bit-identical");
}

#[test]
fn block_reconstruction_error_ordering() {
    // paper Fig. 5 metric: err(quant) <= err(quant+prune), both small
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::new().unwrap();
    let fp = Component::load(&engine, &m, m.component("block_fp").unwrap(), "fp32").unwrap();
    let w8 = Component::load(&engine, &m, m.component("block_w8").unwrap(), "fp32").unwrap();
    let w8p = Component::load(&engine, &m, m.component("block_w8p").unwrap(), "fp32").unwrap();

    let c = 128;
    let size = m.latent_size / 2;
    let mut rng = mobile_diffusion::util::rng::Rng::new(7);
    let x: Vec<f32> = rng.normal_f32_vec(size * size * c);
    let ctx: Vec<f32> = rng.normal_f32_vec(m.tokenizer.seq_len * 128);
    let run = |comp: &Component| {
        comp.run(
            &engine,
            &[ActInput::F32(x.clone()), ActInput::F32(ctx.clone())],
        )
        .unwrap()[0]
            .clone()
    };
    let y_fp = run(&fp);
    let e_q = stats::mse(&y_fp, &run(&w8));
    let e_qp = stats::mse(&y_fp, &run(&w8p));
    let signal = stats::mse(&y_fp, &vec![0.0; y_fp.len()]);
    assert!(e_q > 0.0);
    assert!(e_qp >= e_q, "pruning adds error: {e_qp} vs {e_q}");
    assert!(e_q / signal < 0.05, "quant error should be small: {}", e_q / signal);
}

// ---------------------------------------------------------------- pipeline

#[test]
fn pipelined_generation_end_to_end() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let opts = ExecOptions { num_steps: 2, ..Default::default() };
    let mut ex = PipelinedExecutor::new(m, opts).unwrap();
    let r = ex.generate("a tiny test image", 1, "mobile").unwrap();
    assert_eq!(r.image.len(), r.image_size * r.image_size * 3);
    assert!(r.image.iter().all(|v| v.is_finite()));
    assert_eq!(r.timings.denoise_steps, 2);
    assert!(r.peak_memory > 0);
    // trace must show the text encoder evicted before the decoder peak
    let s = ex.memory_trace().render_ascii(30);
    assert!(s.contains("+text_encoder"));
    assert!(s.contains("-text_encoder"));
    assert!(s.contains("+decoder"));
}

#[test]
fn pipelined_peak_below_naive_peak() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();

    let mut ex = PipelinedExecutor::new(
        m.clone(),
        ExecOptions { num_steps: 2, pipelined: true, ..Default::default() },
    )
    .unwrap();
    let r_pipe = ex.generate("peak test", 3, "mobile").unwrap();

    let mut ex2 = PipelinedExecutor::new(
        m,
        ExecOptions { num_steps: 2, pipelined: false, ..Default::default() },
    )
    .unwrap();
    let r_naive = ex2.generate("peak test", 3, "mobile").unwrap();

    assert!(
        r_pipe.peak_memory < r_naive.peak_memory,
        "pipelined {} < naive {}",
        r_pipe.peak_memory,
        r_naive.peak_memory
    );
    // identical seeds and weights -> identical latents regardless of
    // load order
    assert_eq!(r_pipe.latent, r_naive.latent);
}

#[test]
fn budget_enforcement_fails_naive_but_allows_pipelined() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    // budget: unet + decoder + slack, but NOT unet + text + decoder
    let unet = m.component("unet_mobile").unwrap().weights["fp32"].bytes;
    let text = m.component("text_encoder").unwrap().weights["fp32"].bytes;
    let dec = m.component("decoder").unwrap().weights["fp32"].bytes;
    let budget = unet + text.max(dec) + 1_000_000;
    assert!(budget < unet + text + dec, "test needs a binding budget");

    let mut ex = PipelinedExecutor::new(
        m.clone(),
        ExecOptions {
            num_steps: 2,
            pipelined: true,
            memory_budget: budget,
            ..Default::default()
        },
    )
    .unwrap();
    ex.generate("fits", 5, "mobile").unwrap();

    let mut ex2 = PipelinedExecutor::new(
        m,
        ExecOptions {
            num_steps: 2,
            pipelined: false,
            memory_budget: budget,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(ex2.generate("does not fit", 5, "mobile").is_err());
}

// ---------------------------------------------------------------- server

#[test]
fn server_serves_fifo_requests() {
    let dir = require_artifacts!();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 2;
    let mut server = Server::start(&cfg).unwrap();
    let r1 = server.generate("first", 1).unwrap();
    let r2 = server.generate("second", 2).unwrap();
    assert_eq!(r1.id, 1);
    assert_eq!(r2.id, 2);
    assert!(r1.image.iter().all(|v| v.is_finite()));
    let report = server.metrics_report().unwrap();
    assert!(report.contains("2 ok"), "{report}");
}

#[test]
fn pool_serves_concurrent_requests_with_overrides_within_budget() {
    // acceptance: 4 concurrent requests on a 2-worker pool, per-request
    // num_steps overrides respected, per-worker peak within budget
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let unet = m.component("unet_mobile").unwrap().weights["fp32"].bytes;
    let text = m.component("text_encoder").unwrap().weights["fp32"].bytes;
    let dec = m.component("decoder").unwrap().weights["fp32"].bytes;
    let budget = unet + text.max(dec) + 1_000_000;

    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 2;
    cfg.num_workers = 2;
    cfg.memory_budget_mb = budget as f64 / 1e6;
    let mut server = Server::start(&cfg).unwrap();

    let steps = [None, Some(3), None, Some(4)];
    let receivers: Vec<_> = steps
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let opts = SubmitOptions { num_steps: *s, ..Default::default() };
            server.submit_with("pool overrides", i as u64, opts).unwrap()
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.timings.denoise_steps, steps[i].unwrap_or(2), "request {i}");
        assert!(resp.worker_id < 2);
        assert!(
            resp.peak_memory <= budget,
            "worker peak {} within budget {budget}",
            resp.peak_memory
        );
    }
    let report = server.metrics_report().unwrap();
    assert!(report.contains("2 workers"), "{report}");
    assert!(report.contains("4 ok"), "{report}");
}

#[test]
fn deterministic_across_restarts() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let run = |m: &Manifest| {
        let mut ex = PipelinedExecutor::new(
            m.clone(),
            ExecOptions { num_steps: 2, ..Default::default() },
        )
        .unwrap();
        ex.generate("determinism", 99, "mobile").unwrap().latent
    };
    assert_eq!(run(&m), run(&m));
}
