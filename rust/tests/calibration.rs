//! Closing the cost-model loop on the stub backend: W8A8 activation
//! quantization end to end through the real executor, and the
//! calibrated re-plan shrinking predicted-vs-actual error.
//!
//! Pinned invariants:
//! * with the W8A8 toggle on, every artifact dispatch round-trips its
//!   outputs through the int8 grid — the final image lands exactly on
//!   multiples of the stub scale and dispatches are counted;
//! * batched generation stays bit-identical to solo runs *with
//!   quantization enabled* — the int8 round-trip is elementwise and
//!   deterministic, so the batching parity contract survives it;
//! * a plan rebuilt from a fitted calibration predicts the true step
//!   latency far closer than the shipped constants do.

use std::path::Path;

use mobile_diffusion::delegate::{OpClass, RoofParams};
use mobile_diffusion::pipeline::{
    BatchRequest, ExecOptions, ExecOverrides, PipelinedExecutor,
};
use mobile_diffusion::planner::{
    device_spec, CalibratedProfile, Calibrator, Observation, PlanRegistry,
    MIN_CLASS_SAMPLES,
};
use mobile_diffusion::quant::stub_activation_scale;
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

fn executor(dir: &Path, num_steps: usize) -> PipelinedExecutor {
    let m = Manifest::load(dir).unwrap();
    PipelinedExecutor::new(m, ExecOptions { num_steps, ..Default::default() }).unwrap()
}

#[test]
fn w8a8_quantizes_every_dispatch_and_lands_outputs_on_the_int8_grid() {
    let dir = testkit::fake_artifacts_dir("w8a8-grid", &small_spec()).unwrap();
    let steps = 4;

    // toggle off: artifacts carry the aquant scale but it stays inert
    let mut full = executor(&dir, steps);
    let rf = full
        .generate_with("a lighthouse", 7, "mobile", &ExecOverrides::default())
        .unwrap();
    assert_eq!(full.engine.device_stats().quantized_dispatches(), 0);

    let mut q = executor(&dir, steps);
    q.engine.device_stats().set_activation_quant(true);
    let rq = q
        .generate_with("a lighthouse", 7, "mobile", &ExecOverrides::default())
        .unwrap();
    let stats = q.engine.device_stats();
    // cond + uncond text encode, one UNet dispatch per step, decode
    assert!(
        stats.quantized_dispatches() >= steps as u64 + 3,
        "every stage dispatch went through the round-trip: {}",
        stats.quantized_dispatches()
    );
    assert_ne!(rf.image, rq.image, "quantization changed the bits");

    // the decode dispatch quantizes last, so each final value sits on
    // the int8 grid: k * scale for integer k in [-127, 127] — the
    // per-dispatch error bound itself (<= scale/2 against the same
    // inputs) is pinned in the vendored stub's own tests
    let scale = stub_activation_scale();
    for (i, v) in rq.image.iter().enumerate() {
        let k = v / scale;
        assert!(
            (k - k.round()).abs() < 1e-3 && k.round().abs() <= 127.0,
            "image[{i}] = {v} off the int8 grid (scale {scale})"
        );
    }
}

#[test]
fn batched_parity_survives_w8a8() {
    let dir = testkit::fake_artifacts_dir("w8a8-parity", &small_spec()).unwrap();
    let steps = 3;
    let prompts = ["a puppy", "a bowl of ramen"];

    let mut solo_images = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        let mut ex = executor(&dir, steps);
        ex.engine.device_stats().set_activation_quant(true);
        let r = ex
            .generate_with(prompt, i as u64 + 1, "mobile", &ExecOverrides::default())
            .unwrap();
        solo_images.push(r.image);
    }

    let mut ex = executor(&dir, steps);
    ex.engine.device_stats().set_activation_quant(true);
    let reqs: Vec<BatchRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| BatchRequest {
            prompt: p.to_string(),
            seed: i as u64 + 1,
            overrides: ExecOverrides::default(),
        })
        .collect();
    let results = ex.generate_batch(&reqs, "mobile");
    assert!(ex.engine.device_stats().quantized_dispatches() > 0);
    for (i, r) in results.into_iter().enumerate() {
        assert_eq!(
            r.unwrap().image,
            solo_images[i],
            "request {i}: quantized batch matches quantized solo bit-for-bit"
        );
    }
}

#[test]
fn calibrated_replan_shrinks_predicted_vs_actual_step_error() {
    let spec = device_spec("bigcore").expect("registered device");
    let reg = PlanRegistry::new();
    let shipped = reg.plan(&spec, "mobile").unwrap();
    assert!(!shipped.calibrated);

    // ground truth: the silicon really sustains 3x the shipped flops,
    // 2x the bandwidth, half the dispatch overhead
    let base = spec.delegate.clone();
    let truth = RoofParams {
        flops: base.flops * 3.0,
        bandwidth: base.bandwidth * 2.0,
        dispatch: base.dispatch / 2.0,
    };
    let actual = reg
        .replan(&spec, "mobile", &CalibratedProfile::uniform(base.clone(), truth))
        .unwrap()
        .step_latency_s;

    let err_shipped = (shipped.step_latency_s - actual).abs() / actual;

    // feed the calibrator roofline-exact observations drawn from the
    // truth, as the executor's dispatch observer would
    let mut cal = Calibrator::new(base);
    for &class in OpClass::ALL {
        for i in 0..(3 * MIN_CLASS_SAMPLES) {
            let (flops, bytes) = match i % 3 {
                0 => (1e9 * (1.0 + i as f64), 1e3),
                1 => (1e3, 1e7 * (1.0 + i as f64)),
                _ => (1e3, 1e3),
            };
            let seconds =
                truth.dispatch + (flops / truth.flops).max(bytes / truth.bandwidth);
            cal.record(Observation { class, flops, bytes, seconds });
        }
    }
    let prof = cal.fit();
    assert!(prof.is_calibrated());
    let replanned = reg.replan(&spec, "mobile", &prof).unwrap();
    assert!(replanned.calibrated);

    let err_cal = (replanned.step_latency_s - actual).abs() / actual;
    assert!(
        err_cal < err_shipped * 0.2,
        "calibration shrank the prediction error: {err_cal:.4} vs shipped {err_shipped:.4}"
    );
    assert!(reg.replans() >= 2);
}
