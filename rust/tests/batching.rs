//! Micro-batching acceptance tests on the stub backend: synthetic
//! STUBHLO artifacts (see `mobile_diffusion::testkit`) drive the real
//! executor, pool and server — real buffers, real dispatch counts, no
//! PJRT and no Python.
//!
//! Pinned invariants:
//! * batched generation is bit-identical to solo runs with the same
//!   seeds (per-request guidance and schedules included);
//! * a batch of B issues ONE UNet dispatch per step, not B;
//! * after warmup the step loop creates no new device buffers (it
//!   rewrites the existing plan in place);
//! * the uncond text context is encoded once and reused across
//!   requests until evicted;
//! * B=4 beats B=1 on throughput, recorded to BENCH_throughput.json.

use std::path::Path;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::pipeline::{
    BatchRequest, ExecOptions, ExecOverrides, PipelinedExecutor,
};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::scheduler::Sampler;
use mobile_diffusion::testkit::{self, throughput, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

fn executor(dir: &Path, num_steps: usize) -> PipelinedExecutor {
    let m = Manifest::load(dir).unwrap();
    PipelinedExecutor::new(m, ExecOptions { num_steps, ..Default::default() }).unwrap()
}

fn batch_req(prompt: &str, seed: u64, overrides: ExecOverrides) -> BatchRequest {
    BatchRequest { prompt: prompt.to_string(), seed, overrides }
}

#[test]
fn batched_b4_matches_solo_bit_for_bit_with_one_dispatch_per_step() {
    let dir = testkit::fake_artifacts_dir("parity", &small_spec()).unwrap();
    let steps = 6;
    let prompts = ["an astronaut", "a lighthouse", "a bowl of ramen", "a puppy"];
    let guidances = [7.5, 2.0, 7.5, 11.0];

    // four solo runs, fresh executor each (cold caches)
    let mut solo_latents = Vec::new();
    let mut solo_images = Vec::new();
    let mut solo_unet_dispatches = 0;
    for (i, prompt) in prompts.iter().enumerate() {
        let mut ex = executor(&dir, steps);
        let ov = ExecOverrides {
            guidance_scale: Some(guidances[i]),
            ..Default::default()
        };
        let r = ex.generate_with(prompt, i as u64 + 1, "mobile", &ov).unwrap();
        assert_eq!(r.timings.denoise_steps, steps);
        solo_latents.push(r.latent);
        solo_images.push(r.image);
        solo_unet_dispatches += ex.engine.device_stats().executions_of("unet_mobile");
    }
    assert_eq!(solo_unet_dispatches, 4 * steps as u64, "solo: one dispatch per step each");

    // the same four requests as one batch
    let mut ex = executor(&dir, steps);
    let reqs: Vec<BatchRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            batch_req(
                p,
                i as u64 + 1,
                ExecOverrides { guidance_scale: Some(guidances[i]), ..Default::default() },
            )
        })
        .collect();
    let results = ex.generate_batch(&reqs, "mobile");
    assert_eq!(results.len(), 4);
    let stats = ex.engine.device_stats();
    assert_eq!(
        stats.executions_of("unet_mobile"),
        steps as u64,
        "batched: ONE dispatch per step for the whole batch"
    );
    assert_eq!(stats.rows_of("unet_mobile"), (steps * 2 * 4) as u64);
    for (i, r) in results.into_iter().enumerate() {
        let r = r.unwrap();
        assert_eq!(r.latent, solo_latents[i], "request {i}: latents bit-identical");
        assert_eq!(r.image, solo_images[i], "request {i}: images bit-identical");
        assert_eq!(r.timings.denoise_steps, steps);
    }
}

#[test]
fn every_sampler_is_batch_invariant_bit_for_bit() {
    // acceptance: batch-of-4 equals four solo runs for EVERY member of
    // the sampler family — the multistep eps history and the distilled
    // fixed schedules must be per-row state, invisible to batching
    let dir = testkit::fake_artifacts_dir("samplerparity", &small_spec()).unwrap();
    let steps = 6;
    let prompts = ["an astronaut", "a lighthouse", "a bowl of ramen", "a puppy"];
    let mut ddim_latents: Vec<Vec<f32>> = Vec::new();

    for sampler in Sampler::ALL {
        let ov = |_: usize| ExecOverrides { sampler: Some(sampler), ..Default::default() };

        let mut solo = Vec::new();
        for (i, prompt) in prompts.iter().enumerate() {
            let mut ex = executor(&dir, steps);
            solo.push(ex.generate_with(prompt, i as u64 + 1, "mobile", &ov(i)).unwrap());
        }

        let mut ex = executor(&dir, steps);
        let reqs: Vec<BatchRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| batch_req(p, i as u64 + 1, ov(i)))
            .collect();
        let results = ex.generate_batch(&reqs, "mobile");
        let want_steps = sampler.effective_steps(steps);
        let stats = ex.engine.device_stats();
        assert_eq!(
            stats.executions_of("unet_mobile"),
            want_steps as u64,
            "{}: one dispatch per step for the whole batch",
            sampler.name()
        );
        for (i, r) in results.into_iter().enumerate() {
            let r = r.unwrap();
            assert_eq!(r.timings.denoise_steps, want_steps, "{} request {i}", sampler.name());
            assert_eq!(
                r.latent,
                solo[i].latent,
                "{} request {i}: batched latent bit-identical to solo",
                sampler.name()
            );
            assert_eq!(
                r.image,
                solo[i].image,
                "{} request {i}: batched image bit-identical to solo",
                sampler.name()
            );
            match sampler {
                Sampler::Ddim => ddim_latents.push(r.latent),
                Sampler::Dpm2m => assert_ne!(
                    r.latent, ddim_latents[i],
                    "request {i}: the second-order solver must change the trajectory"
                ),
                _ => {}
            }
        }
    }
}

#[test]
fn per_request_guidance_differentiates_within_a_batch() {
    let dir = testkit::fake_artifacts_dir("guidance", &small_spec()).unwrap();
    let mut ex = executor(&dir, 4);
    let reqs = vec![
        batch_req("same prompt", 9, ExecOverrides { guidance_scale: Some(1.0), ..Default::default() }),
        batch_req("same prompt", 9, ExecOverrides { guidance_scale: Some(9.0), ..Default::default() }),
    ];
    let results = ex.generate_batch(&reqs, "mobile");
    let a = results[0].as_ref().unwrap();
    let b = results[1].as_ref().unwrap();
    assert_ne!(a.latent, b.latent, "guidance is per-request inside one dispatch");
}

#[test]
fn stragglers_with_fewer_steps_finish_and_match_solo() {
    let dir = testkit::fake_artifacts_dir("straggler", &small_spec()).unwrap();
    let step_counts = [3usize, 8, 8];

    let mut solo = Vec::new();
    for (i, &n) in step_counts.iter().enumerate() {
        let mut ex = executor(&dir, 20);
        let ov = ExecOverrides { num_steps: Some(n), ..Default::default() };
        solo.push(ex.generate_with("straggler", i as u64, "mobile", &ov).unwrap());
    }

    let mut ex = executor(&dir, 20);
    let reqs: Vec<BatchRequest> = step_counts
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            batch_req(
                "straggler",
                i as u64,
                ExecOverrides { num_steps: Some(n), ..Default::default() },
            )
        })
        .collect();
    let results = ex.generate_batch(&reqs, "mobile");
    let stats = ex.engine.device_stats();
    // steps 0..3 run at B=3, steps 3..8 at B=2: still one dispatch per
    // step index, 8 total
    assert_eq!(stats.executions_of("unet_mobile"), 8);
    assert_eq!(stats.rows_of("unet_mobile"), (3 * 2 * 3 + 5 * 2 * 2) as u64);
    for (i, r) in results.into_iter().enumerate() {
        let r = r.unwrap();
        assert_eq!(r.timings.denoise_steps, step_counts[i], "request {i}");
        assert_eq!(r.latent, solo[i].latent, "request {i}: straggler parity");
    }
}

#[test]
fn step_loop_creates_no_device_buffers_after_warmup() {
    let dir = testkit::fake_artifacts_dir("zeroalloc", &small_spec()).unwrap();

    // identical work except for the step count: any per-step buffer
    // creation would make the longer run's transfer count higher
    let run = |steps: usize| {
        let mut ex = executor(&dir, steps);
        ex.generate("warmup probe", 5, "mobile").unwrap();
        let st = ex.engine.device_stats();
        (st.transfers(), st.writes(), st.executions_of("unet_mobile"))
    };
    let (transfers_short, writes_short, d_short) = run(2);
    let (transfers_long, writes_long, d_long) = run(12);
    assert_eq!(d_short, 2);
    assert_eq!(d_long, 12);
    assert_eq!(
        transfers_long, transfers_short,
        "after warmup, steps rewrite buffers in place — zero new device buffers"
    );
    assert_eq!(
        writes_long - writes_short,
        2 * 10,
        "each extra step = exactly one latent + one timestep in-place write"
    );
}

#[test]
fn uncond_context_is_cached_until_evicted() {
    let dir = testkit::fake_artifacts_dir("uncond", &small_spec()).unwrap();
    let mut ex = executor(&dir, 2);
    let stats = ex.engine.device_stats();

    ex.generate("first", 1, "mobile").unwrap();
    assert_eq!(stats.executions_of("text_encoder"), 2, "cond + uncond");
    ex.generate("second", 2, "mobile").unwrap();
    assert_eq!(stats.executions_of("text_encoder"), 3, "uncond came from cache");
    ex.generate("third", 3, "mobile").unwrap();
    assert_eq!(stats.executions_of("text_encoder"), 4);

    // eviction invalidates the cached context
    ex.evict_idle();
    ex.generate("fourth", 4, "mobile").unwrap();
    assert_eq!(stats.executions_of("text_encoder"), 6, "re-encoded after evict");
}

#[test]
fn mixed_variants_run_in_separate_groups() {
    let dir = testkit::fake_artifacts_dir("variants", &small_spec()).unwrap();
    let mut ex = executor(&dir, 3);
    let reqs = vec![
        batch_req("a", 1, ExecOverrides::default()),
        batch_req("b", 2, ExecOverrides { variant: Some("base".into()), ..Default::default() }),
        batch_req("c", 3, ExecOverrides::default()),
    ];
    let results = ex.generate_batch(&reqs, "mobile");
    assert!(results.iter().all(|r| r.is_ok()));
    let stats = ex.engine.device_stats();
    assert_eq!(stats.executions_of("unet_mobile"), 3, "requests 0+2 batched");
    assert_eq!(stats.executions_of("unet_base"), 3, "request 1 ran solo");

    // variants produce different outputs for the same seed/prompt
    let m = results[0].as_ref().unwrap();
    let b = results[1].as_ref().unwrap();
    assert_ne!(m.latent, b.latent);
}

#[test]
fn server_pool_batches_end_to_end() {
    let dir = testkit::fake_artifacts_dir("serverpool", &small_spec()).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 3;
    cfg.num_workers = 1;
    cfg.max_batch = 4;
    let mut server = Server::start(&cfg).unwrap();

    let receivers: Vec<_> = (0..4)
        .map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap())
        .collect();
    for rx in receivers {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.timings.denoise_steps, 3);
        assert!(resp.image.iter().all(|v| v.is_finite()));
    }
    let report = server.metrics_report().unwrap();
    assert!(report.contains("4 ok"), "{report}");
    server.with_metrics(|m| {
        assert!(m.batches >= 1 && m.batches <= 4, "batched dispatching happened");
        assert!(m.max_batch_occupancy >= 1);
    });
}

#[test]
fn throughput_b4_beats_b1_and_emits_bench_json() {
    // the acceptance bench in fast mode, run under tier-1 so the
    // recorded numbers always come from the shipped code
    let wl = throughput::Workload::new(true);
    let rows = throughput::run_profile("tier1_throughput", &wl, &[1, 2, 4]).unwrap();
    assert_eq!(rows.len(), 3);
    let b1 = &rows[0];
    let b4 = &rows[2];
    assert!(b4.mean_occupancy > 1.0, "B=4 actually co-scheduled requests");
    assert!(
        b4.images_per_s > b1.images_per_s,
        "B=4 ({:.2} img/s) must beat B=1 ({:.2} img/s)",
        b4.images_per_s,
        b1.images_per_s
    );

    let json = throughput::to_json(&rows, true);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_throughput.json");
    std::fs::write(&out, &json).unwrap();
    assert!(json.contains("\"images_per_s\""));
}
