//! Chaos acceptance tests: the fault-injected device runtime under a
//! deterministic fault schedule — transient dispatch faults, a worker
//! panic, and a quarantined class.
//!
//! Pinned invariants:
//! * every submitted request resolves **exactly once** — one terminal
//!   reply per receiver, a second `recv` always disconnects, and the
//!   ok/failed counters sum to the submission count (nothing lost,
//!   nothing double-completed);
//! * a row that was interrupted by an injected transient fault and
//!   retried from its checkpoint is bit-identical to a fault-free run;
//! * a worker panic is supervised (engine rebuilt, queue keeps
//!   draining) and the in-flight caller gets an explicit error, never
//!   a hang;
//! * a class whose devices keep faulting is quarantined by its breaker
//!   while healthy classes keep serving, and an all-degraded fleet
//!   sheds everything except high-priority probe traffic;
//! * the batching/continuous parity suites run with fault injection
//!   *disabled* — nothing here touches them.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{
    BreakerState, CircuitBreaker, GenerateRequest, Priority, Server, SubmitOptions,
    SupervisionOptions, WorkerExecutor, WorkerPool,
};
use mobile_diffusion::error::{Error, Result};
use mobile_diffusion::pipeline::{
    ExecOptions, ExecOverrides, GenerateResult, PipelinedExecutor, StageTimings,
};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::scheduler::Sampler;
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

/// Fault-free single-request baseline on a fresh executor.
fn solo(dir: &Path, prompt: &str, seed: u64, steps: usize) -> GenerateResult {
    let m = Manifest::load(dir).unwrap();
    let mut ex =
        PipelinedExecutor::new(m, ExecOptions { num_steps: 20, ..Default::default() }).unwrap();
    let ov = ExecOverrides { num_steps: Some(steps), ..Default::default() };
    ex.generate_with(prompt, seed, "mobile", &ov).unwrap()
}

/// Workers fold the device's injected-fault counters into the pool
/// metrics at batch/session boundaries, which may land just *after*
/// the last reply: bound the wait instead of racing it.
fn wait_for(cond: impl Fn() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

fn faulted_cfg(dir: std::path::PathBuf) -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 4;
    cfg.num_workers = 1;
    cfg.max_batch = 4;
    cfg.retry_backoff_ms = 1;
    cfg
}

/// The headline guarantee, across three fixed fault seeds: a schedule
/// of one guaranteed transient dispatch fault plus seeded random
/// transients and latency spikes, and still every request gets exactly
/// one terminal reply.
#[test]
fn every_request_resolves_exactly_once_under_seeded_faults() {
    for seed in [7u64, 19, 1234] {
        let dir =
            testkit::fake_artifacts_dir(&format!("chaos_seed_{seed}"), &small_spec()).unwrap();
        let mut cfg = faulted_cfg(dir);
        cfg.fault_seed = Some(seed);
        cfg.fault_spec = Some("dispatch:4:transient,rate:0.15,spike:5:1".into());
        cfg.retry_limit = 6;
        let mut server = Server::start(&cfg).unwrap();

        let receivers: Vec<_> = (0..6)
            .map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap())
            .collect();
        let (mut ok, mut failed) = (0usize, 0usize);
        for rx in receivers {
            match rx.recv().expect("every request gets a terminal reply") {
                Ok(resp) => {
                    assert!(resp.image.iter().all(|v| v.is_finite()), "seed {seed}");
                    ok += 1;
                }
                Err(_) => failed += 1,
            }
            assert!(rx.recv().is_err(), "seed {seed}: a request must never resolve twice");
        }
        assert_eq!(ok + failed, 6, "seed {seed}: nothing lost, nothing duplicated");
        server.with_metrics(|m| {
            assert_eq!(
                m.stage.requests_ok + m.stage.requests_failed,
                6,
                "seed {seed}: terminal accounting matches the submission count"
            );
            assert!(m.retries >= 1, "seed {seed}: faulted rows were retried, not dropped");
        });
        wait_for(
            || server.with_metrics(|m| m.injected_transient >= 1),
            "the scheduled dispatch fault to surface in the metrics",
        );
        let report = server.metrics_report().unwrap();
        assert!(report.contains("faults:"), "{report}");
        assert!(report.contains("breaker:"), "{report}");
    }
}

/// The recovery-correctness half: rows interrupted by an injected
/// transient dispatch fault and resumed from their checkpoint produce
/// bit-identical latents and images to an uninterrupted run.
#[test]
fn retried_rows_are_bit_identical_to_a_fault_free_run() {
    let dir = testkit::fake_artifacts_dir("chaos_parity", &small_spec()).unwrap();
    let baselines: Vec<_> =
        (0..3).map(|i| solo(&dir, &format!("prompt {i}"), i as u64, 4)).collect();

    let mut cfg = faulted_cfg(dir);
    // exactly one injected fault: the worker device's 4th dispatch
    cfg.fault_spec = Some("dispatch:4:transient".into());
    let mut server = Server::start(&cfg).unwrap();
    let receivers: Vec<_> = (0..3)
        .map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap())
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().expect("transient faults are absorbed by retry");
        assert_eq!(
            resp.latent, baselines[i].latent,
            "row {i}: a retried row must be bit-identical to an uninterrupted run"
        );
        assert_eq!(resp.image, baselines[i].image, "row {i}: decoded image diverged");
    }
    server.with_metrics(|m| {
        assert_eq!(m.stage.requests_ok, 3);
        assert_eq!(m.stage.requests_failed, 0);
        assert!(m.retries >= 1, "the interrupted rows went through the retry path");
    });
    wait_for(
        || server.with_metrics(|m| m.injected_transient >= 1),
        "the scheduled dispatch fault to surface in the metrics",
    );
    let report = server.metrics_report().unwrap();
    assert!(report.contains("faults:"), "{report}");
}

/// The same recovery guarantee under the second-order sampler: a dpm2m
/// row interrupted mid-schedule by an injected transient fault resumes
/// from a checkpoint that carries its eps history, so the retried row
/// is bit-identical to a fault-free run — and terminal accounting is
/// exact.
#[test]
fn retried_multistep_rows_resume_with_history_bit_identically() {
    let dir = testkit::fake_artifacts_dir("chaos_dpm2m", &small_spec()).unwrap();
    let baselines: Vec<_> = (0..3)
        .map(|i| {
            let m = Manifest::load(&dir).unwrap();
            let mut ex = PipelinedExecutor::new(
                m,
                ExecOptions { num_steps: 20, ..Default::default() },
            )
            .unwrap();
            let ov = ExecOverrides {
                num_steps: Some(6),
                sampler: Some(Sampler::Dpm2m),
                ..Default::default()
            };
            ex.generate_with(&format!("prompt {i}"), i as u64, "mobile", &ov).unwrap()
        })
        .collect();

    let mut cfg = faulted_cfg(dir);
    // exactly one injected fault: the worker device's 4th dispatch,
    // which lands mid-schedule where the eps history is non-empty
    cfg.fault_spec = Some("dispatch:4:transient".into());
    let mut server = Server::start(&cfg).unwrap();
    let receivers: Vec<_> = (0..3)
        .map(|i| {
            let opts = SubmitOptions {
                num_steps: Some(6),
                sampler: Some("dpm2m".into()),
                ..Default::default()
            };
            server.submit_with(&format!("prompt {i}"), i as u64, opts).unwrap()
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().expect("transient faults are absorbed by retry");
        assert_eq!(resp.timings.denoise_steps, 6, "row {i}");
        assert_eq!(
            resp.latent, baselines[i].latent,
            "row {i}: a retried multistep row must restore its eps history, not recompute it"
        );
        assert_eq!(resp.image, baselines[i].image, "row {i}: decoded image diverged");
        assert!(rx.recv().is_err(), "row {i}: exactly one terminal reply");
    }
    server.with_metrics(|m| {
        assert_eq!(m.stage.requests_ok, 3, "terminal accounting exact");
        assert_eq!(m.stage.requests_failed, 0);
        assert!(m.retries >= 1, "the interrupted rows went through the retry path");
    });
    wait_for(
        || server.with_metrics(|m| m.injected_transient >= 1),
        "the scheduled dispatch fault to surface in the metrics",
    );
    let report = server.metrics_report().unwrap();
    assert!(report.contains("samplers: dpm2m=3"), "{report}");
}

/// Pool-level chaos: one worker panic plus a class whose device always
/// faults.  The panic is supervised (executor rebuilt, later jobs keep
/// flowing), the in-flight caller gets an explicit error, the faulting
/// class exhausts its retry budget per request and is quarantined by
/// the breaker — and every caller still gets exactly one reply.
#[test]
fn a_worker_panic_and_a_quarantined_class_never_lose_requests() {
    struct ChaosExec {
        class_idx: usize,
        panicked: Arc<AtomicBool>,
    }
    impl WorkerExecutor for ChaosExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            if self.class_idx == 1 {
                return Err(Error::Transient("injected device fault".into()));
            }
            if req.id == 2 && !self.panicked.swap(true, Ordering::SeqCst) {
                panic!("injected worker crash");
            }
            Ok(GenerateResult {
                image: vec![0.0; 4],
                image_size: 2,
                latent: vec![req.seed as f32],
                timings: StageTimings { denoise_steps: 1, total_s: 0.001, ..Default::default() },
                peak_memory: 1,
            })
        }
    }

    let breaker = Arc::new(CircuitBreaker::new(2, 2, Duration::from_secs(60)));
    let panicked = Arc::new(AtomicBool::new(false));
    let supervision = SupervisionOptions {
        retry_limit: 1,
        retry_backoff: Duration::from_millis(1),
        breaker: Some(Arc::clone(&breaker)),
        ..SupervisionOptions::default()
    };
    let classes = [("healthy".to_string(), 1), ("flaky".to_string(), 1)];
    let pool = {
        let panicked = Arc::clone(&panicked);
        WorkerPool::start_supervised(&classes, 32, 1, false, supervision, move |_, class_idx, _| {
            Ok(ChaosExec { class_idx, panicked: Arc::clone(&panicked) })
        })
        .unwrap()
    };

    // class 0 in submission order: ok, panic, ok (after the rebuild)
    let healthy: Vec<_> = (1..=3)
        .map(|i| {
            pool.submit_routed(GenerateRequest::new(i, "p", i), Priority::Normal, None, 0, None)
                .unwrap()
        })
        .collect();
    // class 1: every attempt faults; the retry budget is exhausted
    let flaky: Vec<_> = (10..=11)
        .map(|i| {
            pool.submit_routed(GenerateRequest::new(i, "p", i), Priority::Normal, None, 1, None)
                .unwrap()
        })
        .collect();

    for (i, rx) in healthy.into_iter().enumerate() {
        let id = i as u64 + 1;
        let reply = rx.recv().expect("supervised workers never strand a caller");
        if id == 2 {
            let err = reply.expect_err("the in-flight request of a crashed worker fails");
            assert!(err.to_string().contains("worker died"), "{err}");
        } else {
            assert_eq!(reply.unwrap().id, id, "jobs around the crash are served");
        }
        assert!(rx.recv().is_err(), "exactly one reply per request");
    }
    for rx in flaky {
        let err = rx.recv().unwrap().expect_err("a always-faulting class fails its callers");
        assert!(err.to_string().contains("gave up"), "{err}");
        assert!(rx.recv().is_err(), "exactly one reply per request");
    }

    assert!(panicked.load(Ordering::SeqCst), "the injected panic actually fired");
    assert_eq!(breaker.state(1), BreakerState::Open, "the faulting class is quarantined");
    assert!(breaker.admits(0), "the healthy class keeps admitting");
    pool.with_metrics(|m| {
        assert_eq!(m.worker_restarts, 1, "one supervised rebuild");
        assert_eq!(m.retries, 2, "one retry per flaky-class request");
        assert_eq!(m.retries_exhausted, 2);
        assert_eq!(m.reply_orphaned, 1, "the crashed worker's in-flight request");
    });
    let report = pool.metrics_report();
    assert!(report.contains("flaky=open"), "{report}");
}

/// Degrading admission, last line: with every class quarantined the
/// server sheds normal load at the front door, while high-priority
/// requests ride through as the half-open probe traffic.
#[test]
fn tripped_breakers_shed_normal_load_but_admit_high_priority_probes() {
    let dir = testkit::fake_artifacts_dir("chaos_shed", &small_spec()).unwrap();
    let mut cfg = faulted_cfg(dir);
    cfg.num_steps = 3;
    cfg.breaker_cooldown_ms = 60_000;
    let mut server = Server::start(&cfg).unwrap();

    // healthy fleet: a normal request is served
    server.submit("warmup", 1).unwrap().recv().unwrap().unwrap();

    // operator kill switch: quarantine the only class
    server.breaker().expect("server pools run behind breakers").trip_now(0);

    let err = server.submit("best effort", 2).unwrap_err();
    assert!(err.to_string().contains("shed"), "{err}");

    let rx = server
        .submit_with("probe", 3, SubmitOptions::with_priority(Priority::High))
        .unwrap();
    rx.recv().unwrap().expect("high-priority probes are still served");

    server.with_metrics(|m| {
        assert_eq!(m.shed, 1, "the shed was counted");
        assert_eq!(m.stage.requests_ok, 2, "warmup + probe");
        assert_eq!(m.stage.requests_failed, 0, "shedding happens before the queue");
    });
    let report = server.metrics_report().unwrap();
    assert!(report.contains("default=open"), "{report}");
    assert!(report.contains("faults:"), "{report}");
}
