//! End-to-end heterogeneous-fleet serving on the stub backend: a
//! 2-class fleet (`adreno740:1,bigcore:1`) behind one queue, with
//! plan-driven admission.  Covers the acceptance flow: a
//! tight-deadline request routes to the faster device class, an
//! infeasible deadline is rejected at admission (never queued), and
//! `PoolMetrics` reports per-class predicted-vs-actual latency.

use std::time::Duration;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{Server, SubmitOptions};
use mobile_diffusion::planner::{device_spec, PlanRegistry};
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

/// Plan-predicted service times for the test's (variant, steps), so
/// the deadlines below straddle the two classes whatever the exact
/// model-graph calibration.
fn predictions(steps: usize) -> (f64, f64) {
    let plans = PlanRegistry::new();
    let fast = plans
        .plan(&device_spec("adreno740").unwrap(), "mobile")
        .unwrap()
        .predict_service_s(steps);
    let slow = plans
        .plan(&device_spec("bigcore").unwrap(), "mobile")
        .unwrap()
        .predict_service_s(steps);
    (fast, slow)
}

#[test]
fn two_class_fleet_routes_rejects_and_reports() {
    let steps = 3usize;
    let (fast, slow) = predictions(steps);
    assert!(
        fast < slow,
        "the GPU-delegate class must out-predict the CPU class ({fast} vs {slow})"
    );

    let dir = testkit::fake_artifacts_dir("fleet_e2e", &small_spec()).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = steps;
    cfg.queue_depth = 16;
    cfg.fleet = Some("adreno740:1,bigcore:1".into());
    let mut server = Server::start(&cfg).unwrap();
    assert_eq!(server.num_workers(), 2, "one worker per fleet class");

    // 1. a deadline between the two predictions: only the faster
    //    class is feasible, so the planner routes there
    let tight = Duration::from_secs_f64((fast + slow) / 2.0);
    let opts = SubmitOptions { deadline: Some(tight), ..Default::default() };
    let resp = server.generate_with("tight deadline", 1, opts).unwrap();
    assert_eq!(resp.device_class, "adreno740");
    let predicted = resp.predicted_s.expect("planned fleets carry predictions");
    assert!((predicted - fast).abs() < 1e-9);
    assert!(resp.image.iter().all(|v| v.is_finite()));

    // 2. no deadline: the cheapest (slowest feasible) class takes it
    let resp = server.generate("no deadline", 2).unwrap();
    assert_eq!(resp.device_class, "bigcore");

    // 3. a deadline below even the fast class's prediction is
    //    rejected at admission — it never reaches the queue
    let impossible = Duration::from_secs_f64(fast / 2.0);
    let opts = SubmitOptions { deadline: Some(impossible), ..Default::default() };
    let err = server
        .generate_with("impossible deadline", 3, opts)
        .expect_err("infeasible deadline must be rejected");
    assert!(err.to_string().contains("infeasible"), "{err}");
    server.with_metrics(|m| {
        assert_eq!(m.rejected_infeasible, 1);
        assert_eq!(
            m.rejected_deadline, 0,
            "rejected at admission, not expired in queue"
        );
        assert_eq!(m.stage.requests_ok, 2);
    });

    // 4. per-class predicted-vs-actual latency lands in the metrics
    server.with_metrics(|m| {
        let adreno = m.classes.iter().find(|c| c.name == "adreno740").unwrap();
        assert_eq!(adreno.prediction_count(), 1);
        assert!(adreno.predicted_summary().mean > 0.0);
        assert!(adreno.actual_summary().mean > 0.0);
        let cpu = m.classes.iter().find(|c| c.name == "bigcore").unwrap();
        assert_eq!(cpu.prediction_count(), 1);
    });
    let report = server.metrics_report().unwrap();
    assert!(report.contains("class adreno740"), "{report}");
    assert!(report.contains("class bigcore"), "{report}");
    assert!(report.contains("|rel err|"), "{report}");
}

#[test]
fn deadline_infeasible_for_50_step_ddim_is_served_by_the_distilled_sampler() {
    // acceptance: a deadline no class can meet at 50 DDIM steps is
    // rejected at admission, but the same deadline with the distilled
    // 8-step sampler is admitted and served — the router prices the
    // request at the sampler's effective step count, ~8/50 of the cost
    let steps = 50usize;
    let (fast50, slow50) = predictions(steps);
    let (fast8, _slow8) = predictions(8);
    assert!(fast50 < slow50);
    assert!(fast8 < fast50, "8 steps must out-predict 50 ({fast8} vs {fast50})");

    let dir = testkit::fake_artifacts_dir("fleet_sampler", &small_spec()).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = steps;
    cfg.queue_depth = 16;
    cfg.fleet = Some("adreno740:1,bigcore:1".into());
    let mut server = Server::start(&cfg).unwrap();

    // below even the FAST class's 50-step prediction, above the fast
    // class's 8-step prediction
    let deadline = Duration::from_secs_f64((fast8 + fast50) / 2.0);

    // 50-step DDIM: infeasible on every class, never queued
    let opts = SubmitOptions { deadline: Some(deadline), ..Default::default() };
    let err = server
        .generate_with("ddim under a distilled-only deadline", 1, opts)
        .expect_err("no class serves 50 DDIM steps inside the deadline");
    assert!(err.to_string().contains("infeasible"), "{err}");

    // the same deadline with the distilled 8-step sampler is feasible
    let opts = SubmitOptions {
        deadline: Some(deadline),
        sampler: Some("distilled8".into()),
        ..Default::default()
    };
    let resp = server.generate_with("distilled8 makes it feasible", 2, opts).unwrap();
    assert_eq!(resp.timings.denoise_steps, 8, "the distilled schedule actually ran");
    let predicted = resp.predicted_s.expect("planned fleets carry predictions");
    let plans = PlanRegistry::new();
    let want = plans
        .plan(&device_spec(&resp.device_class).unwrap(), "mobile")
        .unwrap()
        .predict_service_s(8);
    assert!(
        (predicted - want).abs() < 1e-9,
        "priced at the 8-step prediction: {predicted} vs {want}"
    );
    assert!(predicted <= deadline.as_secs_f64());

    server.with_metrics(|m| {
        assert_eq!(m.rejected_infeasible, 1, "the DDIM request was rejected at admission");
        assert_eq!(m.stage.requests_ok, 1);
        assert_eq!(m.stage.requests_failed, 0);
    });
    let report = server.metrics_report().unwrap();
    assert!(report.contains("samplers: distilled8=1"), "{report}");
}

#[test]
fn fleet_respects_variant_overrides_in_routing() {
    let dir = testkit::fake_artifacts_dir("fleet_variant", &small_spec()).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 2;
    cfg.fleet = Some("adreno740:1,bigcore:1".into());
    let mut server = Server::start(&cfg).unwrap();

    // the base variant predicts slower everywhere; a deadline feasible
    // for mobile-on-cpu can be infeasible for base-on-cpu, pushing the
    // base request onto the GPU class
    let plans = PlanRegistry::new();
    let base_cpu = plans
        .plan(&device_spec("bigcore").unwrap(), "base")
        .unwrap()
        .predict_service_s(2);
    let base_gpu = plans
        .plan(&device_spec("adreno740").unwrap(), "base")
        .unwrap()
        .predict_service_s(2);
    assert!(base_gpu < base_cpu);
    let deadline = Duration::from_secs_f64((base_gpu + base_cpu) / 2.0);

    let opts = SubmitOptions {
        variant: Some("base".into()),
        deadline: Some(deadline),
        ..Default::default()
    };
    let resp = server.generate_with("base variant", 1, opts).unwrap();
    assert_eq!(resp.device_class, "adreno740");

    // an unknown variant is rejected as a config error, not counted
    // as deadline infeasibility
    let opts = SubmitOptions { variant: Some("huge".into()), ..Default::default() };
    let err = server.generate_with("unknown variant", 2, opts).unwrap_err();
    assert!(err.to_string().contains("variant"), "{err}");
    server.with_metrics(|m| assert_eq!(m.rejected_infeasible, 0));
}
