//! Step-level continuous batching acceptance tests on the stub
//! backend: a scripted [`ContinuousControl`] pins join/preempt timing
//! to exact step boundaries, so dispatch counts, slot reuse and the
//! bit-identical-to-solo invariant are all checked deterministically.
//!
//! Pinned invariants:
//! * a row that joins an in-flight batch at step k is bit-identical to
//!   a solo run with the same seed;
//! * a preempted-then-resumed row is bit-identical to an uninterrupted
//!   one, with no re-encode and no extra UNet dispatches overall;
//! * reclaimed slots serve joiners (one dispatch per step index, at
//!   the session's seat cap) and never mix rows across `BatchKey`s;
//! * batch load time is amortized across members while the integer
//!   load counters stay whole on the first member;
//! * the server pool serves continuous sessions end-to-end and reports
//!   them.

use std::path::Path;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::error::Result;
use mobile_diffusion::pipeline::{
    BatchKey, BatchRequest, ContinuousControl, ContinuousJob, ExecOptions, ExecOverrides,
    GenerateResult, LiveRow, PipelinedExecutor,
};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::scheduler::Sampler;
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

fn executor(dir: &Path, num_steps: usize) -> PipelinedExecutor {
    let m = Manifest::load(dir).unwrap();
    PipelinedExecutor::new(m, ExecOptions { num_steps, ..Default::default() }).unwrap()
}

fn key() -> BatchKey {
    BatchKey {
        variant: "mobile".into(),
        weights_tag: "fp32".into(),
        sampler: Sampler::Ddim,
    }
}

fn job(prompt: &str, seed: u64, token: u64, steps: usize) -> ContinuousJob {
    ContinuousJob {
        req: BatchRequest {
            prompt: prompt.to_string(),
            seed,
            overrides: ExecOverrides { num_steps: Some(steps), ..Default::default() },
        },
        token,
        resume: None,
    }
}

fn solo(dir: &Path, prompt: &str, seed: u64, steps: usize) -> GenerateResult {
    let mut ex = executor(dir, 20);
    let ov = ExecOverrides { num_steps: Some(steps), ..Default::default() };
    ex.generate_with(prompt, seed, "mobile", &ov).unwrap()
}

/// Scripts the scheduler side of a session: joiners release once the
/// session has run their step count, preemptions fire at the boundary
/// after theirs.
#[derive(Default)]
struct ScriptControl {
    /// `(after_steps, job)` — released at the first boundary where the
    /// session has run at least `after_steps` dispatches
    joins: Vec<(usize, ContinuousJob)>,
    /// `(after_steps, token)` — named as a victim at that boundary
    preempts: Vec<(usize, u64)>,
    steps: usize,
    completions: Vec<(u64, Result<GenerateResult>)>,
    requeued: Vec<ContinuousJob>,
}

impl ScriptControl {
    fn result_of(&self, token: u64) -> &GenerateResult {
        self.completions
            .iter()
            .find(|(t, _)| *t == token)
            .unwrap_or_else(|| panic!("token {token} never completed"))
            .1
            .as_ref()
            .unwrap()
    }
}

impl ContinuousControl for ScriptControl {
    fn poll_joins(&mut self, _key: &BatchKey, slots: usize) -> Vec<ContinuousJob> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.joins.len() && out.len() < slots {
            if self.joins[i].0 <= self.steps {
                out.push(self.joins.remove(i).1);
            } else {
                i += 1;
            }
        }
        out
    }

    fn preempt_victims(&mut self, live: &[LiveRow], _free_slots: usize) -> Vec<u64> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.preempts.len() {
            let (after, token) = self.preempts[i];
            if after <= self.steps && live.iter().any(|r| r.token == token) {
                out.push(token);
                self.preempts.remove(i);
            } else {
                i += 1;
            }
        }
        out
    }

    fn requeue(&mut self, job: ContinuousJob) {
        self.requeued.push(job);
    }

    fn complete(&mut self, token: u64, result: Result<GenerateResult>) {
        self.completions.push((token, result));
    }

    fn on_step(&mut self, _live: usize, _wall_s: f64) {
        self.steps += 1;
    }
}

#[test]
fn joiner_at_a_step_boundary_is_bit_identical_to_solo() {
    let dir = testkit::fake_artifacts_dir("cont_join", &small_spec()).unwrap();
    let solo_a = solo(&dir, "an astronaut", 1, 4);
    let solo_b = solo(&dir, "a lighthouse", 2, 6);

    let mut ex = executor(&dir, 20);
    let mut ctl = ScriptControl::default();
    ctl.joins.push((1, job("a lighthouse", 2, 11, 6)));
    let stats = ex
        .run_continuous(&key(), "mobile", vec![job("an astronaut", 1, 10, 4)], 2, &mut ctl)
        .unwrap();

    // A runs dispatches 1..=4; B joins after dispatch 1 and runs 2..=7
    assert_eq!(stats.steps, 7);
    assert_eq!(stats.joins, 1);
    assert_eq!(stats.peak_occupancy, 2);
    assert_eq!(stats.completed, 2);
    let st = ex.engine.device_stats();
    assert_eq!(st.executions_of("unet_mobile"), 7, "one dispatch per step index");
    assert_eq!(st.rows_of("unet_mobile"), 2 * (1 + 3 * 2 + 3), "CFG rows track occupancy");

    let a = ctl.result_of(10);
    assert_eq!(a.latent, solo_a.latent, "pre-join row unaffected by the splice");
    assert_eq!(a.image, solo_a.image);
    let b = ctl.result_of(11);
    assert_eq!(b.latent, solo_b.latent, "joiner starts at its own schedule head");
    assert_eq!(b.image, solo_b.image);
    assert_eq!(b.timings.denoise_steps, 6);
}

#[test]
fn preempted_row_resumes_bit_identically_in_a_later_session() {
    let dir = testkit::fake_artifacts_dir("cont_preempt", &small_spec()).unwrap();
    let uninterrupted = solo(&dir, "a bowl of ramen", 3, 8);

    let mut ex = executor(&dir, 20);
    let mut ctl = ScriptControl::default();
    ctl.preempts.push((3, 7));
    let s1 = ex
        .run_continuous(&key(), "mobile", vec![job("a bowl of ramen", 3, 7, 8)], 2, &mut ctl)
        .unwrap();
    assert_eq!(s1.steps, 3, "preempted at the boundary after step 3");
    assert_eq!(s1.preemptions, 1);
    assert_eq!(s1.completed, 0);
    assert!(ctl.completions.is_empty());

    let resumed = ctl.requeued.pop().expect("victim was requeued");
    assert!(ctl.requeued.is_empty());
    {
        let cp = resumed.resume.as_ref().expect("victim carries a checkpoint");
        assert_eq!(cp.pos, 3, "checkpoint taken mid-schedule");
        assert_eq!(cp.ts.len(), 8);
    }

    let s2 = ex
        .run_continuous(&key(), "mobile", vec![resumed], 2, &mut ctl)
        .unwrap();
    assert_eq!(s2.steps, 5, "only the remaining schedule ran");
    assert_eq!(s2.resumes, 1);
    assert_eq!(s2.completed, 1);

    let r = ctl.result_of(7);
    assert_eq!(r.latent, uninterrupted.latent, "resume is bit-identical");
    assert_eq!(r.image, uninterrupted.image);
    assert_eq!(r.timings.denoise_steps, 8);
    // across both sessions, exactly one uninterrupted run's dispatches
    assert_eq!(ex.engine.device_stats().executions_of("unet_mobile"), 8);
}

#[test]
fn multistep_row_joins_preempts_and_resumes_bit_identically() {
    // acceptance: the second-order solver's eps history is row state —
    // it rides the checkpoint, so a multistep row spliced into a live
    // batch, preempted mid-schedule and resumed in a later session is
    // bit-identical to an uninterrupted run
    let dir = testkit::fake_artifacts_dir("cont_dpm2m", &small_spec()).unwrap();
    let dpm_key = BatchKey {
        variant: "mobile".into(),
        weights_tag: "fp32".into(),
        sampler: Sampler::Dpm2m,
    };
    let dpm_job = |prompt: &str, seed: u64, token: u64, steps: usize| {
        let mut j = job(prompt, seed, token, steps);
        j.req.overrides.sampler = Some(Sampler::Dpm2m);
        j
    };
    let dpm_solo = |prompt: &str, seed: u64, steps: usize| {
        let mut ex = executor(&dir, 20);
        let ov = ExecOverrides {
            num_steps: Some(steps),
            sampler: Some(Sampler::Dpm2m),
            ..Default::default()
        };
        ex.generate_with(prompt, seed, "mobile", &ov).unwrap()
    };
    let solo_a = dpm_solo("an astronaut", 1, 8);
    let solo_b = dpm_solo("a lighthouse", 2, 6);

    let mut ex = executor(&dir, 20);
    let mut ctl = ScriptControl::default();
    ctl.joins.push((1, dpm_job("a lighthouse", 2, 41, 6)));
    ctl.preempts.push((3, 40));
    let s1 = ex
        .run_continuous(&dpm_key, "mobile", vec![dpm_job("an astronaut", 1, 40, 8)], 2, &mut ctl)
        .unwrap();
    // A runs dispatches 1..=3 then is preempted; B joins after dispatch
    // 1 and runs 2..=7 to completion
    assert_eq!(s1.joins, 1);
    assert_eq!(s1.preemptions, 1);
    assert_eq!(s1.steps, 7);
    assert_eq!(s1.completed, 1);

    let resumed = ctl.requeued.pop().expect("victim was requeued");
    {
        let cp = resumed.resume.as_ref().expect("victim carries a checkpoint");
        assert_eq!(cp.pos, 3, "checkpoint taken mid-schedule");
        assert_eq!(cp.ts.len(), 8);
        assert_eq!(cp.history.len(), 1, "the eps history rides the checkpoint");
        assert!(!cp.history[0].is_empty());
    }

    let s2 = ex
        .run_continuous(&dpm_key, "mobile", vec![resumed], 2, &mut ctl)
        .unwrap();
    assert_eq!(s2.steps, 5, "only the remaining schedule ran");
    assert_eq!(s2.resumes, 1);
    assert_eq!(s2.completed, 1);

    let a = ctl.result_of(40);
    assert_eq!(a.latent, solo_a.latent, "resumed multistep row is bit-identical");
    assert_eq!(a.image, solo_a.image);
    assert_eq!(a.timings.denoise_steps, 8);
    let b = ctl.result_of(41);
    assert_eq!(b.latent, solo_b.latent, "multistep joiner is bit-identical");
    assert_eq!(b.image, solo_b.image);
    // co-batched dispatches: 7 in session one + 5 on resume
    assert_eq!(ex.engine.device_stats().executions_of("unet_mobile"), 12);
}

#[test]
fn incompatible_joiner_is_bounced_untouched() {
    let dir = testkit::fake_artifacts_dir("cont_bounce", &small_spec()).unwrap();
    let mut ex = executor(&dir, 20);
    let mut ctl = ScriptControl::default();
    let mut foreign = job("wrong lane", 5, 21, 4);
    foreign.req.overrides.variant = Some("base".into());
    ctl.joins.push((1, foreign));
    let stats = ex
        .run_continuous(&key(), "mobile", vec![job("right lane", 4, 20, 4)], 2, &mut ctl)
        .unwrap();

    assert_eq!(stats.joins, 0, "the foreign row never joined");
    assert_eq!(stats.completed, 1);
    let st = ex.engine.device_stats();
    assert_eq!(st.executions_of("unet_base"), 0, "foreign executable never ran");
    assert_eq!(ctl.requeued.len(), 1);
    let bounced = &ctl.requeued[0];
    assert_eq!(bounced.token, 21);
    assert!(bounced.resume.is_none(), "bounced exactly as it arrived, not checkpointed");
}

#[test]
fn reclaimed_slots_serve_joiners_and_everyone_matches_solo() {
    let dir = testkit::fake_artifacts_dir("cont_reclaim", &small_spec()).unwrap();
    let solo_short = solo(&dir, "short", 1, 3);
    let solo_long = solo(&dir, "long", 2, 8);
    let solo_late = solo(&dir, "late", 3, 4);

    let mut ex = executor(&dir, 20);
    let mut ctl = ScriptControl::default();
    // "late" arrives exactly when "short" retires and frees its seat
    ctl.joins.push((3, job("late", 3, 32, 4)));
    let stats = ex
        .run_continuous(
            &key(),
            "mobile",
            vec![job("short", 1, 30, 3), job("long", 2, 31, 8)],
            2,
            &mut ctl,
        )
        .unwrap();

    assert_eq!(stats.steps, 8);
    assert_eq!(stats.peak_occupancy, 2, "the seat cap held through the handoff");
    assert_eq!(stats.joins, 1);
    assert_eq!(stats.leaves, 2, "short and late left while long stayed live");
    assert_eq!(stats.completed, 3);
    let st = ex.engine.device_stats();
    assert_eq!(st.executions_of("unet_mobile"), 8, "one dispatch per step index");
    // steps 1-3 at B=2, 4-7 at B=2 (late in short's seat), 8 at B=1
    assert_eq!(st.rows_of("unet_mobile"), 2 * (3 * 2 + 4 * 2 + 1));

    for (token, want) in [(30u64, &solo_short), (31, &solo_long), (32, &solo_late)] {
        let r = ctl.result_of(token);
        assert_eq!(r.latent, want.latent, "token {token}: reclaimed-slot parity");
        assert_eq!(r.image, want.image, "token {token}");
    }
}

#[test]
fn batch_load_time_is_amortized_and_counters_stay_whole() {
    let dir = testkit::fake_artifacts_dir("cont_amort", &small_spec()).unwrap();
    let mut ex = executor(&dir, 3);
    let reqs: Vec<BatchRequest> = (0..4)
        .map(|i| BatchRequest {
            prompt: format!("member {i}"),
            seed: i as u64,
            overrides: ExecOverrides::default(),
        })
        .collect();
    let results = ex.generate_batch(&reqs, "mobile");
    let members: Vec<GenerateResult> =
        results.into_iter().map(|r| r.unwrap()).collect();

    let first = &members[0].timings.loads;
    let timed = first.read_s + first.parse_s + first.dequant_s + first.compile_s + first.upload_s;
    assert!(timed > 0.0, "the cold batch paid real load time");
    for (i, m) in members.iter().enumerate().skip(1) {
        let l = &m.timings.loads;
        // timed load work splits evenly — no member is charged the
        // whole batch's loads just for being listed first
        assert!((l.read_s - first.read_s).abs() < 1e-12, "member {i}");
        assert!((l.parse_s - first.parse_s).abs() < 1e-12, "member {i}");
        assert!((l.dequant_s - first.dequant_s).abs() < 1e-12, "member {i}");
        assert!((l.compile_s - first.compile_s).abs() < 1e-12, "member {i}");
        assert!((l.upload_s - first.upload_s).abs() < 1e-12, "member {i}");
        // integer counters stay whole on the first member so fleet
        // totals count each load once
        assert_eq!(l.cold_loads + l.warm_reloads, 0, "member {i}");
        assert_eq!(l.store_hits + l.store_misses, 0, "member {i}");
    }
    assert!(first.cold_loads >= 3, "encoder + unet + decoder charged once");
}

#[test]
fn continuous_pool_serves_end_to_end_and_reports_sessions() {
    let dir = testkit::fake_artifacts_dir("cont_pool", &small_spec()).unwrap();
    let solo_first = solo(&dir, "prompt 0", 0, 3);

    let mut cfg = AppConfig::default();
    assert!(cfg.continuous, "continuous scheduling is the default");
    cfg.artifacts_dir = dir;
    cfg.num_steps = 3;
    cfg.num_workers = 1;
    cfg.max_batch = 4;
    let mut server = Server::start(&cfg).unwrap();

    let receivers: Vec<_> = (0..4)
        .map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap())
        .collect();
    let mut first = None;
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.timings.denoise_steps, 3);
        assert!(resp.image.iter().all(|v| v.is_finite()));
        if i == 0 {
            first = Some(resp);
        }
    }
    let first = first.unwrap();
    assert_eq!(
        first.latent, solo_first.latent,
        "a continuous-pool row is bit-identical to its solo run"
    );
    server.with_metrics(|m| {
        assert!(m.sessions >= 1, "the pool ran continuous sessions");
        assert_eq!(m.stage.requests_ok, 4);
    });
    let report = server.metrics_report().unwrap();
    assert!(report.contains("continuous:"), "{report}");
}
