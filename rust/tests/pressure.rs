//! Memory-pressure acceptance tests: capacity-driven OOM recovery
//! through the learned degradation ladder.
//!
//! Pinned invariants:
//! * with the device's capacity mode set *between* a 1-wide and a
//!   4-wide working set, OOM arises organically mid-batch — and every
//!   submitted request still resolves **exactly once**, because OOM'd
//!   rows are retried *degraded* (smaller seat cap, shed residency,
//!   W8A8 under the learned budget), never verbatim;
//! * an executor with nothing left to give up fails its OOM'd request
//!   immediately — zero verbatim retries against an exhausted
//!   allocator;
//! * the governor's learned budget converges below the injected
//!   capacity and re-probes upward after a sustained OOM-free streak
//!   (breaker-style hysteresis), restoring the shipped budget at the
//!   ground rung;
//! * the batching/continuous/chaos parity suites run with capacity
//!   mode *off* — nothing here touches them.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{
    GenerateRequest, PressureGovernor, PressureOptions, Priority, Server, SupervisionOptions,
    WorkerExecutor, WorkerPool,
};
use mobile_diffusion::error::{Error, Result};
use mobile_diffusion::pipeline::{
    BatchRequest, ExecOptions, GenerateResult, PipelinedExecutor, StageTimings,
};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

/// Measure the device-byte peak of a `width`-wide fault-free batch on
/// a fresh uncapped executor — the calibration for the capacity cap.
fn measured_peak(dir: &std::path::Path, width: usize) -> u64 {
    let m = Manifest::load(dir).unwrap();
    let mut ex =
        PipelinedExecutor::new(m, ExecOptions { num_steps: 4, ..Default::default() }).unwrap();
    let batch: Vec<BatchRequest> =
        (0..width).map(|i| BatchRequest::new(&format!("prompt {i}"), i as u64)).collect();
    for r in ex.generate_batch(&batch, "mobile") {
        r.unwrap();
    }
    ex.engine.device_stats().mem_peak()
}

/// The headline guarantee: a capacity cap sized so one row fits but a
/// wide batch cannot, and still every request completes exactly once —
/// the OOM is absorbed by checkpoint + degraded retry, and the
/// governor walks away with a learned budget below the shipped one.
#[test]
fn capacity_oom_recovers_via_degraded_retries_and_learns_a_budget() {
    let dir = testkit::fake_artifacts_dir("pressure_e2e", &small_spec()).unwrap();
    let peak1 = measured_peak(&dir, 1);
    let peak4 = measured_peak(&dir, 4);
    assert!(
        peak4 > peak1,
        "a 4-wide batch must need more device bytes than a single row ({peak1} vs {peak4})"
    );
    // one row fits with margin; two or more rows exceed the cap, so
    // the first multi-row session OOMs deterministically
    let cap = peak1 + (peak4 - peak1) / 4;

    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 4;
    cfg.num_workers = 1;
    cfg.max_batch = 4;
    cfg.retry_limit = 4;
    cfg.retry_backoff_ms = 1;
    // a finite planner budget gives the governor a shipped byte figure
    // to shrink from (unbudgeted deployments keep ladder/counters only)
    cfg.memory_budget_mb = 64.0;
    cfg.device_mem_mb = Some(cap as f64 / 1e6);
    let mut server = Server::start(&cfg).unwrap();

    let receivers: Vec<_> =
        (0..6).map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap()).collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx
            .recv()
            .expect("every request gets a terminal reply")
            .unwrap_or_else(|e| panic!("request {i} must complete via degraded retry: {e}"));
        assert!(resp.image.iter().all(|v| v.is_finite()), "request {i}");
        assert!(rx.recv().is_err(), "request {i} must never resolve twice");
    }

    server.with_metrics(|m| {
        assert_eq!(m.stage.requests_ok, 6, "all six completed");
        assert_eq!(m.stage.requests_failed, 0);
        assert!(m.ooms >= 1, "the capacity cap actually bit: ooms={}", m.ooms);
        assert!(
            m.degraded_retries >= 1,
            "OOM'd rows came back degraded: degraded_retries={}",
            m.degraded_retries
        );
    });
    let gov = server.pressure();
    assert!(gov.ooms(0) >= 1);
    assert!(
        gov.effective_budget(0) < gov.shipped_budget(0),
        "the governor learned a budget below shipped"
    );
    let report = server.metrics_report().unwrap();
    assert!(report.contains("pressure:"), "{report}");
    assert!(report.contains("ooms"), "{report}");
}

/// OOMs until `degrade` has been called, then succeeds — the mock
/// analog of a device whose allocator recovers once the plan shrinks.
struct OomUntilDegradedExec {
    degraded: bool,
    executions: Arc<AtomicUsize>,
}

impl WorkerExecutor for OomUntilDegradedExec {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        self.executions.fetch_add(1, Ordering::SeqCst);
        if !self.degraded {
            return Err(Error::Oom("allocator exhausted".into()));
        }
        Ok(GenerateResult {
            image: vec![0.0; 4],
            image_size: 2,
            latent: vec![req.seed as f32],
            timings: StageTimings { denoise_steps: 1, total_s: 0.001, ..Default::default() },
            peak_memory: 1,
        })
    }

    fn degrade(&mut self, _level: u8, _effective_budget: usize) -> Option<String> {
        self.degraded = true;
        Some("shrunk".into())
    }
}

/// Same allocator, but nothing left to give up: `degrade` declines.
struct NoHeadroomExec {
    executions: Arc<AtomicUsize>,
}

impl WorkerExecutor for NoHeadroomExec {
    fn execute(&mut self, _req: &GenerateRequest) -> Result<GenerateResult> {
        self.executions.fetch_add(1, Ordering::SeqCst);
        Err(Error::Oom("allocator exhausted".into()))
    }
}

/// The never-verbatim contract at pool level: a degradable executor
/// completes OOM'd work on the changed plan, while an executor that
/// cannot degrade fails its caller after exactly one device attempt —
/// where a transient-style verbatim retry loop would have burned the
/// whole retry budget against the same exhausted allocator.
#[test]
fn degraded_retry_completes_where_verbatim_retry_would_exhaust() {
    let classes = [("default".to_string(), 1usize)];
    let supervision = SupervisionOptions {
        retry_limit: 3,
        retry_backoff: Duration::from_millis(1),
        pressure: Some(Arc::new(PressureGovernor::new(
            vec![1_000_000],
            PressureOptions::default(),
        ))),
        ..SupervisionOptions::default()
    };

    // degradable: the OOM is absorbed
    let execs = Arc::new(AtomicUsize::new(0));
    let e2 = Arc::clone(&execs);
    let pool = WorkerPool::start_supervised(
        &classes,
        8,
        1,
        false,
        supervision.clone(),
        move |_, _c: usize, _n: &str| {
            Ok(OomUntilDegradedExec { degraded: false, executions: Arc::clone(&e2) })
        },
    )
    .unwrap();
    let rx = pool.submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None).unwrap();
    let resp = rx.recv().unwrap().expect("the degraded retry completes");
    assert_eq!(resp.id, 1);
    assert!(rx.recv().is_err(), "exactly one terminal reply");
    assert_eq!(execs.load(Ordering::SeqCst), 2, "one OOM attempt + one degraded attempt");
    pool.with_metrics(|m| {
        assert_eq!(m.ooms, 1);
        assert_eq!(m.degraded_retries, 1);
        assert_eq!(m.stage.requests_ok, 1);
    });

    // undegradable: fail fast, never re-run the identical plan
    let execs = Arc::new(AtomicUsize::new(0));
    let e2 = Arc::clone(&execs);
    let pool = WorkerPool::start_supervised(
        &classes,
        8,
        1,
        false,
        supervision,
        move |_, _c: usize, _n: &str| Ok(NoHeadroomExec { executions: Arc::clone(&e2) }),
    )
    .unwrap();
    let rx = pool.submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None).unwrap();
    let err = rx.recv().unwrap().expect_err("nothing left to degrade");
    assert!(err.to_string().contains("no degradation left"), "{err}");
    assert!(rx.recv().is_err(), "exactly one terminal reply");
    assert_eq!(
        execs.load(Ordering::SeqCst),
        1,
        "an OOM'd plan is never retried verbatim: the allocator saw exactly one attempt"
    );
    pool.with_metrics(|m| {
        assert_eq!(m.retries, 0, "zero verbatim retries");
        assert_eq!(m.stage.requests_failed, 1);
    });
}

/// The learning loop in isolation: against a device whose true
/// capacity is below the shipped budget, repeated OOMs converge the
/// learned budget under that capacity (never under the floor), and a
/// sustained OOM-free streak re-probes it back up to shipped.
#[test]
fn learned_budget_converges_below_capacity_and_reprobes_upward() {
    let shipped = 1_000_000usize;
    let true_capacity = 400_000usize; // what the device actually grants
    let gov = PressureGovernor::new(
        vec![shipped],
        PressureOptions { probe_streak: 3, ..PressureOptions::default() },
    );

    // every admission above the true capacity OOMs; the governor
    // shrinks until admission stops over-committing
    let mut rounds = 0;
    while gov.effective_budget(0) > true_capacity {
        gov.on_oom(0);
        rounds += 1;
        assert!(rounds < 32, "the ladder must converge, not oscillate");
    }
    assert!(gov.effective_budget(0) <= true_capacity, "admission now fits the device");
    assert!(
        gov.effective_budget(0) >= (shipped as f64 * 0.25) as usize,
        "the floor keeps the class serving"
    );
    assert!(!gov.admits_peak(0, shipped), "shipped-sized plans are now filtered");
    assert!(gov.admits_peak(0, gov.effective_budget(0)));

    // hysteresis: each full OOM-free streak steps one rung down and
    // probes the budget upward; the ground rung restores shipped
    let mut budgets = vec![gov.effective_budget(0)];
    for _ in 0..(mobile_diffusion::coordinator::pressure::MAX_LEVEL as usize) {
        for _ in 0..3 {
            gov.on_success(0);
        }
        budgets.push(gov.effective_budget(0));
    }
    assert!(
        budgets.windows(2).all(|w| w[0] <= w[1]),
        "re-probing is monotone upward: {budgets:?}"
    );
    assert_eq!(gov.level(0), 0, "fully recovered");
    assert_eq!(gov.effective_budget(0), shipped, "ground rung restores the shipped budget");
    assert!(gov.probes(0) >= 1);
}

/// Capacity accounting is per client: ledger-style charge on creation,
/// credit on drop, with the peak watermark the e2e test calibrates
/// against.  (The stub's own tests cover rejection; this pins the
/// public surface integration tests rely on.)
#[test]
fn device_capacity_mode_tracks_live_bytes_and_lifts() {
    let client = xla::PjRtClient::cpu().unwrap();
    let stats = client.stats();
    assert_eq!(stats.device_mem(), None, "unlimited by default");
    stats.set_device_mem(Some(64));
    let buf = client.buffer_from_host_buffer(&[1.0f32; 8], &[8], None).unwrap(); // 32 B
    assert_eq!(stats.mem_used(), 32);
    assert!(
        client.buffer_from_host_buffer(&[1.0f32; 12], &[12], None).is_err(),
        "48 B over the cap"
    );
    assert_eq!(stats.oom_rejections(), 1);
    drop(buf);
    assert_eq!(stats.mem_used(), 0, "dropped buffers credit their bytes back");
    stats.set_device_mem(None);
    let _big = client.buffer_from_host_buffer(&[1.0f32; 64], &[64], None).unwrap();
    assert!(stats.mem_peak() >= 256, "peak watermark survives");
}
