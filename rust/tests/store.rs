//! Host-artifact store + warm reload acceptance tests on the stub
//! backend (synthetic STUBHLO artifacts, real buffers, real compile
//! and dispatch counters — see `mobile_diffusion::testkit`).
//!
//! Pinned invariants (the ISSUE 4 acceptance criteria):
//! * with a fleet of workers sharing one store, each `(component,
//!   tag)` is read and parsed from disk exactly once per process;
//! * a post-eviction re-acquire is a *warm* reload: zero disk reads,
//!   zero parses, zero dequants, zero compiles — only the device
//!   upload — asserted via stage-level `LoadStats`/`LoadProfile` and
//!   the stub's compile counter;
//! * warm-path outputs are bit-identical to cold-path outputs.

use std::sync::Arc;
use std::thread;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::{ArtifactStore, Manifest};
use mobile_diffusion::testkit::{self, FakeArtifactSpec};

fn small_spec() -> FakeArtifactSpec {
    FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    }
}

/// Budget that fits the UNet plus the larger of encoder/decoder — the
/// paper's pipelined shape — but *not* all three, so every request
/// evicts the encoder and decoder.
fn tight_budget(m: &Manifest) -> usize {
    let bytes = |name: &str| m.components[name].weights["fp32"].bytes;
    bytes("unet_mobile") + bytes("text_encoder").max(bytes("decoder"))
}

#[test]
fn four_workers_trigger_exactly_one_disk_load_per_component() {
    let dir = testkit::fake_artifacts_dir("store_threads", &small_spec()).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let store = Arc::new(ArtifactStore::new());
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let store = Arc::clone(&store);
            let m = m.clone();
            thread::spawn(move || {
                for name in ["unet_mobile", "text_encoder", "decoder"] {
                    let comp = m.component(name).unwrap();
                    let (host, _) = store.get_or_load(&m, comp, "fp32").unwrap();
                    assert!(host.stored_bytes() > 0);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        store.disk_loads(),
        3,
        "4 workers x 3 components -> 3 disk loads, not 12"
    );
    assert_eq!(store.hits(), 9);
}

#[test]
fn fleet_pool_shares_the_store_across_workers() {
    let dir = testkit::fake_artifacts_dir("store_fleet", &small_spec()).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_workers = 4;
    cfg.num_steps = 2;
    cfg.queue_depth = 32;
    let mut server = Server::start(&cfg).unwrap();

    let receivers: Vec<_> = (0..8)
        .map(|i| server.submit(&format!("prompt {i}"), i as u64).unwrap())
        .collect();
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let store = server.artifact_store();
    assert_eq!(
        store.disk_loads(),
        3,
        "unet_mobile + text_encoder + decoder each read from disk once, \
         regardless of worker count or reload cycles"
    );
    let report = server.metrics_report().unwrap();
    assert!(report.contains("artifact store: 3 cached"), "{report}");
}

#[test]
fn thrash_under_budget_reloads_warm_with_no_parse_or_compile() {
    let dir = testkit::fake_artifacts_dir("store_thrash", &small_spec()).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let budget = tight_budget(&m);
    let mut ex = PipelinedExecutor::new(
        m,
        ExecOptions { num_steps: 3, memory_budget: budget, ..Default::default() },
    )
    .unwrap();
    let stats = ex.engine.device_stats();

    // request 1: everything is cold
    let r1 = ex.generate("thrash", 7, "mobile").unwrap();
    assert!(r1.peak_memory <= budget);
    let cold = ex.load_profile().clone();
    assert_eq!(cold.cold_loads, 3);
    assert_eq!(cold.warm_reloads, 0);
    assert_eq!(cold.store_misses, 3);
    assert_eq!(stats.compiles(), 3, "one compile per component");
    assert_eq!(ex.store().disk_loads(), 3);
    assert_eq!(r1.timings.loads.cold_loads, 3, "per-request accounting rides the timings");

    // the evicted encoder/decoder left warm remnants behind
    assert!(ex.residency.warm_contains("text_encoder", "fp32"));
    assert!(ex.residency.warm_contains("decoder", "fp32"));

    // request 2: the UNet is still resident; encoder and decoder were
    // evicted under the budget and must come back warm
    let r2 = ex.generate("thrash", 7, "mobile").unwrap();
    let after = ex.load_profile().clone();
    let delta = after.since(&cold);
    assert_eq!(delta.cold_loads, 0, "no cold loads on the warm path");
    assert_eq!(delta.warm_reloads, 2, "text encoder + decoder");
    assert_eq!(delta.store_hits, 2, "host halves came from the store");
    assert_eq!(stats.compiles(), 3, "zero extra compiles");
    assert_eq!(ex.store().disk_loads(), 3, "zero extra disk reads/parses");
    assert_eq!(
        delta.read_s + delta.parse_s + delta.dequant_s + delta.compile_s,
        0.0,
        "warm reloads pay only the upload stage"
    );
    assert!(delta.upload_s > 0.0, "the device upload is still paid");

    // warm-path outputs are bit-identical to the cold-path run
    assert_eq!(r1.latent, r2.latent);
    assert_eq!(r1.image, r2.image);
}

#[test]
fn disabling_warm_slots_goes_back_to_cold_reloads_with_store_hits() {
    let dir = testkit::fake_artifacts_dir("store_no_warm", &small_spec()).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let budget = tight_budget(&m);
    let mut ex = PipelinedExecutor::new(
        m,
        ExecOptions {
            num_steps: 2,
            memory_budget: budget,
            warm_slots: 0,
            ..Default::default()
        },
    )
    .unwrap();
    let stats = ex.engine.device_stats();
    ex.generate("no warm", 1, "mobile").unwrap();
    ex.generate("no warm", 2, "mobile").unwrap();
    let p = ex.load_profile().clone();
    assert_eq!(p.warm_reloads, 0, "tier disabled");
    assert_eq!(p.cold_loads, 5, "3 cold + 2 recompiled reloads");
    assert_eq!(stats.compiles(), 5, "evictions recompile without the tier");
    assert_eq!(
        ex.store().disk_loads(),
        3,
        "the store still absorbs the host half even without warm slots"
    );
    assert_eq!(p.store_hits, 2);
}

#[test]
fn int8_artifacts_dequantize_once_per_process() {
    // default sizing: a 65k-element int8 UNet keeps the dequant stage
    // comfortably above timer resolution
    let spec = FakeArtifactSpec { int8_unet: true, ..Default::default() };
    let dir = testkit::fake_artifacts_dir("store_int8", &spec).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let budget = tight_budget(&m); // int8 unet is smaller than fp32
    let opts = ExecOptions {
        num_steps: 2,
        memory_budget: budget,
        unet_weights: "int8".into(),
        ..Default::default()
    };
    let mut ex = PipelinedExecutor::new(m, opts).unwrap();
    ex.generate("int8", 3, "mobile").unwrap();
    let p1 = ex.load_profile().clone();
    assert!(p1.dequant_s > 0.0, "the int8 UNet paid a dequant stage");
    // drop everything resident, then regenerate: the dequantized rows
    // come back from the store — no second dequant anywhere
    ex.evict_idle();
    ex.generate("int8", 3, "mobile").unwrap();
    let delta = ex.load_profile().since(&p1);
    assert_eq!(delta.dequant_s, 0.0, "dequantization ran once per process");
    assert_eq!(ex.store().disk_loads(), 3);
}
