//! Scheduler + residency tests that need no AOT artifacts and no PJRT
//! device: the pool is exercised with mock executors, the acceptance
//! flow (4 concurrent requests on a 2-worker pool, per-request step
//! overrides, peak memory within budget) with a mock device that runs
//! the real ResidencyManager.

use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use mobile_diffusion::coordinator::{
    GenerateRequest, Priority, WorkerExecutor, WorkerPool,
};
use mobile_diffusion::pipeline::{
    GenerateResult, ResidencyManager, Retention, StageTimings,
};
use mobile_diffusion::{Error, Result};

fn result_with_steps(steps: usize, peak: usize) -> GenerateResult {
    GenerateResult {
        image: vec![0.0; 12],
        image_size: 2,
        latent: vec![0.0; 4],
        timings: StageTimings { denoise_steps: steps, total_s: 0.01, ..Default::default() },
        peak_memory: peak,
    }
}

/// Mock device worker: drives the real residency subsystem through the
/// paper's stage sequence (UNet cached, text encoder evicted after
/// encode, decoder reserve->fulfill->evict) under a budget of 100.
struct MockDevice {
    residency: ResidencyManager<u32>,
    default_steps: usize,
}

impl MockDevice {
    fn new() -> MockDevice {
        MockDevice { residency: ResidencyManager::new(100), default_steps: 20 }
    }
}

impl WorkerExecutor for MockDevice {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        let r = &mut self.residency;
        r.acquire("unet_mobile", "fp32", 50, || Ok(1))?;
        r.acquire("text_encoder", "fp32", 30, || Ok(2))?;
        r.release("text_encoder", "fp32", Retention::Evict)?;
        r.reserve("decoder", "fp32", 40)?;
        r.fulfill("decoder", "fp32", 3)?;
        std::thread::sleep(Duration::from_millis(10)); // decode
        r.release("decoder", "fp32", Retention::Evict)?;
        r.release("unet_mobile", "fp32", Retention::Cache)?;
        let steps = req.num_steps.unwrap_or(self.default_steps);
        Ok(result_with_steps(steps, self.residency.peak()))
    }
}

#[test]
fn two_worker_pool_serves_four_concurrent_requests_within_budget() {
    let pool = WorkerPool::start(2, 16, |_| Ok(MockDevice::new())).unwrap();

    let steps = [None, Some(3), None, Some(4)];
    let receivers: Vec<_> = steps
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut req = GenerateRequest::new(i as u64 + 1, "prompt", i as u64);
            req.num_steps = *s;
            pool.submit(req, Priority::Normal, None).unwrap()
        })
        .collect();

    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, i as u64 + 1);
        assert!(resp.worker_id < 2);
        assert_eq!(
            resp.timings.denoise_steps,
            steps[i].unwrap_or(20),
            "request {i}: per-request num_steps override must be honored"
        );
        assert!(
            resp.peak_memory <= 100,
            "request {i}: peak {} exceeds the 100-byte budget",
            resp.peak_memory
        );
        // pipelining bound: unet + max(text, decoder) = 90, not 120
        assert_eq!(resp.peak_memory, 90);
    }
    let report = pool.metrics_report();
    assert!(report.contains("4 ok"), "{report}");
    assert!(report.contains("worker 1"), "{report}");
}

/// Mock whose `execute` blocks until the test releases a gate token,
/// recording completion order — makes scheduling order deterministic.
struct GatedExec {
    started: mpsc::Sender<u64>,
    gate: Arc<Mutex<mpsc::Receiver<()>>>,
    order: Arc<Mutex<Vec<u64>>>,
}

impl WorkerExecutor for GatedExec {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        let _ = self.started.send(req.id);
        self.gate
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| Error::Runtime("gate closed".into()))?;
        self.order.lock().unwrap().push(req.id);
        Ok(result_with_steps(1, 1))
    }
}

struct Gate {
    started_rx: mpsc::Receiver<u64>,
    gate_tx: mpsc::Sender<()>,
    order: Arc<Mutex<Vec<u64>>>,
}

/// One gated worker; returns the pool plus the test-side controls.
fn gated_pool() -> (WorkerPool, Gate) {
    let (started_tx, started_rx) = mpsc::channel();
    let (gate_tx, gate_rx) = mpsc::channel();
    // factories must be Sync; mpsc endpoints are not, so hand them to
    // the worker through mutexes
    let started_tx = Arc::new(Mutex::new(started_tx));
    let gate_rx = Arc::new(Mutex::new(gate_rx));
    let order = Arc::new(Mutex::new(Vec::new()));
    let order2 = Arc::clone(&order);
    let pool = WorkerPool::start(1, 16, move |_| {
        Ok(GatedExec {
            started: started_tx.lock().unwrap().clone(),
            gate: Arc::clone(&gate_rx),
            order: Arc::clone(&order2),
        })
    })
    .unwrap();
    (pool, Gate { started_rx, gate_tx, order })
}

#[test]
fn fifo_fairness_within_a_priority_class() {
    let (pool, gate) = gated_pool();
    // occupy the worker with request 1...
    let rx1 = pool
        .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
        .unwrap();
    assert_eq!(gate.started_rx.recv().unwrap(), 1);
    // ...then queue 2, 3, 4 in submission order, same class
    let rest: Vec<_> = (2..=4)
        .map(|i| {
            pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                .unwrap()
        })
        .collect();
    for _ in 0..4 {
        gate.gate_tx.send(()).unwrap();
    }
    rx1.recv().unwrap().unwrap();
    for rx in rest {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(*gate.order.lock().unwrap(), vec![1, 2, 3, 4], "strict FIFO");
}

#[test]
fn priority_classes_preempt_queue_order() {
    let (pool, gate) = gated_pool();
    let rx1 = pool
        .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
        .unwrap();
    assert_eq!(gate.started_rx.recv().unwrap(), 1);
    // queued while the worker is busy: low, high, normal
    let r2 = pool.submit(GenerateRequest::new(2, "p", 2), Priority::Low, None).unwrap();
    let r3 = pool.submit(GenerateRequest::new(3, "p", 3), Priority::High, None).unwrap();
    let r4 = pool.submit(GenerateRequest::new(4, "p", 4), Priority::Normal, None).unwrap();
    for _ in 0..4 {
        gate.gate_tx.send(()).unwrap();
    }
    for rx in [rx1, r2, r3, r4] {
        rx.recv().unwrap().unwrap();
    }
    assert_eq!(
        *gate.order.lock().unwrap(),
        vec![1, 3, 4, 2],
        "high before normal before low"
    );
}

#[test]
fn admission_rejects_only_beyond_capacity() {
    let (pool, gate) = gated_pool();
    let rx1 = pool
        .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
        .unwrap();
    assert_eq!(gate.started_rx.recv().unwrap(), 1);
    // capacity 16: fill the queue exactly while the worker is busy
    let mut queued = Vec::new();
    for i in 2..=17 {
        queued.push(
            pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                .unwrap(),
        );
    }
    let err = pool
        .submit(GenerateRequest::new(99, "p", 99), Priority::High, None)
        .expect_err("18th submission must be rejected");
    assert!(err.to_string().contains("full"), "{err}");

    for _ in 0..17 {
        gate.gate_tx.send(()).unwrap();
    }
    rx1.recv().unwrap().unwrap();
    for rx in queued {
        rx.recv().unwrap().unwrap();
    }
    pool.with_metrics(|m| {
        assert_eq!(m.rejected_full, 1);
        assert_eq!(m.stage.requests_ok, 17);
    });
    let report = pool.metrics_report();
    assert!(report.contains("1 rejected"), "{report}");
}
