//! Cold vs converged cost model, and what W8A8 buys (paper Sec. 3.2 +
//! Sec. 3.4).  Emits `BENCH_calibration.json` (repo root).
//!
//! Two claims, both *shape* (absolute numbers are synthetic — stub
//! backend, roofline-exact observations):
//!
//! * **calibration converges** — a fleet whose CPU class really runs
//!   4x better than its shipped constants starts out misrouting a
//!   tight-deadline request to the expensive GPU class; as dispatch
//!   observations accumulate the predicted-vs-actual step error
//!   collapses, the replan trigger fires, and the same request flips
//!   to the truly-cheapest feasible class;
//! * **W8A8 pays where the model says it does** — the int8 activation
//!   charge halves the UNet's peak live activation in the ledger, and
//!   toggling the stub's quantized round-trip on a real executor run
//!   leaves the step loop intact (every dispatch counted).
//!
//!     cargo bench --bench calibration            # full workload
//!     cargo bench --bench calibration -- --fast  # CI smoke mode

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use mobile_diffusion::delegate::{w8a8_gain, OpClass, RoofParams};
use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::planner::{
    device_spec, model::unet_graph, CalibratedProfile, FleetCalibration, FleetRouter,
    FleetSpec, Observation, PlanRegistry, MIN_CLASS_SAMPLES,
};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::testkit::{fake_artifacts_dir, FakeArtifactSpec};

fn fail(msg: &str) -> ! {
    eprintln!("FAIL: {msg}");
    std::process::exit(1);
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let rounds = if fast { 3 } else { 8 };
    let steps = 20;

    // ---- cold vs converged routing -------------------------------
    let fleet = FleetSpec::parse("adreno740:1,bigcore:1").unwrap();
    let cal = FleetCalibration::with_window(256);
    let router = FleetRouter::with_calibration(fleet, Arc::new(PlanRegistry::new()), cal.clone());

    let fast_pred = router.predicted_s(0, "mobile", steps).unwrap();
    let slow_pred = router.predicted_s(1, "mobile", steps).unwrap();
    let tight = Duration::from_secs_f64((fast_pred + slow_pred) / 2.0);
    let cold_class = router.route("mobile", steps, Some(tight)).unwrap().class;

    // ground truth: the CPU silicon runs 4x the shipped constants
    let spec = device_spec("bigcore").unwrap();
    let base = spec.delegate.clone();
    let truth = RoofParams {
        flops: base.flops * 4.0,
        bandwidth: base.bandwidth * 4.0,
        dispatch: base.dispatch / 4.0,
    };
    let truth_reg = PlanRegistry::new();
    let actual_step = truth_reg
        .replan(&spec, "mobile", &CalibratedProfile::uniform(base.clone(), truth))
        .unwrap()
        .step_latency_s;

    let predicted = || router.plans().plan(&spec, "mobile").unwrap().step_latency_s;
    let rel_err = |pred: f64| (pred - actual_step).abs() / actual_step;
    let mut errs = vec![rel_err(predicted())];

    println!("== online roofline calibration (stub fleet, 4x-off CPU class) ==");
    println!("   cold: routed to class {cold_class}, step rel err {:.1}%\n", errs[0] * 100.0);

    let per_round = 3 * MIN_CLASS_SAMPLES;
    for round in 0..rounds {
        for &class in OpClass::ALL {
            for i in 0..per_round {
                let k = round * per_round + i;
                // alternate compute-bound, memory-bound, near-pure
                // dispatch work so every parameter is identified
                let (flops, bytes) = match k % 3 {
                    0 => (1e9 * (1.0 + k as f64), 1e3),
                    1 => (1e3, 1e7 * (1.0 + k as f64)),
                    _ => (1e3, 1e3),
                };
                let seconds =
                    truth.dispatch + (flops / truth.flops).max(bytes / truth.bandwidth);
                cal.record("bigcore", &base, Observation { class, flops, bytes, seconds });
            }
        }
        for line in router.apply_calibration() {
            println!("   {line}");
        }
        errs.push(rel_err(predicted()));
        println!(
            "   round {:>2}: {:>4} obs/class, step rel err {:.2}%",
            round + 1,
            (round + 1) * per_round,
            errs.last().unwrap() * 100.0
        );
    }
    let converged_class = router.route("mobile", steps, Some(tight)).unwrap().class;
    let replans = router.plans().replans();
    println!(
        "\n   converged: routed to class {converged_class}, {} replans, rel err {:.1}% -> {:.2}%\n",
        replans,
        errs[0] * 100.0,
        errs.last().unwrap() * 100.0
    );

    // ---- W8A8 activation quantization ----------------------------
    let adreno = device_spec("adreno740").unwrap();
    let g = unet_graph("mobile").unwrap();
    let gain_s = w8a8_gain(&g, &adreno.delegate);
    let act_fp32: usize = g
        .tensors
        .iter()
        .filter(|t| !t.is_const)
        .map(|t| t.bytes())
        .max()
        .unwrap_or(0);
    let act_int8: usize = g
        .tensors
        .iter()
        .filter(|t| !t.is_const)
        .map(|t| t.elems())
        .max()
        .unwrap_or(0);
    let plan = PlanRegistry::new().plan(&adreno, "mobile").unwrap();

    println!("== W8A8 activation quantization (mobile UNet on adreno740) ==");
    println!(
        "   modeled gain {:+.3} ms/dispatch-set, planner {} it",
        gain_s * 1e3,
        if plan.w8a8 { "enables" } else { "declines" }
    );
    println!(
        "   peak live activation: fp16 {:.2} MB -> int8 {:.2} MB; plan peak {:.1} MB",
        act_fp32 as f64 / 1e6,
        act_int8 as f64 / 1e6,
        plan.peak_memory as f64 / 1e6
    );

    // a real executor run with the stub's int8 round-trip toggled
    let artifacts = FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    };
    let dir = fake_artifacts_dir("bench_calibration", &artifacts).unwrap();
    let num_steps = if fast { 4 } else { 8 };
    let run = |quant: bool| {
        let m = Manifest::load(&dir).unwrap();
        let mut ex =
            PipelinedExecutor::new(m, ExecOptions { num_steps, ..Default::default() }).unwrap();
        ex.engine.device_stats().set_activation_quant(quant);
        let r = ex.generate("calibration bench", 7, "mobile").unwrap();
        let step_s = r.timings.denoise_s / r.timings.denoise_steps.max(1) as f64;
        (step_s, ex.engine.device_stats().quantized_dispatches())
    };
    let (step_off, q_off) = run(false);
    let (step_on, q_on) = run(true);
    println!(
        "   measured step: {:.3} ms off, {:.3} ms on ({} quantized dispatches)\n",
        step_off * 1e3,
        step_on * 1e3,
        q_on
    );

    // ---- artifact ------------------------------------------------
    let errs_json: Vec<String> = errs.iter().map(|e| format!("{e:.6}")).collect();
    let json = format!(
        concat!(
            "{{\n",
            "\"backend\": \"xla-stub\",\n",
            "\"fast\": {fast},\n",
            "\"calibration\": {{\"cold_class\": {cold}, \"converged_class\": {conv}, ",
            "\"actual_step_s\": {actual:.6}, \"replans\": {replans}, ",
            "\"rel_err\": [{errs}]}},\n",
            "\"w8a8\": {{\"gain_ms\": {gain:.4}, \"plan_enables\": {enables}, ",
            "\"act_peak_fp16_bytes\": {afp}, \"act_peak_int8_bytes\": {ai8}, ",
            "\"plan_peak_memory_bytes\": {ppeak}, ",
            "\"measured_step_off_s\": {soff:.6}, \"measured_step_on_s\": {son:.6}, ",
            "\"quantized_dispatches\": {qd}}}\n",
            "}}\n"
        ),
        fast = fast,
        cold = cold_class,
        conv = converged_class,
        actual = actual_step,
        replans = replans,
        errs = errs_json.join(", "),
        gain = gain_s * 1e3,
        enables = plan.w8a8,
        afp = act_fp32,
        ai8 = act_int8,
        ppeak = plan.peak_memory,
        soff = step_off,
        son = step_on,
        qd = q_on,
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_calibration.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("wrote {}", out.display());

    // ---- shape enforcement ---------------------------------------
    if cold_class != 0 {
        fail("cold model must misroute the tight request to the GPU class");
    }
    if converged_class != 1 {
        fail("converged model must flip the route to the truly-cheapest CPU class");
    }
    if replans == 0 {
        fail("calibration never triggered a replan");
    }
    let (first, last) = (errs[0], *errs.last().unwrap());
    if !(last < first * 0.2) {
        fail(&format!("rel err did not collapse: {first:.4} -> {last:.4}"));
    }
    if act_int8 >= act_fp32 {
        fail("int8 activation charge must undercut the fp16 charge");
    }
    if q_off != 0 || q_on == 0 {
        fail(&format!("quantized dispatch counting off: {q_off} off-run, {q_on} on-run"));
    }
}
