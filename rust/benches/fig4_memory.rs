//! Fig. 4 — memory occupancy of the three SD components during the
//! pipelined execution (paper Sec. 3.3), regenerated from a real run of
//! the executor with its memory ledger, against the load-everything
//! baseline.

use std::path::Path;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::planner::{device_spec, model::unet_graph};
use mobile_diffusion::runtime::Manifest;

/// The modeled ledger charge for the largest live activation, fp16 vs
/// the W8A8 int8 buffer (1 byte/elem) — the planner swaps the charge
/// whenever the cost model enables quantization on a (device, variant).
fn w8a8_activation_charges() {
    println!("== W8A8 activation charge (modeled, per UNet variant) ==");
    for variant in ["base", "mobile"] {
        let g = unet_graph(variant).unwrap();
        let acts = g.tensors.iter().filter(|t| !t.is_const);
        let fp16: usize = acts.clone().map(|t| t.bytes()).max().unwrap_or(0);
        let int8: usize = acts.map(|t| t.elems()).max().unwrap_or(0);
        let plan = mobile_diffusion::planner::PlanRegistry::new()
            .plan(&device_spec("adreno740").unwrap(), variant)
            .unwrap();
        println!(
            "   {variant:>6}: peak live activation {:.2} MB -> {:.2} MB int8 \
             ({:.0}% saved); adreno740 plan: w8a8 {}, peak {:.1} MB",
            fp16 as f64 / 1e6,
            int8 as f64 / 1e6,
            (fp16 - int8) as f64 / fp16.max(1) as f64 * 100.0,
            if plan.w8a8 { "on" } else { "off" },
            plan.peak_memory as f64 / 1e6
        );
        assert!(int8 < fp16, "int8 charge must undercut fp16");
    }
    println!();
}

fn main() {
    w8a8_activation_charges();

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; run `make artifacts`");
        return;
    }
    let m = Manifest::load(&dir).unwrap();

    let unet = m.component("unet_mobile").unwrap().weights["fp32"].bytes;
    let text = m.component("text_encoder").unwrap().weights["fp32"].bytes;
    let dec = m.component("decoder").unwrap().weights["fp32"].bytes;
    println!("component weights: unet {:.1} MB, text encoder {:.1} MB, decoder {:.1} MB\n",
             unet as f64 / 1e6, text as f64 / 1e6, dec as f64 / 1e6);

    let run = |pipelined: bool| {
        let mut ex = PipelinedExecutor::new(
            m.clone(),
            ExecOptions { num_steps: 8, pipelined, ..Default::default() },
        )
        .unwrap();
        let r = ex.generate("fig4: memory occupancy", 4, "mobile").unwrap();
        (r.peak_memory, ex.memory_trace().render_ascii(48), r.timings.total_s)
    };

    println!("== Fig. 4: pipelined execution (paper Sec. 3.3) ==");
    let (peak_pipe, trace_pipe, t_pipe) = run(true);
    println!("{trace_pipe}");
    println!("peak {:.1} MB, wall {:.2} s\n", peak_pipe as f64 / 1e6, t_pipe);

    println!("== baseline: all components resident ==");
    let (peak_naive, trace_naive, t_naive) = run(false);
    println!("{trace_naive}");
    println!("peak {:.1} MB, wall {:.2} s\n", peak_naive as f64 / 1e6, t_naive);

    let saved = peak_naive - peak_pipe;
    println!(
        "pipelining saves {:.1} MB of peak memory ({:.0}% of the naive peak); \
         expected ~min(text, decoder) = {:.1} MB",
        saved as f64 / 1e6,
        saved as f64 / peak_naive as f64 * 100.0,
        text.min(dec) as f64 / 1e6
    );
    assert!(peak_pipe < peak_naive);
    // peak_pipe ~= unet + max(text, dec) (+ slack for the int8 scales etc)
    let expect = (unet + text.max(dec)) as f64;
    let rel = (peak_pipe as f64 - expect).abs() / expect;
    assert!(rel < 0.05, "pipelined peak {peak_pipe} should be ~{expect}");
}
