//! Pass-pipeline benchmark: full-pipeline rewrite time and the
//! cost-gated modeled-latency delta, on both SD variants and every
//! registered device class.  Emits `BENCH_passes.json` (repo root).
//!
//! Two claims are enforced (exit 1 on violation):
//!
//! * the cost-gated plan is never worse than the unplanned graph on
//!   any device class (the planner's core invariant);
//! * on the GPU-delegate class the pipeline strictly pays on both
//!   variants (islands removed, softmax fused, layout debris gone).
//!
//!     cargo bench --bench passes            # full workload
//!     cargo bench --bench passes -- --fast  # CI smoke mode

use std::path::Path;
use std::time::Instant;

use mobile_diffusion::delegate::RuleSet;
use mobile_diffusion::passes;
use mobile_diffusion::planner::{model, modeled_cost_s, plan_graph, registered_devices};

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

struct DeviceRow {
    device: &'static str,
    before_ms: f64,
    after_ms: f64,
    schedule: Vec<&'static str>,
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("PASSES_FAST").is_ok();
    let iters = if fast { 7 } else { 31 };
    let rules = RuleSet::default();

    println!(
        "== pass pipeline: rewrite time + modeled-latency delta{} ==\n",
        if fast { " (fast mode)" } else { "" }
    );

    let mut json = String::from("{\n");
    json.push_str(&format!("  \"fast\": {fast},\n"));
    json.push_str("  \"variants\": [\n");

    let mut ok = true;
    for (vi, variant) in model::VARIANTS.iter().enumerate() {
        let g0 = model::unet_graph(variant).unwrap();

        // full-pipeline rewrite wall time (fresh graph per iteration)
        let mut samples = Vec::with_capacity(iters);
        let mut last_rewrites = 0usize;
        for _ in 0..iters {
            let mut g = g0.clone();
            let t0 = Instant::now();
            let report = passes::run_all(&mut g);
            samples.push(t0.elapsed().as_secs_f64());
            last_rewrites = report.total_rewrites();
        }
        let rewrite_ms = median(&mut samples) * 1e3;
        println!(
            "{variant}: {} ops, {} rewrite sites, pipeline rewrite {:.3} ms",
            g0.ops.len(),
            last_rewrites,
            rewrite_ms
        );

        // cost-gated modeled-latency delta per device class
        let mut rows: Vec<DeviceRow> = Vec::new();
        for spec in registered_devices() {
            let before = modeled_cost_s(&g0, &rules, &spec);
            let planned = plan_graph(&g0, &rules, &spec);
            println!(
                "  {:<10} {:>8.2} ms -> {:>8.2} ms ({:.2}x)   [{}]",
                spec.name,
                before * 1e3,
                planned.cost_s * 1e3,
                before / planned.cost_s.max(1e-12),
                planned.passes_used.join(", ")
            );
            if planned.cost_s > before {
                eprintln!(
                    "FAIL: plan worse than unplanned on {} ({variant})",
                    spec.name
                );
                ok = false;
            }
            if spec.name == "adreno740" && planned.cost_s >= before {
                eprintln!("FAIL: pipeline does not strictly pay on the GPU class ({variant})");
                ok = false;
            }
            rows.push(DeviceRow {
                device: spec.name,
                before_ms: before * 1e3,
                after_ms: planned.cost_s * 1e3,
                schedule: planned.passes_used.clone(),
            });
        }
        println!();

        json.push_str("    {\n");
        json.push_str(&format!("      \"variant\": \"{}\",\n", json_escape(variant)));
        json.push_str(&format!("      \"ops\": {},\n", g0.ops.len()));
        json.push_str(&format!("      \"rewrite_sites\": {last_rewrites},\n"));
        json.push_str(&format!("      \"pipeline_rewrite_ms\": {rewrite_ms:.6},\n"));
        json.push_str("      \"devices\": [\n");
        for (di, r) in rows.iter().enumerate() {
            let sched: Vec<String> =
                r.schedule.iter().map(|s| format!("\"{}\"", json_escape(s))).collect();
            json.push_str(&format!(
                "        {{\"device\": \"{}\", \"modeled_before_ms\": {:.6}, \
                 \"modeled_after_ms\": {:.6}, \"speedup\": {:.4}, \"schedule\": [{}]}}{}\n",
                json_escape(r.device),
                r.before_ms,
                r.after_ms,
                r.before_ms / r.after_ms.max(1e-12),
                sched.join(", "),
                if di + 1 < rows.len() { "," } else { "" }
            ));
        }
        json.push_str("      ]\n");
        json.push_str(&format!(
            "    }}{}\n",
            if vi + 1 < model::VARIANTS.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_passes.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("wrote {}", out.display());

    if !ok {
        std::process::exit(1);
    }
}
