//! Table 1 — end-to-end 512x512 generation latency on Galaxy-S23-class
//! hardware (text encoding + 20 effective denoising steps + image
//! decoding), regenerated two ways:
//!
//!  1. **cost model at SD v2.1 scale** for the four deployment
//!     configurations (the paper's rows + the no-passes TFLite baseline
//!     that motivates Sec. 3.1);
//!  2. **measured wall-clock** of our real (small-scale) pipeline on the
//!     CPU PJRT backend, with its stage breakdown.
//!
//! Absolute seconds in (1) come from the analytic device profiles in
//! delegate::cost; the claim being reproduced is the *shape*: ours(TFLite
//! + passes) < custom kernels < Hexagon engine, with incomplete
//! delegation far behind.

use std::path::Path;

use mobile_diffusion::delegate::{
    graph_cost, single_device_cost, RuleSet, CPU_BIGCORE, GPU_ADRENO740,
    GPU_CUSTOM_KERNELS, NPU_HEXAGON,
};
use mobile_diffusion::graph::{self, Graph};
use mobile_diffusion::passes;
use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::Manifest;

const STEPS: usize = 20; // paper: 20 effective denoising steps

fn load(dir: &Path, name: &str) -> Graph {
    graph::load(&dir.join(format!("{name}.graph.json"))).unwrap()
}

fn optimized(mut g: Graph) -> Graph {
    passes::run_all(&mut g);
    g
}

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; run `make artifacts`");
        return;
    }

    println!("== Table 1: end-to-end latency, SD v2.1-scale cost model ==");
    println!("   (text encoding + {STEPS} denoising steps + decoding, 512x512)\n");

    let unet = load(&dir, "sd_v21_unet");
    let text = load(&dir, "sd_v21_text_encoder");
    let dec = load(&dir, "sd_v21_decoder");
    let unet_opt = optimized(unet.clone());
    let text_opt = optimized(text.clone());
    let dec_opt = optimized(dec.clone());
    let rules = RuleSet::default();

    let e2e = |t_text: f64, t_unet: f64, t_dec: f64| t_text + STEPS as f64 * t_unet + t_dec;

    // ours: TFLite delegate + all Sec. 3.1/3.2 passes -> full delegation
    let ours = e2e(
        graph_cost(&text_opt, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
        graph_cost(&unet_opt, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
        graph_cost(&dec_opt, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
    );
    // stock TFLite export, no graph passes: CPU islands + transfers
    let stock = e2e(
        graph_cost(&text, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
        graph_cost(&unet, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
        graph_cost(&dec, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total(),
    );
    // Chen et al. 2023: private OpenCL kernels, complete coverage
    let custom = e2e(
        single_device_cost(&text_opt, &GPU_CUSTOM_KERNELS),
        single_device_cost(&unet_opt, &GPU_CUSTOM_KERNELS),
        single_device_cost(&dec_opt, &GPU_CUSTOM_KERNELS),
    );
    // Hou & Asghar 2023: Hexagon NPU via the Qualcomm AI engine
    let hexagon = e2e(
        single_device_cost(&text_opt, &NPU_HEXAGON),
        single_device_cost(&unet_opt, &NPU_HEXAGON),
        single_device_cost(&dec_opt, &NPU_HEXAGON),
    );

    println!("{:<46} {:>8}  {:>11}", "configuration", "model", "latency");
    let rows = [
        ("Hou & Asghar (Hexagon proc., Qualcomm engine)", "SD v1.5", hexagon, "~15 s"),
        ("Chen et al. (mobile GPU, custom kernels)", "SD v1.4", custom, "~12 s"),
        ("OURS (mobile GPU, stock TFLite + passes)", "SD v2.1", ours, "~7 s"),
        ("TFLite export without graph passes", "SD v2.1", stock, "(n/a)"),
    ];
    for (name, model, secs, paper) in rows {
        println!("{:<46} {:>8}  {:>8.1} s   paper: {}", name, model, secs, paper);
    }
    println!();
    assert!(
        ours < custom && custom < hexagon && hexagon < stock,
        "Table-1 ordering must hold: {ours:.1} {custom:.1} {hexagon:.1} {stock:.1}"
    );
    println!(
        "speedups: ours vs custom {:.2}x, vs hexagon {:.2}x, vs no-passes {:.2}x",
        custom / ours,
        hexagon / ours,
        stock / ours
    );

    // -------- measured wall-clock of the real small pipeline -------------
    println!("\n== measured: real small-scale pipeline (CPU PJRT) ==");
    let manifest = Manifest::load(&dir).unwrap();
    let mut ex = PipelinedExecutor::new(
        manifest,
        ExecOptions { num_steps: STEPS, ..Default::default() },
    )
    .unwrap();
    // warm the resident UNet, then measure a full request
    ex.ensure_unet("mobile").unwrap();
    let r = ex.generate("table one benchmark prompt", 1, "mobile").unwrap();
    let t = &r.timings;
    println!("total          {:>8.2} s", t.total_s);
    println!("  text load    {:>8.3} s", t.text_load_s);
    println!("  text encode  {:>8.3} s", t.text_encode_s);
    println!(
        "  denoise      {:>8.2} s  ({} steps, {:.1} ms/step)",
        t.denoise_s,
        t.denoise_steps,
        t.denoise_s / t.denoise_steps as f64 * 1e3
    );
    println!("  decoder load {:>8.3} s", t.decoder_load_s);
    println!("  decode       {:>8.3} s", t.decode_s);
    println!("peak memory    {:>8.1} MB", r.peak_memory as f64 / 1e6);
}
