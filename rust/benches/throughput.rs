//! Serving throughput vs micro-batch size on the stub backend, plus an
//! open-loop arrival sweep comparing step-level continuous batching
//! against run-to-completion scheduling.
//!
//! Part 1 drives a 1-worker pool over synthetic STUBHLO artifacts at
//! batch sizes {1, 2, 4} (closed loop, all requests submitted up
//! front).  Part 2 replays deterministic Poisson arrivals at increasing
//! offered load against the *same* worker in both scheduling modes and
//! reports p50/p95/p99 latency: continuous batching must strictly beat
//! run-to-completion on p95 at the highest load, where a
//! run-to-completion worker strands arrivals behind in-flight batch
//! tails that continuous scheduling lets them join.  Both sweeps land
//! in `BENCH_throughput.json` (repo root).  The stub's per-dispatch
//! weight digest models the fixed dispatch cost a real device pays, so
//! the *shape* of the curves is the claim — absolute numbers are
//! synthetic.
//!
//!     cargo bench --bench throughput            # full workload
//!     cargo bench --bench throughput -- --fast  # CI smoke mode
//!
//! The same harness runs in fast mode under `cargo test`
//! (rust/tests/batching.rs), which also enforces B=4 > B=1.

use std::path::Path;

use mobile_diffusion::testkit::throughput::{
    run_open_loop_profile, run_profile, to_json_with_open_loop, Workload,
};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("THROUGHPUT_FAST").is_ok();
    let wl = Workload::new(fast);
    println!(
        "== throughput vs micro-batch size (stub backend{}) ==",
        if fast { ", fast mode" } else { "" }
    );
    println!(
        "   {} requests x {} steps, 1 worker\n",
        wl.requests, wl.steps
    );

    let rows = match run_profile("bench_throughput", &wl, &[1, 2, 4]) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("throughput bench failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "{:>6} {:>12} {:>12} {:>14} {:>12}",
        "batch", "images/s", "steps/s", "p95 latency", "occupancy"
    );
    for r in &rows {
        println!(
            "{:>6} {:>12.2} {:>12.2} {:>11.1} ms {:>12.2}",
            r.batch,
            r.images_per_s,
            r.steps_per_s,
            r.p95_latency_s * 1e3,
            r.mean_occupancy
        );
    }
    let speedup = rows[2].images_per_s / rows[0].images_per_s.max(1e-12);
    println!("\nB=4 vs B=1 speedup: {speedup:.2}x");

    println!("\n== open-loop Poisson arrivals: continuous vs run-to-completion ==");
    let load_factors: &[f64] = if fast { &[0.8, 1.6] } else { &[0.5, 1.0, 2.0] };
    let open = match run_open_loop_profile("bench_open_loop", &wl, 4, load_factors) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("open-loop bench failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "{:>6} {:>12} {:>11} {:>11} {:>11} {:>10} {:>6}",
        "load", "mode", "p50", "p95", "p99", "occupancy", "joins"
    );
    for r in &open {
        println!(
            "{:>6.2} {:>12} {:>8.1} ms {:>8.1} ms {:>8.1} ms {:>10.2} {:>6}",
            r.load_factor,
            if r.continuous { "continuous" } else { "rtc" },
            r.p50_latency_s * 1e3,
            r.p95_latency_s * 1e3,
            r.p99_latency_s * 1e3,
            r.mean_occupancy,
            r.joins,
        );
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_throughput.json");
    let json = to_json_with_open_loop(&rows, &open, fast);
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("wrote {}", out.display());
    if speedup <= 1.0 {
        eprintln!("FAIL: batching did not improve throughput");
        std::process::exit(1);
    }
    // the tentpole claim: at the highest offered load, joining the
    // in-flight batch at step boundaries must beat waiting out its tail
    let top = load_factors.last().copied().unwrap_or(0.0);
    let at = |cont: bool| {
        open.iter()
            .find(|r| r.continuous == cont && (r.load_factor - top).abs() < 1e-9)
            .map(|r| r.p95_latency_s)
    };
    match (at(false), at(true)) {
        (Some(rtc), Some(cont)) => {
            println!(
                "high-load p95: rtc {:.1} ms, continuous {:.1} ms ({:.2}x)",
                rtc * 1e3,
                cont * 1e3,
                rtc / cont.max(1e-12)
            );
            if cont >= rtc {
                eprintln!("FAIL: continuous batching did not improve high-load p95");
                std::process::exit(1);
            }
        }
        _ => {
            eprintln!("FAIL: open-loop sweep missing the high-load operating points");
            std::process::exit(1);
        }
    }
}
