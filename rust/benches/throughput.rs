//! Serving throughput vs micro-batch size on the stub backend.
//!
//! Drives a 1-worker pool over synthetic STUBHLO artifacts at batch
//! sizes {1, 2, 4} and emits `BENCH_throughput.json` (repo root) with
//! images/s, steps/s and p95 latency per operating point.  The stub's
//! per-dispatch weight digest models the fixed dispatch cost a real
//! device pays, so the *shape* of the curve (B=4 > B=1) is the claim —
//! absolute numbers are synthetic.
//!
//!     cargo bench --bench throughput            # full workload
//!     cargo bench --bench throughput -- --fast  # CI smoke mode
//!
//! The same harness runs in fast mode under `cargo test`
//! (rust/tests/batching.rs), which also enforces B=4 > B=1.

use std::path::Path;

use mobile_diffusion::testkit::throughput::{run_profile, to_json, Workload};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast")
        || std::env::var("THROUGHPUT_FAST").is_ok();
    let wl = Workload::new(fast);
    println!(
        "== throughput vs micro-batch size (stub backend{}) ==",
        if fast { ", fast mode" } else { "" }
    );
    println!(
        "   {} requests x {} steps, 1 worker\n",
        wl.requests, wl.steps
    );

    let rows = match run_profile("bench_throughput", &wl, &[1, 2, 4]) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("throughput bench failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "{:>6} {:>12} {:>12} {:>14} {:>12}",
        "batch", "images/s", "steps/s", "p95 latency", "occupancy"
    );
    for r in &rows {
        println!(
            "{:>6} {:>12.2} {:>12.2} {:>11.1} ms {:>12.2}",
            r.batch,
            r.images_per_s,
            r.steps_per_s,
            r.p95_latency_s * 1e3,
            r.mean_occupancy
        );
    }
    let speedup = rows[2].images_per_s / rows[0].images_per_s.max(1e-12);
    println!("\nB=4 vs B=1 speedup: {speedup:.2}x");

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_throughput.json");
    let json = to_json(&rows, fast);
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("wrote {}", out.display());
    if speedup <= 1.0 {
        eprintln!("FAIL: batching did not improve throughput");
        std::process::exit(1);
    }
}
