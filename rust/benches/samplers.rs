//! Few-step sampler family vs the 50-step DDIM baseline (paper: "20
//! effective denoising steps" via distillation; here the serving-side
//! claim).  Emits `BENCH_samplers.json` (repo root).
//!
//! The claim is *shape* (absolute numbers are synthetic — stub
//! backend): at matched batch width, an 8-step request (DPM-Solver++
//! multistep or the distilled 8-step schedule) completes in at most
//! 1/4 of the 50-step DDIM wall-clock, and every sampler still issues
//! exactly one UNet dispatch per step index for the whole batch.
//!
//!     cargo bench --bench samplers            # full workload
//!     cargo bench --bench samplers -- --fast  # CI smoke mode

use std::path::Path;
use std::time::Instant;

use mobile_diffusion::pipeline::{BatchRequest, ExecOptions, ExecOverrides, PipelinedExecutor};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::scheduler::Sampler;
use mobile_diffusion::testkit::{fake_artifacts_dir, FakeArtifactSpec};

fn fail(msg: &str) -> ! {
    eprintln!("FAIL: {msg}");
    std::process::exit(1);
}

const BATCH: usize = 4;

struct Row {
    name: &'static str,
    requested: usize,
    steps: usize,
    wall_s: f64,
    dispatches: u64,
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let reps = if fast { 2 } else { 5 };
    let spec = FakeArtifactSpec {
        unet_weight_elems: 16_384,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    };
    let dir = fake_artifacts_dir("bench_samplers", &spec).unwrap();

    // (sampler, requested steps): the distilled members pin their own
    // count, so they are driven at the 50-step default to show it
    let configs = [
        (Sampler::Ddim, 50usize),
        (Sampler::Dpm2m, 8),
        (Sampler::Distilled8, 50),
        (Sampler::Distilled4, 50),
    ];

    println!("== few-step samplers vs 50-step DDIM (stub backend, B={BATCH}) ==");
    let mut rows: Vec<Row> = Vec::new();
    for (sampler, requested) in configs {
        let effective = sampler.effective_steps(requested);
        let mut best = f64::INFINITY;
        let mut dispatches = 0u64;
        for _ in 0..reps {
            let m = Manifest::load(&dir).unwrap();
            let mut ex =
                PipelinedExecutor::new(m, ExecOptions { num_steps: 50, ..Default::default() })
                    .unwrap();
            // warm the weight caches so the measurement is the step loop
            let warm = ExecOverrides { num_steps: Some(1), ..Default::default() };
            ex.generate_with("samplers bench warmup", 0, "mobile", &warm).unwrap();

            let reqs: Vec<BatchRequest> = (0..BATCH)
                .map(|i| BatchRequest {
                    prompt: format!("bench prompt {i}"),
                    seed: i as u64 + 1,
                    overrides: ExecOverrides {
                        num_steps: Some(requested),
                        sampler: Some(sampler),
                        ..Default::default()
                    },
                })
                .collect();
            let before = ex.engine.device_stats().executions_of("unet_mobile");
            let t0 = Instant::now();
            let results = ex.generate_batch(&reqs, "mobile");
            let dt = t0.elapsed().as_secs_f64();
            for (i, r) in results.iter().enumerate() {
                match r {
                    Ok(r) if r.timings.denoise_steps != effective => fail(&format!(
                        "{}: request {i} ran {} steps, wanted {effective}",
                        sampler.name(),
                        r.timings.denoise_steps
                    )),
                    Ok(_) => {}
                    Err(e) => fail(&format!("{}: request {i} failed: {e}", sampler.name())),
                }
            }
            dispatches = ex.engine.device_stats().executions_of("unet_mobile") - before;
            best = best.min(dt);
        }
        println!(
            "   {:<12} requested {:>2} -> {:>2} steps: {:>8.3} ms wall, {} dispatches",
            sampler.name(),
            requested,
            effective,
            best * 1e3,
            dispatches
        );
        rows.push(Row {
            name: sampler.name(),
            requested,
            steps: effective,
            wall_s: best,
            dispatches,
        });
    }

    let baseline = rows[0].wall_s;
    println!();
    for r in rows.iter().skip(1) {
        println!("   {:<12} speedup vs ddim@50: {:.2}x", r.name, baseline / r.wall_s);
    }

    // ---- artifact ------------------------------------------------
    let row_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                concat!(
                    "  {{\"sampler\": \"{}\", \"requested_steps\": {}, ",
                    "\"effective_steps\": {}, \"wall_s\": {:.6}, ",
                    "\"unet_dispatches\": {}, \"speedup_vs_ddim50\": {:.3}}}"
                ),
                r.name,
                r.requested,
                r.steps,
                r.wall_s,
                r.dispatches,
                baseline / r.wall_s
            )
        })
        .collect();
    let json = format!(
        "{{\n\"backend\": \"xla-stub\",\n\"fast\": {fast},\n\"batch\": {BATCH},\n\"rows\": [\n{}\n]\n}}\n",
        row_json.join(",\n")
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_samplers.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("\nwrote {}", out.display());

    // ---- shape enforcement ---------------------------------------
    for r in &rows {
        if r.dispatches != r.steps as u64 {
            fail(&format!(
                "{}: {} UNet dispatches for {} steps at B={BATCH} — batching broke",
                r.name, r.dispatches, r.steps
            ));
        }
    }
    for r in rows.iter().filter(|r| r.steps == 8) {
        let speedup = baseline / r.wall_s;
        if speedup < 4.0 {
            fail(&format!(
                "{}: 8-step speedup vs 50-step DDIM must be >= 4x, got {speedup:.2}x",
                r.name
            ));
        }
    }
    let d4 = rows.iter().find(|r| r.name == "distilled4").unwrap();
    if baseline / d4.wall_s < 4.0 {
        fail("distilled4 must beat the 50-step baseline by >= 4x");
    }
}
