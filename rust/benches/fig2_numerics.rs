//! Fig. 2 + Fig. 3 — numerical effect of the mobile rewrites.
//!
//! Fig. 2 (quantitative proxy): `unet_base` vs `unet_mobile` on identical
//! latent/prompt — the serialized conv, broadcast-free group norm and
//! clipped GELU must change the predicted noise only *subtly*; we report
//! MSE / PSNR / max-abs over the real artifacts, plus the end-to-end
//! final-latent deltas over full DDIM runs.
//!
//! Fig. 3 (binary16 emulation): the tanh-cubic GELU overflows float16 —
//! we count non-finite intermediates over an activation sweep and show
//! the clipped variant keeps every intermediate finite while matching
//! the f32 reference.

use std::path::Path;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::{ActInput, Component, Engine, Manifest};
use mobile_diffusion::util::f16::{self, F16};
use mobile_diffusion::util::rng::Rng;
use mobile_diffusion::util::stats;

const SQRT_2_OVER_PI: f32 = 0.7978845608;
const GELU_CUBIC: f32 = 0.044715;

/// Emulated-f16 tanh GELU; returns (output, any_nonfinite_intermediate).
fn gelu_f16(x: f32, clip: Option<f32>) -> (f32, bool) {
    let xh = F16::from_f32(x);
    let g = match clip {
        Some(m) => f16::clamp(xh, -m, m),
        None => xh,
    };
    let sq = f16::mul(g, g);
    let cube = f16::mul(sq, g);
    let scaled_cube = f16::mul(F16::from_f32(GELU_CUBIC), cube);
    let sum = f16::add(g, scaled_cube);
    let inner = f16::mul(F16::from_f32(SQRT_2_OVER_PI), sum);
    let t = f16::tanh(inner);
    let one_plus = f16::add(F16::from_f32(1.0), t);
    let half_x = f16::mul(F16::from_f32(0.5), xh);
    let out = f16::mul(half_x, one_plus);
    let bad = !sq.is_finite()
        || !cube.is_finite()
        || !scaled_cube.is_finite()
        || !sum.is_finite()
        || !inner.is_finite()
        || !out.is_finite();
    (out.to_f32(), bad)
}

fn gelu_f32(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_CUBIC * x * x * x)).tanh())
}

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; run `make artifacts`");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::new().unwrap();

    // ---------------- Fig. 2: base vs mobile UNet -----------------------
    println!("== Fig. 2: baseline vs mobile graph rewrites (single UNet eval) ==\n");
    let base = Component::load(&engine, &m, m.component("unet_base").unwrap(), "fp32").unwrap();
    let mobile =
        Component::load(&engine, &m, m.component("unet_mobile").unwrap(), "fp32").unwrap();
    let n = m.latent_size * m.latent_size * m.latent_channels;

    println!("{:<8} {:>14} {:>10} {:>12}", "seed", "mse", "psnr dB", "max-abs");
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let latent2 = rng.normal_f32_vec(2 * n);
        let ctx = rng.normal_f32_vec(2 * m.tokenizer.seq_len * 128);
        let acts = vec![
            ActInput::F32(latent2.clone()),
            ActInput::F32(vec![500.0]),
            ActInput::F32(ctx.clone()),
        ];
        let a = &base.run(&engine, &acts).unwrap()[0];
        let acts = vec![
            ActInput::F32(latent2),
            ActInput::F32(vec![500.0]),
            ActInput::F32(ctx),
        ];
        let b = &mobile.run(&engine, &acts).unwrap()[0];
        let peak = a.iter().fold(0f32, |mx, v| mx.max(v.abs())) as f64;
        let mse = stats::mse(a, b);
        println!(
            "{:<8} {:>14.3e} {:>10.1} {:>12.3e}",
            seed,
            mse,
            stats::psnr(a, b, peak),
            stats::max_abs_diff(a, b)
        );
        assert!(stats::max_abs_diff(a, b) / peak < 1e-3, "must stay subtle");
    }
    drop(base);
    drop(mobile);

    println!("\n-- end-to-end: final latent after a full DDIM run --");
    let run_variant = |variant: &str| {
        let mut ex = PipelinedExecutor::new(
            m.clone(),
            ExecOptions { num_steps: 10, ..Default::default() },
        )
        .unwrap();
        ex.generate("fig2 prompt: a cat on a sofa", 77, variant).unwrap()
    };
    let r_base = run_variant("base");
    let r_mobile = run_variant("mobile");
    let peak = r_base.latent.iter().fold(0f32, |mx, v| mx.max(v.abs())) as f64;
    println!(
        "latent mse {:.3e}, psnr {:.1} dB, max-abs {:.3e} (paper: 'difference was subtle')",
        stats::mse(&r_base.latent, &r_mobile.latent),
        stats::psnr(&r_base.latent, &r_mobile.latent, peak),
        stats::max_abs_diff(&r_base.latent, &r_mobile.latent)
    );
    let img_mse = stats::mse(&r_base.image, &r_mobile.image);
    println!("image  mse {:.3e} (range ~[-1, 1])", img_mse);

    // ---------------- Fig. 3: float16 GELU instability -------------------
    println!("\n== Fig. 3 / Sec. 3.2: binary16 GELU emulation ==\n");
    let sweep: Vec<f32> = (0..20000)
        .map(|i| -200.0 + i as f32 * 0.02) // [-200, 200)
        .collect();
    for (name, clip) in [("tanh-cubic (baseline)", None), ("clipped, M=10 (ours)", Some(10.0))] {
        let mut bad = 0usize;
        let mut max_err = 0f64;
        for &x in &sweep {
            let (y, nonfinite) = gelu_f16(x, clip);
            if nonfinite {
                bad += 1;
            } else {
                max_err = max_err.max((y as f64 - gelu_f32(x) as f64).abs());
            }
        }
        println!(
            "{:<24} non-finite intermediates: {:>5} / {}   max |err| vs f32 (finite region): {:.3e}",
            name,
            bad,
            sweep.len(),
            max_err
        );
        if clip.is_none() {
            assert!(bad > 0, "baseline must exhibit the instability");
        } else {
            assert_eq!(bad, 0, "clipped GELU must be finite everywhere");
        }
    }

    // overflow threshold (paper: the cubic term; 65504^(1/3) ~= 40.3)
    let mut threshold = None;
    for i in 0..100000 {
        let x = i as f32 * 0.01;
        let (_, nonfinite) = gelu_f16(x, None);
        if nonfinite {
            threshold = Some(x);
            break;
        }
    }
    println!(
        "\nbaseline first non-finite at |x| = {:.2} (expected ~40.3 = 65504^(1/3))",
        threshold.unwrap()
    );
    assert!((threshold.unwrap() - 40.3).abs() < 0.2);

    // equality inside the clip: gamma_10 is the identity for |x| <= 10
    let mut max_delta = 0f32;
    for i in 0..2000 {
        let x = -10.0 + i as f32 * 0.01;
        let (a, _) = gelu_f16(x, None);
        let (b, _) = gelu_f16(x, Some(10.0));
        max_delta = max_delta.max((a - b).abs());
    }
    println!("max |clipped - baseline| for |x| <= 10: {max_delta} (must be 0)");
    assert_eq!(max_delta, 0.0);
}
