//! Fig. 5 / Sec. 3.4 — model compression quality, measured with the
//! paper's own indirect metric: block-wise reconstruction error (Li et
//! al. 2021) of a spatial-transformer block under W8A16 quantization and
//! structured pruning, plus storage footprints and the end-to-end effect
//! of int8 UNet weights on the final latent.

use std::path::Path;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::quant::WeightFile;
use mobile_diffusion::runtime::{ActInput, Component, Engine, Manifest};
use mobile_diffusion::util::rng::Rng;
use mobile_diffusion::util::stats;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; run `make artifacts`");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let engine = Engine::new().unwrap();

    // ---------------- storage footprints --------------------------------
    println!("== Sec. 3.4: weight storage (UNet) ==\n");
    let c = m.component("unet_mobile").unwrap();
    let mut rows = Vec::new();
    for tag in ["fp32", "int8", "int8_pruned"] {
        let wf = WeightFile::load(&m.weight_path(c, tag).unwrap()).unwrap();
        rows.push((tag, wf.stored_bytes()));
    }
    let fp32_bytes = rows[0].1 as f64;
    for (tag, bytes) in &rows {
        println!(
            "{:<12} {:>8.2} MB   ({:.2}x smaller than fp32)",
            tag,
            *bytes as f64 / 1e6,
            fp32_bytes / *bytes as f64
        );
    }

    // ---------------- Fig. 5: block-wise reconstruction error ------------
    println!("\n== Fig. 5: block-wise reconstruction error (spatial-transformer block) ==\n");
    let fp = Component::load(&engine, &m, m.component("block_fp").unwrap(), "fp32").unwrap();
    let w8 = Component::load(&engine, &m, m.component("block_w8").unwrap(), "fp32").unwrap();
    let w8p = Component::load(&engine, &m, m.component("block_w8p").unwrap(), "fp32").unwrap();

    let cdim = 128;
    let size = m.latent_size / 2;
    let mut sum_q = 0.0;
    let mut sum_qp = 0.0;
    let mut sum_sig = 0.0;
    let trials = 5;
    println!("{:<8} {:>14} {:>18}", "input", "err(W8)", "err(W8 + prune)");
    for seed in 0..trials {
        let mut rng = Rng::new(seed as u64 + 100);
        let x = rng.normal_f32_vec(size * size * cdim);
        let ctx = rng.normal_f32_vec(m.tokenizer.seq_len * 128);
        let run = |comp: &Component| {
            comp.run(&engine, &[ActInput::F32(x.clone()), ActInput::F32(ctx.clone())])
                .unwrap()[0]
                .clone()
        };
        let y_fp = run(&fp);
        let e_q = stats::mse(&y_fp, &run(&w8));
        let e_qp = stats::mse(&y_fp, &run(&w8p));
        sum_q += e_q;
        sum_qp += e_qp;
        sum_sig += stats::mse(&y_fp, &vec![0.0; y_fp.len()]);
        println!("{:<8} {:>14.4e} {:>18.4e}", seed, e_q, e_qp);
    }
    let (e_q, e_qp, sig) = (sum_q / trials as f64, sum_qp / trials as f64, sum_sig / trials as f64);
    println!(
        "\nmean:    err(W8) {:.4e}   err(W8+prune) {:.4e}   (signal power {:.3e})",
        e_q, e_qp, sig
    );
    println!(
        "relative: {:.3}% and {:.3}% of signal — paper: 'differences in details, \
         less prominent than [the fp16 instability]'",
        e_q / sig * 100.0,
        e_qp / sig * 100.0
    );
    assert!(e_qp >= e_q, "pruning adds error on top of quantization");
    assert!(e_q / sig < 0.05, "quantization error stays small");
    drop(fp);
    drop(w8);
    drop(w8p);

    // ---------------- end-to-end with int8 UNet weights ------------------
    println!("\n== end-to-end: final latent vs weight precision (8 DDIM steps) ==\n");
    let run_tag = |tag: &str| {
        let mut ex = PipelinedExecutor::new(
            m.clone(),
            ExecOptions {
                num_steps: 8,
                unet_weights: tag.into(),
                ..Default::default()
            },
        )
        .unwrap();
        ex.generate("fig5: a mountain at sunset", 5, "mobile").unwrap()
    };
    let r_fp = run_tag("fp32");
    let peak = r_fp.latent.iter().fold(0f32, |mx, v| mx.max(v.abs())) as f64;
    println!("{:<14} {:>14} {:>10} {:>12}", "weights", "latent mse", "psnr dB", "peak MB");
    println!("{:<14} {:>14} {:>10} {:>12.1}", "fp32", "-", "-", r_fp.peak_memory as f64 / 1e6);
    for tag in ["int8", "int8_pruned"] {
        let r = run_tag(tag);
        println!(
            "{:<14} {:>14.4e} {:>10.1} {:>12.1}",
            tag,
            stats::mse(&r_fp.latent, &r.latent),
            stats::psnr(&r_fp.latent, &r.latent, peak),
            r.peak_memory as f64 / 1e6
        );
        assert!(r.peak_memory < r_fp.peak_memory, "int8 must reduce peak memory");
    }
}
