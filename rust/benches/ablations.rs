//! Ablation study — each Sec. 3.1/3.2 technique toggled independently on
//! the SD v2.1-scale UNet: delegate coverage, CPU-island count, and
//! modeled per-eval / end-to-end latency.  Quantifies how much each
//! rewrite contributes to the Table-1 headline.

use std::path::Path;

use mobile_diffusion::delegate::{graph_cost, RuleSet, CPU_BIGCORE, GPU_ADRENO740};
use mobile_diffusion::graph;
use mobile_diffusion::passes::manager::run_registry;
use mobile_diffusion::passes::serialize_conv::Dim;
use mobile_diffusion::passes::serialize_conv::SerializeConv;
use mobile_diffusion::passes::{Pass, PassRegistry};

const STEPS: usize = 20;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; run `make artifacts`");
        return;
    }
    let base = graph::load(&dir.join("sd_v21_unet.graph.json")).unwrap();
    let rules = RuleSet::default();

    let std_reg = PassRegistry::standard();
    let configs: &[(&str, PassRegistry)] = &[
        ("none (stock export)", PassRegistry::empty()),
        ("groupnorm only", std_reg.subset(&["groupnorm"]).unwrap()),
        ("fc-to-conv only", std_reg.subset(&["fc_to_conv"]).unwrap()),
        (
            "gn + fc-to-conv",
            std_reg.subset(&["groupnorm", "fc_to_conv"]).unwrap(),
        ),
        (
            "gn + fc + serialize",
            std_reg
                .subset(&["groupnorm", "fc_to_conv", "serialize_conv"])
                .unwrap(),
        ),
        ("all (paper + fusions)", std_reg.clone()),
    ];

    println!("== ablation: Sec. 3.1/3.2 passes on the SD v2.1 UNet ==\n");
    println!(
        "{:<24} {:>9} {:>9} {:>12} {:>13} {:>12}",
        "passes", "coverage", "cpu ops", "transitions", "unet eval", "e2e 20 steps"
    );

    let mut prev_total = f64::NAN;
    for (name, reg) in configs {
        let mut g = base.clone();
        let _report = run_registry(&mut g, &rules, &GPU_ADRENO740, reg);
        let cost = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE);
        let e2e = STEPS as f64 * cost.total();
        println!(
            "{:<24} {:>8.2}% {:>9} {:>12} {:>10.1} ms {:>10.1} s",
            name,
            rules.coverage(&g) * 100.0,
            cost.cpu_ops,
            cost.transitions,
            cost.total() * 1e3,
            e2e
        );
        prev_total = e2e;
    }
    let _ = prev_total;

    // ---- serialization dimension ablation (the paper's 15.5 vs 40.9) ---
    println!("\n== ablation: serialization dimension for the failing conv ==\n");
    for (name, dim) in [("input (paper's choice)", Dim::Input), ("output", Dim::Output)] {
        let mut g = base.clone();
        // prerequisite passes so only the conv remains
        run_registry(
            &mut g,
            &rules,
            &GPU_ADRENO740,
            &std_reg.without(&["serialize_conv"]),
        );
        let pass = SerializeConv {
            rules: rules.clone(),
            dev: GPU_ADRENO740,
            force_dim: Some(dim),
        };
        let n = pass.run(&mut g);
        let cost = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE);
        println!(
            "{:<24} rewrote {} conv(s), unet eval {:>7.1} ms, e2e {:>5.1} s",
            name,
            n,
            cost.total() * 1e3,
            STEPS as f64 * cost.total()
        );
    }

    // ---- distilled step-count ablation ----------------------------------
    println!("\n== ablation: progressive-distillation step schedules ==\n");
    let mut g = base.clone();
    run_registry(&mut g, &rules, &GPU_ADRENO740, &std_reg);
    let per_eval = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE).total();
    for steps in [50, 20, 10, 5] {
        println!(
            "{:>3} steps: {:>5.1} s end-to-end (UNet part)",
            steps,
            steps as f64 * per_eval
        );
    }
}
