//! Goodput and tail latency under injected device faults on the stub
//! backend.  Emits `BENCH_chaos.json` (repo root).
//!
//! Two workloads over the same synthetic artifacts and request mix:
//!
//! * **fault-free** — the baseline serving run;
//! * **faulted** — three fixed fault seeds, each a schedule of one
//!   guaranteed transient dispatch fault per worker device plus seeded
//!   random transients and latency spikes; workers absorb them through
//!   checkpoint retry and supervision;
//! * **oom-heavy** — a schedule of guaranteed device-OOM dispatch
//!   faults: workers climb the memory-pressure degradation ladder and
//!   requeue the affected rows *degraded*, never verbatim.
//!
//! The claim is the *shape*: under faults every request still resolves
//! exactly once (ok + failed == submitted), goodput stays positive,
//! and the injected-fault/retry counters surface in the metrics.
//! Absolute numbers are synthetic (stub backend).
//!
//!     cargo bench --bench chaos            # full workload
//!     cargo bench --bench chaos -- --fast  # CI smoke mode

use std::path::Path;
use std::time::{Duration, Instant};

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::testkit::{fake_artifacts_dir, FakeArtifactSpec};

const FAULT_SEEDS: [u64; 3] = [7, 19, 1234];
/// Seed for the OOM-heavy schedule (the seed only drives the random
/// transient stream; the OOMs themselves are scheduled, not drawn).
const OOM_SEED: u64 = 77;

struct RunStats {
    ok: usize,
    failed: usize,
    goodput_rps: f64,
    p50_s: f64,
    p95_s: f64,
    injected_transient: u64,
    retries: usize,
    worker_restarts: usize,
    ooms: usize,
    degraded_retries: usize,
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Serve `n` requests and measure client-observed completion: one
/// receiver thread per request timestamps its own terminal reply, so
/// tail latency is not skewed by in-order draining.
fn run(cfg: &AppConfig, n: usize, expect_faults: bool) -> RunStats {
    let mut server = Server::start(cfg).unwrap();
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let rx = server.submit(&format!("prompt {i}"), i as u64).unwrap();
            (rx, Instant::now())
        })
        .collect();
    let handles: Vec<_> = receivers
        .into_iter()
        .map(|(rx, submitted)| {
            std::thread::spawn(move || {
                let reply = rx.recv().expect("every request gets a terminal reply");
                let latency_s = submitted.elapsed().as_secs_f64();
                assert!(rx.recv().is_err(), "a request must never resolve twice");
                (reply.is_ok(), latency_s)
            })
        })
        .collect();
    let outcomes: Vec<(bool, f64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall_s = t0.elapsed().as_secs_f64();

    let ok = outcomes.iter().filter(|(o, _)| *o).count();
    let failed = outcomes.len() - ok;
    let mut lat: Vec<f64> = outcomes.iter().map(|(_, l)| *l).collect();
    lat.sort_by(|a, b| a.total_cmp(b));

    // injected counters are folded in at session boundaries, which may
    // trail the last reply by a scheduling quantum: bound the wait
    if expect_faults {
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.with_metrics(|m| m.injected_transient == 0) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let (injected_transient, retries, worker_restarts, ooms, degraded_retries) = server
        .with_metrics(|m| {
            (m.injected_transient, m.retries, m.worker_restarts, m.ooms, m.degraded_retries)
        });

    RunStats {
        ok,
        failed,
        goodput_rps: ok as f64 / wall_s.max(1e-12),
        p50_s: quantile(&lat, 0.50),
        p95_s: quantile(&lat, 0.95),
        injected_transient,
        retries,
        worker_restarts,
        ooms,
        degraded_retries,
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("CHAOS_FAST").is_ok();
    let n = if fast { 12 } else { 32 };
    let spec = FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    };
    let dir = fake_artifacts_dir("bench_chaos", &spec).unwrap();
    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 4;
    cfg.num_workers = 2;
    cfg.max_batch = 4;
    cfg.retry_backoff_ms = 1;
    cfg.retry_limit = 6;

    println!(
        "== goodput and tail latency under injected faults (stub backend{}) ==",
        if fast { ", fast mode" } else { "" }
    );
    println!("   {n} requests, 4 steps, 2 workers, retry budget 6\n");

    let baseline = run(&cfg, n, false);
    println!(
        "{:>14} {:>10.1} req/s   p50 {:>7.1} ms   p95 {:>7.1} ms   {} ok",
        "fault-free",
        baseline.goodput_rps,
        baseline.p50_s * 1e3,
        baseline.p95_s * 1e3,
        baseline.ok,
    );

    let mut faulted = Vec::with_capacity(FAULT_SEEDS.len());
    for seed in FAULT_SEEDS {
        let mut fcfg = cfg.clone();
        fcfg.fault_seed = Some(seed);
        fcfg.fault_spec = Some("dispatch:4:transient,rate:0.1,spike:7:1".into());
        let stats = run(&fcfg, n, true);
        println!(
            "{:>14} {:>10.1} req/s   p50 {:>7.1} ms   p95 {:>7.1} ms   {} ok, {} failed, \
             {} injected, {} retries, {} restarts",
            format!("seed {seed}"),
            stats.goodput_rps,
            stats.p50_s * 1e3,
            stats.p95_s * 1e3,
            stats.ok,
            stats.failed,
            stats.injected_transient,
            stats.retries,
            stats.worker_restarts,
        );
        faulted.push((seed, stats));
    }

    // OOM-heavy schedule: guaranteed device-OOM dispatch faults per
    // worker device.  Injected OOMs land in `injected_fatal`/`ooms`,
    // not `injected_transient`, so the transient wait loop is skipped
    // (OOMs are counted in the worker loop before the terminal reply).
    let mut ocfg = cfg.clone();
    ocfg.fault_seed = Some(OOM_SEED);
    ocfg.fault_spec = Some("dispatch:3:oom,dispatch:11:oom".into());
    let oom = run(&ocfg, n, false);
    println!(
        "{:>14} {:>10.1} req/s   p50 {:>7.1} ms   p95 {:>7.1} ms   {} ok, {} failed, \
         {} ooms, {} degraded retries",
        "oom-heavy",
        oom.goodput_rps,
        oom.p50_s * 1e3,
        oom.p95_s * 1e3,
        oom.ok,
        oom.failed,
        oom.ooms,
        oom.degraded_retries,
    );

    let faulted_json: Vec<String> = faulted
        .iter()
        .map(|(seed, s)| {
            format!(
                concat!(
                    "{{\"seed\": {seed}, \"goodput_rps\": {gp:.3}, ",
                    "\"p50_s\": {p50:.6}, \"p95_s\": {p95:.6}, ",
                    "\"ok\": {ok}, \"failed\": {failed}, ",
                    "\"injected_transient\": {inj}, \"retries\": {ret}, ",
                    "\"worker_restarts\": {restarts}, ",
                    "\"ooms\": {ooms}, \"degraded_retries\": {deg}}}"
                ),
                seed = seed,
                gp = s.goodput_rps,
                p50 = s.p50_s,
                p95 = s.p95_s,
                ok = s.ok,
                failed = s.failed,
                inj = s.injected_transient,
                ret = s.retries,
                restarts = s.worker_restarts,
                ooms = s.ooms,
                deg = s.degraded_retries,
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n",
            "\"backend\": \"xla-stub\",\n",
            "\"fast\": {fast},\n",
            "\"requests\": {n},\n",
            "\"baseline\": {{\"goodput_rps\": {bgp:.3}, \"p50_s\": {bp50:.6}, ",
            "\"p95_s\": {bp95:.6}, \"ok\": {bok}}},\n",
            "\"faulted\": [\n{fj}\n],\n",
            "\"oom_heavy\": {{\"seed\": {oseed}, \"goodput_rps\": {ogp:.3}, ",
            "\"p50_s\": {op50:.6}, \"p95_s\": {op95:.6}, ",
            "\"ok\": {ook}, \"failed\": {ofailed}, ",
            "\"ooms\": {ooms}, \"degraded_retries\": {odeg}, ",
            "\"retries\": {oret}}}\n",
            "}}\n"
        ),
        fast = fast,
        n = n,
        bgp = baseline.goodput_rps,
        bp50 = baseline.p50_s,
        bp95 = baseline.p95_s,
        bok = baseline.ok,
        fj = faulted_json.join(",\n"),
        oseed = OOM_SEED,
        ogp = oom.goodput_rps,
        op50 = oom.p50_s,
        op95 = oom.p95_s,
        ook = oom.ok,
        ofailed = oom.failed,
        ooms = oom.ooms,
        odeg = oom.degraded_retries,
        oret = oom.retries,
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_chaos.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("\nwrote {}", out.display());

    if baseline.ok != n || baseline.failed != 0 {
        eprintln!("FAIL: fault-free run lost requests ({} ok of {n})", baseline.ok);
        std::process::exit(1);
    }
    for (seed, s) in &faulted {
        if s.ok + s.failed != n {
            eprintln!(
                "FAIL: seed {seed}: {} ok + {} failed != {n} submitted (lost or duplicated)",
                s.ok, s.failed
            );
            std::process::exit(1);
        }
        if s.injected_transient == 0 {
            eprintln!("FAIL: seed {seed}: the fault schedule injected nothing");
            std::process::exit(1);
        }
        if s.goodput_rps <= 0.0 {
            eprintln!("FAIL: seed {seed}: zero goodput under faults");
            std::process::exit(1);
        }
    }
    if oom.ok + oom.failed != n {
        eprintln!(
            "FAIL: oom-heavy: {} ok + {} failed != {n} submitted (lost or duplicated)",
            oom.ok, oom.failed
        );
        std::process::exit(1);
    }
    if oom.ooms == 0 {
        eprintln!("FAIL: oom-heavy: the fault schedule injected no device OOMs");
        std::process::exit(1);
    }
    if oom.goodput_rps <= 0.0 {
        eprintln!("FAIL: oom-heavy: zero goodput under memory pressure");
        std::process::exit(1);
    }
}
