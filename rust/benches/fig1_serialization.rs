//! Fig. 1 — the two graph rewrites of paper Sec. 3.1:
//!
//!  (a) FullyConnected -> Reshape/1x1-Conv2D/Reshape: delegability flips
//!      while the modeled latency stays ~equal ("almost the same latency
//!      when benchmarked on the GPU");
//!  (b) Conv2D serialization of the 1x32x32x1920 -> 1x32x32x640 layer:
//!      minimal-factor search along each dimension + the latency sweep
//!      that makes the paper pick input serialization (15.5 ms vs
//!      40.9 ms).

use mobile_diffusion::delegate::{
    cost, op_latency, RuleSet, GPU_ADRENO740,
};
use mobile_diffusion::graph::builder::GraphBuilder;
use mobile_diffusion::passes::serialize_conv::{minimal_factor, plan, Dim};

fn main() {
    let rules = RuleSet::default();
    let dev = &GPU_ADRENO740;

    // ---------------- Fig. 1a: FC -> Conv2D -----------------------------
    println!("== Fig. 1a: FullyConnected -> 1x1 Conv2D (1x4096x320 -> 1280) ==\n");
    let mut b = GraphBuilder::new("fc");
    let x = b.input("x", &[1, 4096, 320]);
    b.fully_connected("fc", x, 1280);
    let g_fc = b.finish();

    let mut b = GraphBuilder::new("conv");
    let x = b.input("x", &[1, 1, 4096, 320]);
    b.conv2d("conv1x1", x, 1280, 1, 1);
    let g_conv = b.finish();

    let fc_ok = rules.check(&g_fc, &g_fc.ops[0]).ok();
    let conv_ok = rules.check(&g_conv, &g_conv.ops[0]).ok();
    let t_fc = op_latency(&g_fc, &g_fc.ops[0], dev);
    let t_conv = op_latency(&g_conv, &g_conv.ops[0], dev);
    println!("{:<28} delegable={:<5}  modeled latency {:>7.2} ms",
             "FULLY_CONNECTED", fc_ok, t_fc * 1e3);
    println!("{:<28} delegable={:<5}  modeled latency {:>7.2} ms",
             "RESHAPE/CONV_2D/RESHAPE", conv_ok, t_conv * 1e3);
    assert!(!fc_ok && conv_ok, "conversion must flip delegability");
    let rel = (t_fc - t_conv).abs() / t_fc;
    println!("latency delta: {:.1}% (paper: 'almost the same latency')\n", rel * 100.0);
    assert!(rel < 0.05);

    // ---------------- Fig. 1b: serialization sweep ----------------------
    println!("== Fig. 1b: serialization of conv 1x32x32x1920 -> 1x32x32x640 ==\n");
    let (h, w, cin, cout, k) = (32, 32, 1920, 640, 3);

    println!("{:<10} {:>8} {:>14} {:>12}", "dimension", "factor", "delegable", "latency");
    for (dim, along_input) in [(Dim::Input, true), (Dim::Output, false)] {
        let channels = if along_input { cin } else { cout };
        for factor in [1usize, 2, 4, 5, 8, 16] {
            if channels % factor != 0 {
                continue;
            }
            let (ci, co) = if along_input { (cin / factor, cout) } else { (cin, cout / factor) };
            let ok = {
                let mut b = GraphBuilder::new("probe");
                let x = b.input("x", &[1, h, w, ci]);
                b.conv2d("c", x, co, k, 1);
                let g = b.finish();
                rules.check(&g, &g.ops[0]).ok()
            };
            let t = cost::serialized_conv_latency(h, w, cin, cout, k, factor, along_input, dev);
            println!(
                "{:<10} {:>8} {:>14} {:>9.1} ms",
                format!("{dim:?}"),
                factor,
                ok,
                t * 1e3
            );
        }
    }

    let f_in = minimal_factor(&rules, h, w, cin, cout, k, Dim::Input).unwrap();
    let f_out = minimal_factor(&rules, h, w, cin, cout, k, Dim::Output).unwrap();
    let t_in = cost::serialized_conv_latency(h, w, cin, cout, k, f_in, true, dev);
    let t_out = cost::serialized_conv_latency(h, w, cin, cout, k, f_out, false, dev);
    println!("\nminimal factors: input {f_in} (paper: 2), output {f_out} (paper: 8)");
    println!(
        "latency at minimal factor: input {:.1} ms (paper: 15.5), output {:.1} ms (paper: 40.9)",
        t_in * 1e3,
        t_out * 1e3
    );
    assert_eq!((f_in, f_out), (2, 8));

    let p = plan(&rules, dev, h, w, cin, cout, k).unwrap();
    println!("chosen plan: {:?} serialization, factor {} (paper chose input)", p.dim, p.factor);
    assert_eq!(p.dim, Dim::Input);
}
