//! Serving behaviour under device memory pressure on the stub
//! backend.  Emits `BENCH_pressure.json` (repo root).
//!
//! Two runs over the same synthetic artifacts and request mix:
//!
//! * **uncapped** — capacity mode off, the reference goodput;
//! * **capped** — `--device-mem` calibrated *between* a 1-wide and a
//!   4-wide working set, so multi-row sessions OOM organically and the
//!   workers climb the degradation ladder (shrink seats, shed the warm
//!   tier, W8A8 under the learned budget) instead of retrying verbatim.
//!
//! The claim is the *shape*: under a capacity cap every request still
//! resolves exactly once via degraded retries, the OOM/degraded
//! counters surface, and the governor walks away with a learned
//! effective budget at or below the shipped one.  Absolute numbers are
//! synthetic (stub backend).
//!
//!     cargo bench --bench pressure            # full workload
//!     cargo bench --bench pressure -- --fast  # CI smoke mode

use std::path::Path;
use std::time::Instant;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::Server;
use mobile_diffusion::pipeline::{BatchRequest, ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::testkit::{fake_artifacts_dir, FakeArtifactSpec};

struct RunStats {
    ok: usize,
    failed: usize,
    goodput_rps: f64,
    p50_s: f64,
    p95_s: f64,
    ooms: usize,
    degraded_retries: usize,
    shipped_budget: usize,
    effective_budget: usize,
    level: u8,
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Measure the device-byte peak of a `width`-wide fault-free batch on
/// a fresh uncapped executor — the calibration for the capacity cap.
fn measured_peak(dir: &Path, width: usize) -> u64 {
    let m = Manifest::load(dir).unwrap();
    let mut ex =
        PipelinedExecutor::new(m, ExecOptions { num_steps: 4, ..Default::default() }).unwrap();
    let batch: Vec<BatchRequest> =
        (0..width).map(|i| BatchRequest::new(&format!("prompt {i}"), i as u64)).collect();
    for r in ex.generate_batch(&batch, "mobile") {
        r.unwrap();
    }
    ex.engine.device_stats().mem_peak()
}

/// Serve `n` requests, one receiver thread per request, and fold in
/// the pool metrics plus the governor's learned budget.
fn run(cfg: &AppConfig, n: usize) -> RunStats {
    let mut server = Server::start(cfg).unwrap();
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let rx = server.submit(&format!("prompt {i}"), i as u64).unwrap();
            (rx, Instant::now())
        })
        .collect();
    let handles: Vec<_> = receivers
        .into_iter()
        .map(|(rx, submitted)| {
            std::thread::spawn(move || {
                let reply = rx.recv().expect("every request gets a terminal reply");
                let latency_s = submitted.elapsed().as_secs_f64();
                assert!(rx.recv().is_err(), "a request must never resolve twice");
                (reply.is_ok(), latency_s)
            })
        })
        .collect();
    let outcomes: Vec<(bool, f64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall_s = t0.elapsed().as_secs_f64();

    let ok = outcomes.iter().filter(|(o, _)| *o).count();
    let failed = outcomes.len() - ok;
    let mut lat: Vec<f64> = outcomes.iter().map(|(_, l)| *l).collect();
    lat.sort_by(|a, b| a.total_cmp(b));

    let (ooms, degraded_retries) = server.with_metrics(|m| (m.ooms, m.degraded_retries));
    let gov = server.pressure();
    RunStats {
        ok,
        failed,
        goodput_rps: ok as f64 / wall_s.max(1e-12),
        p50_s: quantile(&lat, 0.50),
        p95_s: quantile(&lat, 0.95),
        ooms,
        degraded_retries,
        shipped_budget: gov.shipped_budget(0),
        effective_budget: gov.effective_budget(0),
        level: gov.level(0),
    }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("PRESSURE_FAST").is_ok();
    let n = if fast { 8 } else { 24 };
    let spec = FakeArtifactSpec {
        unet_weight_elems: 4_096,
        encoder_weight_elems: 512,
        decoder_weight_elems: 512,
        ..Default::default()
    };
    let dir = fake_artifacts_dir("bench_pressure", &spec).unwrap();

    let peak1 = measured_peak(&dir, 1);
    let peak4 = measured_peak(&dir, 4);
    // one row fits with margin; two or more rows exceed the cap
    let cap = peak1 + (peak4 - peak1) / 4;

    let mut cfg = AppConfig::default();
    cfg.artifacts_dir = dir;
    cfg.num_steps = 4;
    cfg.num_workers = 1;
    cfg.max_batch = 4;
    cfg.retry_backoff_ms = 1;
    cfg.retry_limit = 4;
    // a finite planner budget gives the governor a shipped byte figure
    cfg.memory_budget_mb = 64.0;

    println!(
        "== serving under device memory pressure (stub backend{}) ==",
        if fast { ", fast mode" } else { "" }
    );
    println!(
        "   {n} requests, 4 steps, 1 worker, seat cap 4; device cap {cap} B \
         (1-wide peak {peak1} B, 4-wide peak {peak4} B)\n"
    );

    let uncapped = run(&cfg, n);
    println!(
        "{:>10} {:>10.1} req/s   p50 {:>7.1} ms   p95 {:>7.1} ms   {} ok",
        "uncapped",
        uncapped.goodput_rps,
        uncapped.p50_s * 1e3,
        uncapped.p95_s * 1e3,
        uncapped.ok,
    );

    let mut ccfg = cfg.clone();
    ccfg.device_mem_mb = Some(cap as f64 / 1e6);
    let capped = run(&ccfg, n);
    println!(
        "{:>10} {:>10.1} req/s   p50 {:>7.1} ms   p95 {:>7.1} ms   {} ok, {} failed, \
         {} ooms, {} degraded retries, budget {} -> {} B (rung {})",
        "capped",
        capped.goodput_rps,
        capped.p50_s * 1e3,
        capped.p95_s * 1e3,
        capped.ok,
        capped.failed,
        capped.ooms,
        capped.degraded_retries,
        capped.shipped_budget,
        capped.effective_budget,
        capped.level,
    );

    let json = format!(
        concat!(
            "{{\n",
            "\"backend\": \"xla-stub\",\n",
            "\"fast\": {fast},\n",
            "\"requests\": {n},\n",
            "\"device_cap_bytes\": {cap},\n",
            "\"peak1_bytes\": {peak1},\n",
            "\"peak4_bytes\": {peak4},\n",
            "\"uncapped\": {{\"goodput_rps\": {ugp:.3}, \"p50_s\": {up50:.6}, ",
            "\"p95_s\": {up95:.6}, \"ok\": {uok}}},\n",
            "\"capped\": {{\"goodput_rps\": {cgp:.3}, \"p50_s\": {cp50:.6}, ",
            "\"p95_s\": {cp95:.6}, \"ok\": {cok}, \"failed\": {cfailed}, ",
            "\"ooms\": {cooms}, \"degraded_retries\": {cdeg}, ",
            "\"shipped_budget\": {cship}, \"effective_budget\": {ceff}, ",
            "\"level\": {clevel}}}\n",
            "}}\n"
        ),
        fast = fast,
        n = n,
        cap = cap,
        peak1 = peak1,
        peak4 = peak4,
        ugp = uncapped.goodput_rps,
        up50 = uncapped.p50_s,
        up95 = uncapped.p95_s,
        uok = uncapped.ok,
        cgp = capped.goodput_rps,
        cp50 = capped.p50_s,
        cp95 = capped.p95_s,
        cok = capped.ok,
        cfailed = capped.failed,
        cooms = capped.ooms,
        cdeg = capped.degraded_retries,
        cship = capped.shipped_budget,
        ceff = capped.effective_budget,
        clevel = capped.level,
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_pressure.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("\nwrote {}", out.display());

    if uncapped.ok != n || uncapped.failed != 0 {
        eprintln!("FAIL: uncapped run lost requests ({} ok of {n})", uncapped.ok);
        std::process::exit(1);
    }
    if uncapped.ooms != 0 {
        eprintln!("FAIL: uncapped run hit {} OOMs with capacity mode off", uncapped.ooms);
        std::process::exit(1);
    }
    if capped.ok != n {
        eprintln!(
            "FAIL: capped: {} ok + {} failed of {n} — degraded retries must absorb the cap",
            capped.ok, capped.failed
        );
        std::process::exit(1);
    }
    if capped.ooms == 0 {
        eprintln!("FAIL: capped: the capacity cap never bit (calibration off?)");
        std::process::exit(1);
    }
    if capped.degraded_retries == 0 {
        eprintln!("FAIL: capped: OOM'd rows were not retried degraded");
        std::process::exit(1);
    }
    if capped.effective_budget > capped.shipped_budget {
        eprintln!(
            "FAIL: capped: learned budget {} exceeds shipped {}",
            capped.effective_budget, capped.shipped_budget
        );
        std::process::exit(1);
    }
    if capped.goodput_rps <= 0.0 {
        eprintln!("FAIL: capped: zero goodput under memory pressure");
        std::process::exit(1);
    }
}
