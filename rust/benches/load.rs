//! Cold vs warm component acquisition and fleet startup on the stub
//! backend.  Emits `BENCH_load.json` (repo root).
//!
//! Three measurements over synthetic int8 STUBHLO artifacts (int8 so
//! the cold path pays a real dequant stage):
//!
//! * **cold acquire** — fresh store + fresh executor: disk read, MDWB
//!   parse, dequant, HLO compile, device upload;
//! * **warm acquire** — same executor after an eviction: the host half
//!   comes from the artifact store, the executable from the residency
//!   warm tier, so only the device upload is paid;
//! * **fleet startup** — 4 workers acquiring every component through
//!   one shared store vs 4 private stores (the pre-store world).
//!
//! The claim is the *shape*: warm reload >= 5x faster than cold, and a
//! shared-store fleet does 1 disk load per component instead of 1 per
//! worker.  Absolute numbers are synthetic (stub backend).
//!
//!     cargo bench --bench load            # full workload
//!     cargo bench --bench load -- --fast  # CI smoke mode

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use mobile_diffusion::pipeline::{ExecOptions, PipelinedExecutor};
use mobile_diffusion::runtime::{ArtifactStore, Manifest};
use mobile_diffusion::testkit::{fake_artifacts_dir, FakeArtifactSpec};

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

fn opts() -> ExecOptions {
    ExecOptions { unet_weights: "int8".into(), ..Default::default() }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("LOAD_FAST").is_ok();
    let spec = FakeArtifactSpec {
        int8_unet: true,
        unet_weight_elems: if fast { 262_144 } else { 1_048_576 },
        ..Default::default()
    };
    let iters = if fast { 7 } else { 15 };
    let dir = fake_artifacts_dir("bench_load", &spec).unwrap();
    let m = Manifest::load(&dir).unwrap();
    println!(
        "== cold vs warm component acquisition (stub backend{}) ==",
        if fast { ", fast mode" } else { "" }
    );
    println!("   int8 UNet, {} weight elements, {iters} iterations\n", spec.unet_weight_elems);

    // ---- cold: fresh store + executor every time ----------------------
    let mut cold_samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let mut ex = PipelinedExecutor::new(m.clone(), opts()).unwrap();
        let t0 = Instant::now();
        ex.ensure_unet("mobile").unwrap();
        cold_samples.push(t0.elapsed().as_secs_f64());
    }
    let cold_s = median(&mut cold_samples);

    // ---- warm: evict between acquires, same store + warm tier ---------
    let mut ex = PipelinedExecutor::new(m.clone(), opts()).unwrap();
    ex.ensure_unet("mobile").unwrap(); // prime store + warm tier
    let primed = ex.load_profile().clone();
    let mut warm_samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        ex.evict_idle(); // budget-eviction stand-in: demotes to warm
        let t0 = Instant::now();
        ex.ensure_unet("mobile").unwrap();
        warm_samples.push(t0.elapsed().as_secs_f64());
    }
    let warm_s = median(&mut warm_samples);
    // stage accounting over the warm reloads alone (prime excluded)
    let profile = ex.load_profile().since(&primed);
    assert_eq!(profile.warm_reloads as usize, iters, "every re-acquire was warm");

    let speedup = cold_s / warm_s.max(1e-12);
    println!("{:>18} {:>12}", "path", "median");
    println!("{:>18} {:>9.3} ms", "cold acquire", cold_s * 1e3);
    println!("{:>18} {:>9.3} ms", "warm acquire", warm_s * 1e3);
    println!("\nwarm reload speedup: {speedup:.1}x (upload-only vs read+parse+dequant+compile+upload)");

    // ---- fleet-of-4 startup: shared store vs private stores -----------
    let fleet_workers = 4usize;
    let acquire_all = |store: Arc<ArtifactStore>| {
        let handles: Vec<_> = (0..fleet_workers)
            .map(|_| {
                let m = m.clone();
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    let mut ex =
                        PipelinedExecutor::with_store(m, opts(), store).unwrap();
                    ex.ensure_unet("mobile").unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    };
    let shared = Arc::new(ArtifactStore::new());
    let t0 = Instant::now();
    acquire_all(Arc::clone(&shared));
    let fleet_shared_s = t0.elapsed().as_secs_f64();
    let shared_loads = shared.disk_loads();
    let shared_hits = shared.hits();

    // private store per worker (the pre-store world): same 4 threads,
    // but every worker pays its own disk read + parse + dequant
    let t0 = Instant::now();
    let handles: Vec<_> = (0..fleet_workers)
        .map(|_| {
            let m = m.clone();
            std::thread::spawn(move || acquire_all_private(&m))
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let fleet_private_s = t0.elapsed().as_secs_f64();

    println!(
        "\nfleet-of-{fleet_workers} startup: shared store {:.1} ms ({shared_loads} disk loads, \
         {shared_hits} hits) vs private stores {:.1} ms ({fleet_workers} disk loads)",
        fleet_shared_s * 1e3,
        fleet_private_s * 1e3,
    );

    let json = format!(
        concat!(
            "{{\n",
            "\"backend\": \"xla-stub\",\n",
            "\"fast\": {fast},\n",
            "\"unet_weight_elems\": {elems},\n",
            "\"iterations\": {iters},\n",
            "\"cold_acquire_s\": {cold:.6},\n",
            "\"warm_acquire_s\": {warm:.6},\n",
            "\"warm_speedup\": {speedup:.2},\n",
            "\"warm_stage_s\": {{\"read\": {read:.6}, \"parse\": {parse:.6}, ",
            "\"dequant\": {dequant:.6}, \"compile\": {compile:.6}, ",
            "\"upload\": {upload:.6}}},\n",
            "\"fleet\": {{\"workers\": {workers}, ",
            "\"shared_store_startup_s\": {fss:.6}, ",
            "\"private_store_startup_s\": {fps:.6}, ",
            "\"shared_disk_loads\": {sdl}, \"shared_store_hits\": {ssh}}}\n",
            "}}\n"
        ),
        fast = fast,
        elems = spec.unet_weight_elems,
        iters = iters,
        cold = cold_s,
        warm = warm_s,
        speedup = speedup,
        read = profile.read_s,
        parse = profile.parse_s,
        dequant = profile.dequant_s,
        compile = profile.compile_s,
        upload = profile.upload_s,
        workers = fleet_workers,
        fss = fleet_shared_s,
        fps = fleet_private_s,
        sdl = shared_loads,
        ssh = shared_hits,
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_load.json");
    if let Err(e) = std::fs::write(&out, &json) {
        eprintln!("could not write {}: {e}", out.display());
        std::process::exit(1);
    }
    println!("wrote {}", out.display());

    if shared_loads != 1 {
        eprintln!("FAIL: shared store did {shared_loads} disk loads for one component");
        std::process::exit(1);
    }
    if speedup < 5.0 {
        eprintln!("FAIL: warm reload only {speedup:.1}x faster than cold (want >= 5x)");
        std::process::exit(1);
    }
}

/// One worker with a private store — the pre-store cold world.
fn acquire_all_private(m: &Manifest) {
    let mut ex = PipelinedExecutor::new(m.clone(), opts()).unwrap();
    ex.ensure_unet("mobile").unwrap();
}
