//! Process-wide host-artifact store: the cacheable half of a component
//! load, shared by every fleet worker and every reload cycle.
//!
//! A component load splits into two halves with very different costs
//! and lifetimes:
//!
//! * the **host half** — disk read of the MDWB weight container, parse,
//!   int8 dequantization — is immutable, `Send + Sync`, and identical
//!   for every worker.  It lives here as an [`Arc<HostArtifact>`],
//!   loaded from disk **exactly once per process** no matter how many
//!   workers race for it or how many eviction/reload cycles a worker
//!   goes through;
//! * the **device half** — HLO compile + weight-buffer upload — is
//!   per-worker (PJRT handles are not `Send`) and stays in
//!   [`crate::runtime::engine::Component`].
//!
//! Concurrency: a per-key slot mutex serializes loaders of the *same*
//! `(component, tag)` — the second worker blocks until the first
//! finishes and then takes the cached artifact (a hit, no disk) —
//! while loads of different keys proceed in parallel.  The outer map
//! lock is held only long enough to find or create a slot.
//!
//! One store serves one artifact directory (keys are `(component,
//! tag)`); the server creates a single store and threads it into every
//! pool worker's executor factory.
//!
//! Host memory: cached artifacts live **outside** the device memory
//! ledger by design — the ledger keeps bounding resident device bytes
//! while the store trades host RAM for never paying a cold load twice
//! (int8 entries additionally pin their one-time dequantized f32 rows,
//! ~4 bytes/elem beyond the at-rest size).  The cache is unbounded and
//! process-lifetime; [`ArtifactStore::invalidate`] is the pressure
//! valve for hosts that must shed a tag (e.g. after an on-disk
//! artifact refresh or to drop a precision no longer served).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::quant::{Payload, WeightFile, WeightTensor};
use crate::runtime::artifact::{ComponentManifest, Manifest};

/// Wall-clock cost of the host half of one cold load, per stage.
#[derive(Debug, Clone, Default)]
pub struct HostLoadStats {
    /// disk read of the weight container
    pub read_s: f64,
    /// MDWB parse
    pub parse_s: f64,
    /// int8 -> dense f32 dequantization (zero for pure-fp32 containers)
    pub dequant_s: f64,
    /// container bytes read from disk
    pub bytes_read: usize,
}

impl HostLoadStats {
    pub fn total_s(&self) -> f64 {
        self.read_s + self.parse_s + self.dequant_s
    }
}

/// The immutable host half of a loaded component: parsed weight
/// container, pre-dequantized f32 rows for int8 tensors, and the HLO
/// text path the device half compiles from.
#[derive(Debug)]
pub struct HostArtifact {
    pub component: String,
    pub tag: String,
    pub hlo_path: PathBuf,
    pub weights: WeightFile,
    /// dense f32 rows for int8 tensors, dequantized exactly once per
    /// process (fp32 tensors are served as borrowed views instead)
    dequant: BTreeMap<String, Vec<f32>>,
    pub stats: HostLoadStats,
}

impl HostArtifact {
    /// Cold-load the host half: read, parse, dequantize — each stage
    /// timed separately so the observed overhead can feed the planner.
    pub fn load(
        component: &str,
        tag: &str,
        hlo_path: PathBuf,
        weight_path: &Path,
    ) -> Result<HostArtifact> {
        let t0 = Instant::now();
        let raw = std::fs::read(weight_path)
            .map_err(|e| Error::Weights(format!("{}: {}", weight_path.display(), e)))?;
        let read_s = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let weights = WeightFile::parse(&raw)?;
        let parse_s = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let mut dequant = BTreeMap::new();
        for (path, t) in &weights.tensors {
            if matches!(t.payload, Payload::I8 { .. }) {
                dequant.insert(path.clone(), t.to_f32().into_owned());
            }
        }
        let dequant_s = t2.elapsed().as_secs_f64();

        Ok(HostArtifact {
            component: component.to_string(),
            tag: tag.to_string(),
            hlo_path,
            weights,
            dequant,
            stats: HostLoadStats { read_s, parse_s, dequant_s, bytes_read: raw.len() },
        })
    }

    pub fn tensor(&self, path: &str) -> Option<&WeightTensor> {
        self.weights.tensors.get(path)
    }

    /// Borrowed dense f32 view of a tensor: fp32 payloads alias the
    /// parsed container, int8 payloads alias the store's one-time
    /// dequant cache.  Neither allocates.
    pub fn dense_f32(&self, path: &str) -> Option<&[f32]> {
        let t = self.weights.tensors.get(path)?;
        match &t.payload {
            Payload::F32(v) => Some(v.as_slice()),
            Payload::I8 { .. } => self.dequant.get(path).map(|v| v.as_slice()),
        }
    }

    /// At-rest byte count (the memory-ledger number).
    pub fn stored_bytes(&self) -> usize {
        self.weights.stored_bytes()
    }
}

type Slot = Arc<Mutex<Option<Arc<HostArtifact>>>>;

/// Thread-safe cache of [`HostArtifact`]s keyed by `(component, tag)`.
#[derive(Debug, Default)]
pub struct ArtifactStore {
    slots: Mutex<BTreeMap<(String, String), Slot>>,
    disk_loads: AtomicU64,
    hits: AtomicU64,
}

impl ArtifactStore {
    pub fn new() -> ArtifactStore {
        ArtifactStore::default()
    }

    /// The cached artifact for `(comp, tag)`, loading it from disk on
    /// first use.  Returns `(artifact, hit)` — `hit` is false exactly
    /// when *this* call paid the disk read/parse/dequant.
    pub fn get_or_load(
        &self,
        manifest: &Manifest,
        comp: &ComponentManifest,
        tag: &str,
    ) -> Result<(Arc<HostArtifact>, bool)> {
        self.get_or_load_paths(
            &comp.name,
            tag,
            manifest.hlo_path(comp),
            manifest.weight_path(comp, tag)?,
        )
    }

    /// Path-level entry point for callers that cannot hold a manifest
    /// reference (the prefetch child thread ships owned paths instead).
    pub fn get_or_load_paths(
        &self,
        component: &str,
        tag: &str,
        hlo_path: PathBuf,
        weight_path: PathBuf,
    ) -> Result<(Arc<HostArtifact>, bool)> {
        let slot = {
            let mut slots = self.slots.lock().unwrap();
            Arc::clone(
                slots
                    .entry((component.to_string(), tag.to_string()))
                    .or_default(),
            )
        };
        // per-key lock: racing loaders of the same key serialize here
        // and all but the first observe a hit
        let mut guard = slot.lock().unwrap();
        if let Some(a) = guard.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((Arc::clone(a), true));
        }
        let loaded = Arc::new(HostArtifact::load(component, tag, hlo_path, &weight_path)?);
        self.disk_loads.fetch_add(1, Ordering::Relaxed);
        *guard = Some(Arc::clone(&loaded));
        Ok((loaded, false))
    }

    /// Cache lookups served without touching disk.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cold loads that read and parsed the container from disk.
    pub fn disk_loads(&self) -> u64 {
        self.disk_loads.load(Ordering::Relaxed)
    }

    /// Number of artifacts currently cached.  Non-blocking: a key
    /// whose cold load is still in flight (slot locked) counts as not
    /// cached, and the map lock is released before any slot is probed
    /// so a metrics poll never stalls other keys' loads.
    pub fn cached(&self) -> usize {
        let slots: Vec<Slot> = self.slots.lock().unwrap().values().cloned().collect();
        slots
            .iter()
            .filter(|s| s.try_lock().map(|g| g.is_some()).unwrap_or(false))
            .count()
    }

    /// Drop a cached artifact (e.g. after an on-disk artifact refresh);
    /// returns whether anything was cached under the key.
    pub fn invalidate(&self, component: &str, tag: &str) -> bool {
        let slot = self
            .slots
            .lock()
            .unwrap()
            .get(&(component.to_string(), tag.to_string()))
            .cloned();
        match slot {
            Some(s) => s.lock().unwrap().take().is_some(),
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Minimal MDWB bytes: one fp32 tensor "w" of `n` elements.
    fn mdwb_f32(n: usize) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"MDWB");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(1u16).to_le_bytes());
        out.extend_from_slice(b"w");
        out.push(0);
        out.push(1);
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for i in 0..n {
            out.extend_from_slice(&(i as f32).to_le_bytes());
        }
        out
    }

    fn write_container(label: &str, n: usize) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("md_store_test_{label}"));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        std::fs::write(&p, mdwb_f32(n)).unwrap();
        p
    }

    #[test]
    fn second_lookup_is_a_hit_not_a_disk_load() {
        let wp = write_container("hit", 8);
        let store = ArtifactStore::new();
        let (a, hit) = store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp.clone())
            .unwrap();
        assert!(!hit);
        assert_eq!(a.dense_f32("w").unwrap().len(), 8);
        let (b, hit) = store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp)
            .unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b), "same parsed container");
        assert_eq!(store.disk_loads(), 1);
        assert_eq!(store.hits(), 1);
        assert_eq!(store.cached(), 1);
    }

    #[test]
    fn different_tags_cache_separately() {
        let wp = write_container("tags", 4);
        let store = ArtifactStore::new();
        store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp.clone())
            .unwrap();
        store
            .get_or_load_paths("c", "int8", PathBuf::from("c.hlo"), wp)
            .unwrap();
        assert_eq!(store.disk_loads(), 2);
        assert_eq!(store.cached(), 2);
    }

    #[test]
    fn failed_loads_are_not_cached() {
        let store = ArtifactStore::new();
        let missing = PathBuf::from("/nonexistent/md_store/w.bin");
        assert!(store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), missing)
            .is_err());
        assert_eq!(store.disk_loads(), 0);
        assert_eq!(store.cached(), 0);
        // a later load of the (now present) file succeeds fresh
        let wp = write_container("retry", 2);
        assert!(store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp)
            .is_ok());
        assert_eq!(store.disk_loads(), 1);
    }

    #[test]
    fn racing_threads_trigger_exactly_one_disk_load() {
        let wp = write_container("race", 64);
        let store = Arc::new(ArtifactStore::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let store = Arc::clone(&store);
                let wp = wp.clone();
                thread::spawn(move || {
                    store
                        .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp)
                        .unwrap()
                        .0
                        .stored_bytes()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 64 * 4);
        }
        assert_eq!(store.disk_loads(), 1, "one cold load for the whole race");
        assert_eq!(store.hits(), 7);
    }

    #[test]
    fn invalidate_forces_a_reload() {
        let wp = write_container("inval", 4);
        let store = ArtifactStore::new();
        store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp.clone())
            .unwrap();
        assert!(store.invalidate("c", "fp32"));
        assert!(!store.invalidate("c", "fp32"), "already empty");
        assert!(!store.invalidate("ghost", "fp32"));
        let (_, hit) = store
            .get_or_load_paths("c", "fp32", PathBuf::from("c.hlo"), wp)
            .unwrap();
        assert!(!hit);
        assert_eq!(store.disk_loads(), 2);
    }
}
