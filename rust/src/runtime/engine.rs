//! PJRT execution engine: load HLO text artifacts, keep weights resident
//! as device buffers, execute with fresh activation inputs.
//!
//! Mirrors the deployment reality the paper describes: model *programs*
//! are compiled once at load; weights are stored compressed (int8) and
//! cast up once at load time (W8A16); per-request work is activation
//! upload + execute only.  Python never appears here.
//!
//! The load path is two-tier (see [`crate::runtime::store`]): the host
//! half (read/parse/dequant) comes from the shared [`HostArtifact`]
//! store, the device half (compile + upload) happens here.  A **warm**
//! load additionally reuses a previously compiled executable (kept by
//! the residency layer across evictions), paying only the upload.

use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ComponentManifest, Manifest};
use crate::runtime::store::{HostArtifact, HostLoadStats};

/// Map a backend error into the crate taxonomy.  Injected faults (and,
/// with real bindings, the PJRT status codes) carry a classification
/// that decides retry vs fail vs worker restart — see `error.rs`.
fn xerr(e: xla::Error) -> Error {
    match e.fault_kind() {
        Some(xla::FaultKind::Transient) => Error::Transient(e.to_string()),
        Some(xla::FaultKind::Oom) => Error::Oom(e.to_string()),
        Some(xla::FaultKind::DeviceLost) => Error::DeviceLost(e.to_string()),
        Some(xla::FaultKind::Fatal) | None => Error::Xla(e.to_string()),
    }
}

/// Shared PJRT client (CPU plugin).
pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// This engine's device counters (transfers, in-place writes,
    /// per-program dispatches) — used by tests and benchmarks to pin
    /// down hot-loop behaviour without instrumenting the loop itself.
    pub fn device_stats(&self) -> std::sync::Arc<xla::DeviceStats> {
        self.client.stats()
    }

    /// Compile an HLO-text artifact.
    pub fn compile_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xerr)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(xerr)
    }
}

/// Stage-level cost of one component load.  The host stages are zero
/// when the artifact store already held the component (a store hit);
/// `compile_s` is zero on a warm reload (executable reused).
#[derive(Debug, Clone, Default)]
pub struct LoadStats {
    /// disk read of the weight container (host half)
    pub read_s: f64,
    /// MDWB parse (host half)
    pub parse_s: f64,
    /// int8 -> f32 dequantization (host half)
    pub dequant_s: f64,
    /// HLO compile (device half; zero when `warm`)
    pub compile_s: f64,
    /// weight-buffer upload to the device (device half; always paid)
    pub upload_s: f64,
    pub weight_bytes_stored: usize,
    pub weight_bytes_resident: usize,
    /// the host half came from the process-wide artifact store cache
    pub store_hit: bool,
    /// the executable came from the warm tier (no compile this load)
    pub warm: bool,
}

impl LoadStats {
    /// Wall seconds this load spent across every stage.
    pub fn total_s(&self) -> f64 {
        self.read_s + self.parse_s + self.dequant_s + self.compile_s + self.upload_s
    }
}

/// A compiled executable handle shareable across reloads *within one
/// worker thread* (PJRT executables are not `Send`).  The residency
/// layer keeps these in its warm tier after eviction so a re-acquire
/// skips the compile.
pub type WarmExecutable = Rc<xla::PjRtLoadedExecutable>;

/// A loaded, executable model component with resident weight buffers.
pub struct Component {
    pub name: String,
    exe: WarmExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub act_shapes: Vec<Vec<usize>>,
    pub act_dtypes: Vec<String>,
    pub stats: LoadStats,
}

impl Component {
    /// One-shot cold load without a shared store (offline tools, tests
    /// over real artifacts): read + parse + compile + upload.
    pub fn load(
        engine: &Engine,
        manifest: &Manifest,
        comp: &ComponentManifest,
        weights_tag: &str,
    ) -> Result<Component> {
        let host = HostArtifact::load(
            &comp.name,
            weights_tag,
            manifest.hlo_path(comp),
            &manifest.weight_path(comp, weights_tag)?,
        )?;
        Self::load_from_host(engine, comp, &host, None, false)
    }

    /// Device half of a load over a (possibly store-cached) host
    /// artifact: compile the HLO — or reuse `warm_exe` from the
    /// residency warm tier — and upload the dense weights in manifest
    /// order.  `store_hit` says whether *this* load paid the host
    /// stages; it only affects the reported [`LoadStats`].
    pub fn load_from_host(
        engine: &Engine,
        comp: &ComponentManifest,
        host: &HostArtifact,
        warm_exe: Option<WarmExecutable>,
        store_hit: bool,
    ) -> Result<Component> {
        let warm = warm_exe.is_some();
        let t0 = Instant::now();
        let exe = match warm_exe {
            Some(e) => e,
            None => Rc::new(engine.compile_hlo(&host.hlo_path)?),
        };
        let compile_s = if warm { 0.0 } else { t0.elapsed().as_secs_f64() };

        let t1 = Instant::now();
        let stored = host.stored_bytes();
        let mut weight_bufs = Vec::with_capacity(comp.params.len());
        let mut resident = 0usize;
        for p in &comp.params {
            let t = host.tensor(&p.path).ok_or_else(|| {
                Error::Weights(format!("weight file missing {}", p.path))
            })?;
            if t.shape != p.spec.shape {
                return Err(Error::Weights(format!(
                    "{}: shape {:?} != manifest {:?}",
                    p.path, t.shape, p.spec.shape
                )));
            }
            let dense = host.dense_f32(&p.path).ok_or_else(|| {
                Error::Weights(format!("no dense view for {}", p.path))
            })?;
            let buf = match (&t.payload, p.spec.dtype.as_str()) {
                // int8 params consumed natively (block_w8 artifacts)
                (crate::quant::Payload::I8 { .. }, "int8") => {
                    let data: Vec<i8> = dense.iter().map(|&v| v as i8).collect();
                    resident += data.len();
                    engine
                        .client
                        .buffer_from_host_raw_bytes(
                            xla::ElementType::S8,
                            unsafe {
                                std::slice::from_raw_parts(
                                    data.as_ptr() as *const u8,
                                    data.len(),
                                )
                            },
                            &p.spec.shape,
                            None,
                        )
                        .map_err(xerr)?
                }
                _ => {
                    // W8A16 cast-up (or plain f32): dense f32 upload
                    // straight from the borrowed store view — no copy
                    resident += dense.len() * 4;
                    engine
                        .client
                        .buffer_from_host_buffer::<f32>(dense, &p.spec.shape, None)
                        .map_err(xerr)?
                }
            };
            weight_bufs.push(buf);
        }
        let upload_s = t1.elapsed().as_secs_f64();

        // host stages are charged to the load that actually ran them
        let host_stats = if store_hit {
            HostLoadStats::default()
        } else {
            host.stats.clone()
        };
        Ok(Component {
            name: comp.name.clone(),
            exe,
            weight_bufs,
            act_shapes: comp.activations.iter().map(|a| a.shape.clone()).collect(),
            act_dtypes: comp.activations.iter().map(|a| a.dtype.clone()).collect(),
            stats: LoadStats {
                read_s: host_stats.read_s,
                parse_s: host_stats.parse_s,
                dequant_s: host_stats.dequant_s,
                compile_s,
                upload_s,
                weight_bytes_stored: stored,
                weight_bytes_resident: resident,
                store_hit,
                warm,
            },
        })
    }

    /// This component's compiled executable — the warm-tier payload the
    /// residency layer keeps across evictions.
    pub fn executable(&self) -> WarmExecutable {
        Rc::clone(&self.exe)
    }

    /// Upload one activation (by manifest position) as a device buffer
    /// the caller may keep resident across calls — the serving hot path
    /// uses this for the text context, which is constant over all
    /// denoise steps of a request.
    pub fn upload(
        &self,
        engine: &Engine,
        idx: usize,
        act: &ActInput,
    ) -> Result<xla::PjRtBuffer> {
        let shape = &self.act_shapes[idx];
        match act {
            ActInput::F32(v) => engine
                .client
                .buffer_from_host_buffer::<f32>(v, shape, None)
                .map_err(xerr),
            ActInput::I32(v) => engine
                .client
                .buffer_from_host_buffer::<i32>(v, shape, None)
                .map_err(xerr),
        }
    }

    /// Upload an f32 activation whose leading (batch) dimension is the
    /// manifest's, scaled by `batch` — the micro-batched denoise path
    /// packs `batch` requests' CFG rows into one dispatch.  `batch == 1`
    /// reproduces the manifest shape exactly.
    ///
    /// Note: a real AOT executable is compiled at a fixed batch size;
    /// serving at several sizes means one executable per size.  The
    /// vendored stub accepts any leading dimension, standing in for
    /// that per-batch-size executable set.
    pub fn upload_f32_rows(
        &self,
        engine: &Engine,
        idx: usize,
        data: &[f32],
        batch: usize,
    ) -> Result<xla::PjRtBuffer> {
        let mut shape = self.act_shapes[idx].clone();
        if let Some(d0) = shape.first_mut() {
            *d0 *= batch.max(1);
        }
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(Error::Runtime(format!(
                "{}: activation {idx} at batch {batch} wants {want} elements, got {}",
                self.name,
                data.len()
            )));
        }
        engine
            .client
            .buffer_from_host_buffer::<f32>(data, &shape, None)
            .map_err(xerr)
    }

    /// Execute with f32/i32 activation inputs (in manifest order).
    /// Returns the flattened f32 outputs (one vec per output tensor).
    pub fn run(&self, engine: &Engine, acts: &[ActInput]) -> Result<Vec<Vec<f32>>> {
        if acts.len() != self.act_shapes.len() {
            return Err(Error::Runtime(format!(
                "{}: want {} activations, got {}",
                self.name,
                self.act_shapes.len(),
                acts.len()
            )));
        }
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(acts.len());
        for (i, act) in acts.iter().enumerate() {
            bufs.push(self.upload(engine, i, act)?);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(&refs)
    }

    /// Execute with pre-uploaded activation buffers (in manifest order).
    pub fn run_buffers(&self, acts: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::new();
        self.run_buffers_into(acts, &mut out)?;
        Ok(out)
    }

    /// Execute with pre-uploaded activation buffers, writing the
    /// flattened f32 outputs into caller-owned vectors whose capacity
    /// is reused across calls — the zero-realloc read-back of the
    /// serving hot loop.
    pub fn run_buffers_into(
        &self,
        acts: &[&xla::PjRtBuffer],
        out: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weight_bufs.len() + acts.len());
        args.extend(self.weight_bufs.iter());
        args.extend(acts.iter().copied());

        let result = self.exe.execute_b(&args).map_err(xerr)?;
        let lit = result[0][0].to_literal_sync().map_err(xerr)?;
        // the AOT path lowers with return_tuple=True
        let tuple = lit.to_tuple().map_err(xerr)?;
        if out.len() != tuple.len() {
            out.resize_with(tuple.len(), Vec::new);
        }
        for (slot, l) in out.iter_mut().zip(&tuple) {
            l.copy_into_f32(slot).map_err(xerr)?;
        }
        Ok(())
    }

    pub fn resident_bytes(&self) -> usize {
        self.stats.weight_bytes_resident
    }
}

/// Rewrite an existing device buffer in place from host f32 data (the
/// donated-buffer fast path: no allocation, no new buffer).  The dtype
/// and element count must match the buffer exactly.
pub fn write_buffer_f32(buf: &mut xla::PjRtBuffer, data: &[f32]) -> Result<()> {
    buf.write_from_host::<f32>(data).map_err(xerr)
}

/// Activation input payload.
pub enum ActInput {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl ActInput {
    pub fn f32(v: Vec<f32>) -> ActInput {
        ActInput::F32(v)
    }
    pub fn i32(v: Vec<i32>) -> ActInput {
        ActInput::I32(v)
    }
}
