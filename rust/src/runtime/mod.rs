//! Runtime layer: manifest parsing + PJRT execution of the AOT HLO
//! artifacts (see /opt/xla-example/load_hlo for the interchange rules —
//! HLO *text*, not serialized protos).

pub mod artifact;
pub mod engine;

pub use artifact::{ComponentManifest, Manifest, ParamSpec, TensorSpec};
pub use engine::{write_buffer_f32, ActInput, Component, Engine, LoadStats};
