//! Runtime layer: manifest parsing, the process-wide host-artifact
//! store ([`store`]: parsed weight containers + dequantized rows,
//! loaded from disk once per process), and PJRT execution of the AOT
//! HLO artifacts (see /opt/xla-example/load_hlo for the interchange
//! rules — HLO *text*, not serialized protos).

pub mod artifact;
pub mod engine;
pub mod store;

pub use artifact::{ComponentManifest, Manifest, ParamSpec, TensorSpec};
pub use engine::{
    write_buffer_f32, ActInput, Component, Engine, LoadStats, WarmExecutable,
};
pub use store::{ArtifactStore, HostArtifact, HostLoadStats};
