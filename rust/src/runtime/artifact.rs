//! Artifact manifest (artifacts/manifest.json) — the contract between
//! the Python build path and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::scheduler::SchedulerParams;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
    fn from_json(j: &Json) -> TensorSpec {
        TensorSpec {
            shape: j
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            dtype: j.get("dtype").as_str().unwrap_or("float32").to_string(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub path: String,
    pub spec: TensorSpec,
}

#[derive(Debug, Clone)]
pub struct WeightSet {
    pub file: String,
    pub bytes: usize,
}

#[derive(Debug, Clone)]
pub struct ComponentManifest {
    pub name: String,
    pub hlo_file: String,
    pub variant: String,
    pub params: Vec<ParamSpec>,
    pub activations: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub param_bytes_f32: usize,
    /// precision tag ("fp32" / "int8" / "int8_pruned") -> file
    pub weights: BTreeMap<String, WeightSet>,
}

#[derive(Debug, Clone)]
pub struct GoldenTrace {
    pub latent0: Vec<f64>,
    pub eps_scale: f64,
    pub trace: Vec<Vec<f64>>,
    /// golden DPM-Solver++(2M) trace: the full 8-step multistep
    /// schedule over the same `latent0`/surrogate (empty in manifests
    /// built before the sampler family)
    pub multistep_trace: Vec<Vec<f64>>,
}

#[derive(Debug, Clone)]
pub struct SchedulerManifest {
    pub params: SchedulerParams,
    pub alphas_cumprod: Vec<f64>,
    pub timesteps: Vec<usize>,
    pub golden: GoldenTrace,
}

#[derive(Debug, Clone)]
pub struct TokenizerManifest {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub golden: Vec<(String, Vec<i32>)>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub cfg_batch: usize,
    pub latent_size: usize,
    pub latent_channels: usize,
    pub image_size: usize,
    pub components: BTreeMap<String, ComponentManifest>,
    pub scheduler: SchedulerManifest,
    pub tokenizer: TokenizerManifest,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Manifest(format!("{}: {}", path.display(), e)))?;
        let j = Json::parse(&text).map_err(|e| Error::Manifest(e.to_string()))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest> {
        let mut components = BTreeMap::new();
        let comps = j
            .get("components")
            .as_obj()
            .ok_or_else(|| Error::Manifest("missing components".into()))?;
        for (name, c) in comps {
            let params = c
                .get("params")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|p| ParamSpec {
                    path: p.get("path").as_str().unwrap_or("").to_string(),
                    spec: TensorSpec::from_json(p),
                })
                .collect();
            let mut weights = BTreeMap::new();
            if let Some(w) = c.get("weights").as_obj() {
                for (tag, meta) in w {
                    weights.insert(
                        tag.clone(),
                        WeightSet {
                            file: meta.get("file").as_str().unwrap_or("").to_string(),
                            bytes: meta.get("bytes").as_usize().unwrap_or(0),
                        },
                    );
                }
            }
            components.insert(
                name.clone(),
                ComponentManifest {
                    name: name.clone(),
                    hlo_file: c.get("hlo").as_str().unwrap_or("").to_string(),
                    variant: c.get("variant").as_str().unwrap_or("").to_string(),
                    params,
                    activations: c
                        .get("activations")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect(),
                    outputs: c
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect(),
                    param_bytes_f32: c.get("param_bytes_f32").as_usize().unwrap_or(0),
                    weights,
                },
            );
        }

        let s = j.get("scheduler");
        let scheduler = SchedulerManifest {
            params: SchedulerParams {
                num_train_timesteps: s.get("num_train_timesteps").as_usize().unwrap_or(1000),
                beta_start: s.get("beta_start").as_f64().unwrap_or(0.00085),
                beta_end: s.get("beta_end").as_f64().unwrap_or(0.012),
                num_inference_steps: s.get("num_inference_steps").as_usize().unwrap_or(20),
                guidance_scale: s.get("guidance_scale").as_f64().unwrap_or(7.5),
            },
            alphas_cumprod: s
                .get("alphas_cumprod")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_f64())
                .collect(),
            timesteps: s
                .get("timesteps")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            golden: GoldenTrace {
                latent0: s
                    .get("golden")
                    .get("latent0")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .collect(),
                eps_scale: s.get("golden").get("eps_scale").as_f64().unwrap_or(0.1),
                trace: s
                    .get("golden")
                    .get("trace")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|row| {
                        row.as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_f64())
                            .collect()
                    })
                    .collect(),
                multistep_trace: s
                    .get("golden")
                    .get("multistep_trace")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|row| {
                        row.as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_f64())
                            .collect()
                    })
                    .collect(),
            },
        };

        let t = j.get("tokenizer");
        let tokenizer = TokenizerManifest {
            vocab_size: t.get("vocab_size").as_usize().unwrap_or(4096),
            seq_len: t.get("seq_len").as_usize().unwrap_or(16),
            golden: t
                .get("golden")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|g| {
                    (
                        g.get("text").as_str().unwrap_or("").to_string(),
                        g.get("ids")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|v| v.as_i64().map(|x| x as i32))
                            .collect(),
                    )
                })
                .collect(),
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            cfg_batch: j.get("cfg_batch").as_usize().unwrap_or(2),
            latent_size: j.get("latent").get("size").as_usize().unwrap_or(32),
            latent_channels: j.get("latent").get("channels").as_usize().unwrap_or(4),
            image_size: j.get("image").get("size").as_usize().unwrap_or(256),
            components,
            scheduler,
            tokenizer,
        })
    }

    pub fn component(&self, name: &str) -> Result<&ComponentManifest> {
        self.components
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no component {name}")))
    }

    pub fn hlo_path(&self, comp: &ComponentManifest) -> PathBuf {
        self.dir.join(&comp.hlo_file)
    }

    pub fn weight_path(&self, comp: &ComponentManifest, tag: &str) -> Result<PathBuf> {
        comp.weights
            .get(tag)
            .map(|w| self.dir.join(&w.file))
            .ok_or_else(|| {
                Error::Manifest(format!("component {} has no weights '{tag}'", comp.name))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let src = r#"{
          "cfg_batch": 2,
          "latent": {"size": 32, "channels": 4},
          "image": {"size": 256, "channels": 3},
          "components": {
            "unet_mobile": {
              "hlo": "unet_mobile.hlo.txt", "variant": "mobile",
              "params": [{"path": "conv_in/w", "shape": [3,3,4,64],
                          "dtype": "float32"}],
              "activations": [{"shape": [2,32,32,4], "dtype": "float32"}],
              "outputs": [{"shape": [2,32,32,4], "dtype": "float32"}],
              "param_bytes_f32": 9216,
              "weights": {"fp32": {"file": "w.bin", "bytes": 9216}}
            }
          },
          "scheduler": {
            "num_train_timesteps": 1000, "beta_start": 0.00085,
            "beta_end": 0.012, "num_inference_steps": 20,
            "guidance_scale": 7.5,
            "alphas_cumprod": [0.999, 0.998],
            "timesteps": [950, 900],
            "golden": {"latent0": [0.1], "eps_scale": 0.1,
                       "trace": [[0.2]]}
          },
          "tokenizer": {"vocab_size": 4096, "seq_len": 16,
                        "golden": [{"text": "hi", "ids": [1, 7, 0]}]}
        }"#;
        let j = Json::parse(src).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/art"), &j).unwrap();
        assert_eq!(m.cfg_batch, 2);
        let c = m.component("unet_mobile").unwrap();
        assert_eq!(c.params.len(), 1);
        assert_eq!(c.params[0].spec.elems(), 3 * 3 * 4 * 64);
        assert_eq!(c.activations[0].shape, vec![2, 32, 32, 4]);
        assert_eq!(m.scheduler.params.num_inference_steps, 20);
        assert_eq!(m.tokenizer.golden[0].1, vec![1, 7, 0]);
        assert!(m.component("nope").is_err());
        assert!(m.weight_path(c, "int8").is_err());
        assert!(m.weight_path(c, "fp32").unwrap().ends_with("w.bin"));
    }
}
