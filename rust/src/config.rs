//! Layered configuration: defaults -> optional JSON config file -> CLI
//! flags (hand-rolled parser; no clap offline).

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::pipeline::ExecOptions;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct AppConfig {
    pub artifacts_dir: PathBuf,
    /// "base" | "mobile"
    pub variant: String,
    /// "fp32" | "int8" | "int8_pruned"
    pub unet_weights: String,
    pub memory_budget_mb: f64,
    pub pipelined: bool,
    pub num_steps: usize,
    /// "ddim" | "dpm2m" | "distilled4" | "distilled8" — the default
    /// sampler for requests that don't override it (see
    /// `scheduler::Sampler`)
    pub sampler: String,
    pub guidance_scale: f64,
    pub seed: u64,
    pub prompt: String,
    pub out: Option<PathBuf>,
    /// device workers in the serving pool (each owns its own engine
    /// and memory budget)
    pub num_workers: usize,
    /// admission-queue capacity; submissions beyond it are rejected
    pub queue_depth: usize,
    /// max compatible requests a worker drains into one micro-batched
    /// denoise dispatch (1 = no cross-request batching)
    pub max_batch: usize,
    /// heterogeneous fleet spec, e.g. "adreno740:2,bigcore:1" — class
    /// names resolve against the planner's device registry.  When set,
    /// worker counts come from the spec (overriding `num_workers`) and
    /// admission routes by plan-predicted service time.
    pub fleet: Option<String>,
    /// compiled executables each worker keeps across evictions (the
    /// warm-reload tier); 0 disables warm reuse
    pub warm_slots: usize,
    /// step-level continuous batching: workers re-poll the queue at
    /// denoise-step boundaries (joins, slot reclamation, deadline
    /// preemption) instead of running each batch to completion
    pub continuous: bool,
    /// deterministic fault injection: seed for the device runtime's
    /// fault plan (None = faults disabled unless `fault_spec` sets
    /// exact trigger points)
    pub fault_seed: Option<u64>,
    /// probability [0,1] that a UNet dispatch fails with a transient
    /// device error (drawn from the seeded stream)
    pub fault_rate: f64,
    /// exact fault schedule, e.g. "dispatch:3:transient,compile:1:oom"
    /// (see the device runtime's `FaultPlan::parse`)
    pub fault_spec: Option<String>,
    /// transient-failure retries per request before failing the caller
    pub retry_limit: usize,
    /// base retry backoff in ms (doubles per attempt, capped at 16x)
    pub retry_backoff_ms: u64,
    /// consecutive faults that quarantine a device class
    pub breaker_threshold: u32,
    /// quarantine duration in ms before a half-open probe
    pub breaker_cooldown_ms: u64,
    /// observation-window size for online calibration: bounds the
    /// per-op-class roofline fit windows and the per-class
    /// predicted-vs-actual metric windows; also caps the
    /// measured-overhead trust threshold
    pub calib_window: usize,
    /// capacity-accounted device memory in MB: the stub charges live
    /// buffer bytes against this cap and fails allocations beyond it
    /// with a real OOM (None = unlimited, the default).  Distinct from
    /// `memory_budget_mb`, which is the *planner's* residency budget —
    /// setting this below the working set is how OOM recovery is
    /// exercised end-to-end
    pub device_mem_mb: Option<f64>,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            variant: "mobile".into(),
            unet_weights: "fp32".into(),
            memory_budget_mb: f64::INFINITY,
            pipelined: true,
            num_steps: 20,
            sampler: "ddim".into(),
            guidance_scale: 7.5,
            seed: 0,
            prompt: "a photograph of an astronaut riding a horse".into(),
            out: None,
            num_workers: 1,
            queue_depth: 32,
            max_batch: 1,
            fleet: None,
            warm_slots: 8,
            continuous: true,
            fault_seed: None,
            fault_rate: 0.0,
            fault_spec: None,
            retry_limit: 3,
            retry_backoff_ms: 25,
            breaker_threshold: 3,
            breaker_cooldown_ms: 1000,
            calib_window: crate::planner::calibrate::DEFAULT_CALIB_WINDOW,
            device_mem_mb: None,
        }
    }
}

impl AppConfig {
    pub fn exec_options(&self) -> ExecOptions {
        ExecOptions {
            memory_budget: if self.memory_budget_mb.is_finite() {
                (self.memory_budget_mb * 1e6) as usize
            } else {
                usize::MAX
            },
            pipelined: self.pipelined,
            unet_weights: self.unet_weights.clone(),
            num_steps: self.num_steps,
            sampler: crate::scheduler::Sampler::parse(&self.sampler).unwrap_or_default(),
            guidance_scale: self.guidance_scale,
            warm_slots: self.warm_slots,
        }
    }

    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {}", path.display(), e)))?;
        let j = Json::parse(&text).map_err(|e| Error::Config(e.to_string()))?;
        self.apply_json(&j);
        Ok(())
    }

    pub fn apply_json(&mut self, j: &Json) {
        if let Some(v) = j.get("artifacts_dir").as_str() {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("variant").as_str() {
            self.variant = v.to_string();
        }
        if let Some(v) = j.get("unet_weights").as_str() {
            self.unet_weights = v.to_string();
        }
        if let Some(v) = j.get("memory_budget_mb").as_f64() {
            self.memory_budget_mb = v;
        }
        if let Some(v) = j.get("pipelined").as_bool() {
            self.pipelined = v;
        }
        if let Some(v) = j.get("num_steps").as_usize() {
            self.num_steps = v;
        }
        if let Some(v) = j.get("sampler").as_str() {
            self.sampler = v.to_string();
        }
        if let Some(v) = j.get("guidance_scale").as_f64() {
            self.guidance_scale = v;
        }
        if let Some(v) = j.get("seed").as_i64() {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("prompt").as_str() {
            self.prompt = v.to_string();
        }
        if let Some(v) = j.get("num_workers").as_usize() {
            self.num_workers = v;
        }
        if let Some(v) = j.get("queue_depth").as_usize() {
            self.queue_depth = v;
        }
        if let Some(v) = j.get("max_batch").as_usize() {
            self.max_batch = v;
        }
        if let Some(v) = j.get("fleet").as_str() {
            self.fleet = Some(v.to_string());
        }
        if let Some(v) = j.get("warm_slots").as_usize() {
            self.warm_slots = v;
        }
        if let Some(v) = j.get("continuous").as_bool() {
            self.continuous = v;
        }
        if let Some(v) = j.get("fault_seed").as_i64() {
            self.fault_seed = Some(v as u64);
        }
        if let Some(v) = j.get("fault_rate").as_f64() {
            self.fault_rate = v;
        }
        if let Some(v) = j.get("fault_spec").as_str() {
            self.fault_spec = Some(v.to_string());
        }
        if let Some(v) = j.get("retry_limit").as_usize() {
            self.retry_limit = v;
        }
        if let Some(v) = j.get("retry_backoff_ms").as_i64() {
            self.retry_backoff_ms = v as u64;
        }
        if let Some(v) = j.get("breaker_threshold").as_usize() {
            self.breaker_threshold = v as u32;
        }
        if let Some(v) = j.get("breaker_cooldown_ms").as_i64() {
            self.breaker_cooldown_ms = v as u64;
        }
        if let Some(v) = j.get("calib_window").as_usize() {
            self.calib_window = v;
        }
        if let Some(v) = j.get("device_mem_mb").as_f64() {
            self.device_mem_mb = Some(v);
        }
    }

    /// Parse `--key value` / `--flag` CLI arguments (after the
    /// subcommand).  Unknown keys are an error.
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let key = args[i].as_str();
            let take = |i: &mut usize| -> Result<String> {
                *i += 1;
                args.get(*i)
                    .cloned()
                    .ok_or_else(|| Error::Config(format!("{key} needs a value")))
            };
            match key {
                "--artifacts" => self.artifacts_dir = PathBuf::from(take(&mut i)?),
                "--config" => {
                    let p = PathBuf::from(take(&mut i)?);
                    self.load_file(&p)?;
                }
                "--variant" => self.variant = take(&mut i)?,
                "--weights" => self.unet_weights = take(&mut i)?,
                "--budget-mb" => {
                    self.memory_budget_mb = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--budget-mb: {e}")))?;
                }
                "--no-pipeline" => self.pipelined = false,
                "--steps" => {
                    self.num_steps = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--steps: {e}")))?;
                }
                "--sampler" => self.sampler = take(&mut i)?,
                "--guidance" => {
                    self.guidance_scale = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--guidance: {e}")))?;
                }
                "--seed" => {
                    self.seed = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--seed: {e}")))?;
                }
                "--prompt" => self.prompt = take(&mut i)?,
                "--out" => self.out = Some(PathBuf::from(take(&mut i)?)),
                "--workers" => {
                    self.num_workers = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--workers: {e}")))?;
                }
                "--queue-depth" => {
                    self.queue_depth = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--queue-depth: {e}")))?;
                }
                "--max-batch" => {
                    self.max_batch = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--max-batch: {e}")))?;
                }
                "--fleet" => self.fleet = Some(take(&mut i)?),
                "--no-continuous" => self.continuous = false,
                "--fault-seed" => {
                    self.fault_seed = Some(
                        take(&mut i)?
                            .parse()
                            .map_err(|e| Error::Config(format!("--fault-seed: {e}")))?,
                    );
                }
                "--fault-rate" => {
                    self.fault_rate = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--fault-rate: {e}")))?;
                }
                "--fault-spec" => self.fault_spec = Some(take(&mut i)?),
                "--retry-limit" => {
                    self.retry_limit = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--retry-limit: {e}")))?;
                }
                "--retry-backoff-ms" => {
                    self.retry_backoff_ms = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--retry-backoff-ms: {e}")))?;
                }
                "--breaker-threshold" => {
                    self.breaker_threshold = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--breaker-threshold: {e}")))?;
                }
                "--breaker-cooldown-ms" => {
                    self.breaker_cooldown_ms = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--breaker-cooldown-ms: {e}")))?;
                }
                "--warm-slots" => {
                    self.warm_slots = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--warm-slots: {e}")))?;
                }
                "--calib-window" => {
                    self.calib_window = take(&mut i)?
                        .parse()
                        .map_err(|e| Error::Config(format!("--calib-window: {e}")))?;
                }
                "--device-mem" => {
                    self.device_mem_mb = Some(
                        take(&mut i)?
                            .parse()
                            .map_err(|e| Error::Config(format!("--device-mem: {e}")))?,
                    );
                }
                other => {
                    return Err(Error::Config(format!("unknown flag {other}")));
                }
            }
            i += 1;
        }
        if self.num_workers == 0 {
            return Err(Error::Config("--workers must be at least 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("--queue-depth must be at least 1".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("--max-batch must be at least 1".into()));
        }
        if !crate::planner::model::VARIANTS.contains(&self.variant.as_str()) {
            return Err(Error::Config(format!(
                "bad variant {} (known: {})",
                self.variant,
                crate::planner::model::VARIANTS.join(", ")
            )));
        }
        if !["fp32", "int8", "int8_pruned"].contains(&self.unet_weights.as_str()) {
            return Err(Error::Config(format!("bad weights {}", self.unet_weights)));
        }
        if crate::scheduler::Sampler::parse(&self.sampler).is_none() {
            return Err(Error::Config(format!(
                "bad sampler {} (known: {})",
                self.sampler,
                crate::scheduler::Sampler::names().join(", ")
            )));
        }
        if let Some(spec) = &self.fleet {
            // fail fast on typos: resolve the spec against the planner
            // registry now rather than at server startup
            crate::planner::FleetSpec::parse(spec)?;
        }
        if self.calib_window == 0 {
            return Err(Error::Config("--calib-window must be at least 1".into()));
        }
        if !(0.0..=1.0).contains(&self.fault_rate) {
            return Err(Error::Config(format!(
                "--fault-rate must be in [0, 1], got {}",
                self.fault_rate
            )));
        }
        if let Some(mb) = self.device_mem_mb {
            if mb.is_nan() || mb <= 0.0 {
                return Err(Error::Config(format!(
                    "--device-mem must be positive MB, got {mb}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_flags() {
        let mut c = AppConfig::default();
        c.apply_args(&args(&[
            "--steps", "5", "--weights", "int8", "--no-pipeline",
            "--budget-mb", "64", "--seed", "7", "--prompt", "hello world",
        ]))
        .unwrap();
        assert_eq!(c.num_steps, 5);
        assert_eq!(c.unet_weights, "int8");
        assert!(!c.pipelined);
        assert_eq!(c.seed, 7);
        let eo = c.exec_options();
        assert_eq!(eo.memory_budget, 64_000_000);
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--nope"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--steps", "abc"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--variant", "huge"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--steps"])).is_err(), "missing value");
    }

    #[test]
    fn json_layer() {
        let mut c = AppConfig::default();
        let j = Json::parse(r#"{"num_steps": 3, "variant": "base"}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.num_steps, 3);
        assert_eq!(c.variant, "base");
    }

    #[test]
    fn pool_flags_and_json() {
        let mut c = AppConfig::default();
        assert_eq!(c.num_workers, 1, "single-phone default");
        assert_eq!(c.queue_depth, 32);
        assert_eq!(c.max_batch, 1, "no cross-request batching by default");
        c.apply_args(&args(&["--workers", "4", "--queue-depth", "8", "--max-batch", "4"]))
            .unwrap();
        assert_eq!(c.num_workers, 4);
        assert_eq!(c.queue_depth, 8);
        assert_eq!(c.max_batch, 4);

        let j = Json::parse(r#"{"num_workers": 2, "queue_depth": 16, "max_batch": 2}"#)
            .unwrap();
        c.apply_json(&j);
        assert_eq!(c.num_workers, 2);
        assert_eq!(c.queue_depth, 16);
        assert_eq!(c.max_batch, 2);

        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--workers", "0"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--queue-depth", "0"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--max-batch", "0"])).is_err());
    }

    #[test]
    fn warm_slots_flag_and_json() {
        let mut c = AppConfig::default();
        assert_eq!(c.warm_slots, 8, "warm reloads on by default");
        assert_eq!(c.exec_options().warm_slots, 8);
        c.apply_args(&args(&["--warm-slots", "0"])).unwrap();
        assert_eq!(c.warm_slots, 0, "0 disables the warm tier");
        let j = Json::parse(r#"{"warm_slots": 16}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.warm_slots, 16);
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--warm-slots", "x"])).is_err());
    }

    #[test]
    fn continuous_flag_and_json() {
        let mut c = AppConfig::default();
        assert!(c.continuous, "continuous batching on by default");
        c.apply_args(&args(&["--no-continuous"])).unwrap();
        assert!(!c.continuous);
        let j = Json::parse(r#"{"continuous": true}"#).unwrap();
        c.apply_json(&j);
        assert!(c.continuous);
    }

    #[test]
    fn fault_and_supervision_flags_and_json() {
        let mut c = AppConfig::default();
        assert!(c.fault_seed.is_none(), "faults off by default");
        assert_eq!(c.fault_rate, 0.0);
        assert!(c.fault_spec.is_none());
        assert_eq!(c.retry_limit, 3);
        assert_eq!(c.retry_backoff_ms, 25);
        assert_eq!(c.breaker_threshold, 3);
        assert_eq!(c.breaker_cooldown_ms, 1000);

        c.apply_args(&args(&[
            "--fault-seed", "42", "--fault-rate", "0.25",
            "--fault-spec", "dispatch:3:transient",
            "--retry-limit", "5", "--retry-backoff-ms", "10",
            "--breaker-threshold", "2", "--breaker-cooldown-ms", "500",
        ]))
        .unwrap();
        assert_eq!(c.fault_seed, Some(42));
        assert!((c.fault_rate - 0.25).abs() < 1e-12);
        assert_eq!(c.fault_spec.as_deref(), Some("dispatch:3:transient"));
        assert_eq!(c.retry_limit, 5);
        assert_eq!(c.retry_backoff_ms, 10);
        assert_eq!(c.breaker_threshold, 2);
        assert_eq!(c.breaker_cooldown_ms, 500);

        let mut c = AppConfig::default();
        let j = Json::parse(
            r#"{"fault_seed": 7, "fault_rate": 0.1, "fault_spec": "transfer:1:fatal",
                "retry_limit": 1, "retry_backoff_ms": 5,
                "breaker_threshold": 4, "breaker_cooldown_ms": 250}"#,
        )
        .unwrap();
        c.apply_json(&j);
        assert_eq!(c.fault_seed, Some(7));
        assert!((c.fault_rate - 0.1).abs() < 1e-12);
        assert_eq!(c.fault_spec.as_deref(), Some("transfer:1:fatal"));
        assert_eq!(c.retry_limit, 1);
        assert_eq!(c.retry_backoff_ms, 5);
        assert_eq!(c.breaker_threshold, 4);
        assert_eq!(c.breaker_cooldown_ms, 250);

        // fault rates outside [0, 1] fail validation
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--fault-rate", "1.5"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--fault-rate", "-0.1"])).is_err());
    }

    #[test]
    fn calib_window_flag_json_and_validation() {
        let mut c = AppConfig::default();
        assert_eq!(
            c.calib_window,
            crate::planner::calibrate::DEFAULT_CALIB_WINDOW,
            "calibration on by default with the library window"
        );
        c.apply_args(&args(&["--calib-window", "64"])).unwrap();
        assert_eq!(c.calib_window, 64);

        let j = Json::parse(r#"{"calib_window": 512}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.calib_window, 512);

        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--calib-window", "0"])).is_err(), "zero window");
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--calib-window", "x"])).is_err(), "bad value");
    }

    #[test]
    fn device_mem_flag_json_and_validation() {
        let mut c = AppConfig::default();
        assert!(c.device_mem_mb.is_none(), "unlimited device memory by default");
        c.apply_args(&args(&["--device-mem", "48"])).unwrap();
        assert_eq!(c.device_mem_mb, Some(48.0));

        let mut c = AppConfig::default();
        let j = Json::parse(r#"{"device_mem_mb": 12.5}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.device_mem_mb, Some(12.5));

        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--device-mem", "0"])).is_err(), "zero cap");
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--device-mem", "-4"])).is_err(), "negative cap");
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--device-mem", "tiny"])).is_err(), "bad value");
    }

    #[test]
    fn sampler_flag_json_and_validation() {
        let mut c = AppConfig::default();
        assert_eq!(c.sampler, "ddim", "first-order DDIM by default");
        assert_eq!(c.exec_options().sampler, crate::scheduler::Sampler::Ddim);
        c.apply_args(&args(&["--sampler", "dpm2m"])).unwrap();
        assert_eq!(c.sampler, "dpm2m");
        assert_eq!(c.exec_options().sampler, crate::scheduler::Sampler::Dpm2m);

        let j = Json::parse(r#"{"sampler": "distilled8"}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.sampler, "distilled8");
        assert_eq!(
            c.exec_options().sampler,
            crate::scheduler::Sampler::Distilled8
        );

        let mut c = AppConfig::default();
        let err = c.apply_args(&args(&["--sampler", "euler"])).unwrap_err();
        assert!(err.to_string().contains("bad sampler"), "{err}");
        assert!(err.to_string().contains("distilled4"), "lists the family: {err}");
    }

    #[test]
    fn fleet_flag_and_json() {
        let mut c = AppConfig::default();
        assert!(c.fleet.is_none(), "homogeneous by default");
        c.apply_args(&args(&["--fleet", "adreno740:2,bigcore:1"])).unwrap();
        assert_eq!(c.fleet.as_deref(), Some("adreno740:2,bigcore:1"));

        let mut c = AppConfig::default();
        let j = Json::parse(r#"{"fleet": "adreno740:1,hexagon:1"}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.fleet.as_deref(), Some("adreno740:1,hexagon:1"));

        // typos fail at flag parse, not at server startup
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--fleet", "warpdrive:2"])).is_err());
        let mut c = AppConfig::default();
        assert!(c.apply_args(&args(&["--fleet", "adreno740:0"])).is_err());
    }
}
