//! # mobile-diffusion
//!
//! A reproduction of *Squeezing Large-Scale Diffusion Models for Mobile*
//! (Choi et al., ICML 2023 Workshop on Challenges in Deployable
//! Generative AI) as a three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the coordinator: a multi-worker serving
//!   stack (admission-controlled priority/deadline queue in front of a
//!   pool of device workers, each owning a pipelined executor and a
//!   component-residency cache with a warm executable tier), the
//!   process-wide `runtime::store` host-artifact cache (each component
//!   read/parsed/dequantized from disk once per process, shared by
//!   every fleet worker), the `planner` that fuses the analysis
//!   stack into scheduling (named device-class registry, cost-gated
//!   pass planning, per-`(device, variant)` execution plans, and
//!   plan-driven admission routing for heterogeneous `--fleet` pools,
//!   with measured load overheads fed back into admission), the
//!   paper's pipelined memory-constrained execution (Sec. 3.3), a
//!   TFLite GPU-delegate simulator with the paper's Sec. 3.1 support
//!   rules and an Adreno-740-class cost model, the declarative
//!   pattern-rewrite compiler core (`graph::pattern`) with its
//!   registry of graph passes (FC->Conv, conv serialization,
//!   broadcast-free group norm, stable GELU, fused softmax,
//!   attention reshape elimination), and W8A16 weight storage
//!   (Sec. 3.4).
//! * **L2 (python/compile, build-time only)** — a from-scratch latent
//!   diffusion pipeline (CLIP-like text encoder, UNet, VAE decoder)
//!   AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the paper's
//!   rewritten hot-spots, validated against pure-jnp oracles.
//!
//! See DESIGN.md (repo root) for the serving architecture: request
//! lifecycle, scheduling policy, and the residency subsystem.

pub mod config;
pub mod coordinator;
pub mod delegate;
pub mod error;
pub mod graph;
pub mod passes;
pub mod pipeline;
pub mod planner;
pub mod quant;
pub mod runtime;
pub mod scheduler;
pub mod testkit;
pub mod tokenizer;
pub mod util;

pub use error::{Error, Result};
