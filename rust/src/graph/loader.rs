//! Load `artifacts/*.graph.json` (python/compile/graphspec.py) into the IR.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

use super::ir::{DType, Graph, OpType};

pub fn from_json(json: &Json) -> Result<Graph> {
    let name = json
        .get("name")
        .as_str()
        .ok_or_else(|| Error::Graph("missing graph name".into()))?;
    let mut g = Graph::new(name);

    let tensors = json
        .get("tensors")
        .as_arr()
        .ok_or_else(|| Error::Graph("missing tensors".into()))?;
    for t in tensors {
        let tname = t.get("name").as_str().unwrap_or("?");
        let shape: Vec<usize> = t
            .get("shape")
            .as_arr()
            .ok_or_else(|| Error::Graph(format!("tensor {} missing shape", tname)))?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = DType::parse(t.get("dtype").as_str().unwrap_or("f16"))
            .ok_or_else(|| Error::Graph(format!("bad dtype for {}", tname)))?;
        let is_const = t.get("const").as_bool().unwrap_or(false);
        let id = g.add_tensor(tname, &shape, dtype, is_const);
        let want = t.get("id").as_usize().unwrap_or(id);
        if want != id {
            return Err(Error::Graph(format!(
                "non-dense tensor ids: got {} want {}",
                want, id
            )));
        }
    }

    let ops = json
        .get("ops")
        .as_arr()
        .ok_or_else(|| Error::Graph("missing ops".into()))?;
    for o in ops {
        let oname = o.get("name").as_str().unwrap_or("?").to_string();
        let ty_str = o.get("type").as_str().unwrap_or("?");
        let ty = OpType::parse(ty_str)
            .ok_or_else(|| Error::Graph(format!("unknown op type {}", ty_str)))?;
        let inputs = o
            .get("inputs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let outputs = o
            .get("outputs")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let mut attrs = std::collections::BTreeMap::new();
        if let Some(a) = o.get("attrs").as_obj() {
            for (k, v) in a {
                if let Some(n) = v.as_f64() {
                    attrs.insert(k.clone(), n);
                }
            }
        }
        g.add_op_with_attrs(ty, &oname, inputs, outputs, attrs);
    }

    g.validate().map_err(Error::Graph)?;
    Ok(g)
}

pub fn load(path: &Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Io(format!("{}: {}", path.display(), e)))?;
    let json = Json::parse(&text)
        .map_err(|e| Error::Graph(format!("{}: {}", path.display(), e)))?;
    from_json(&json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let src = r#"{
          "name": "t", "activation_dtype": "f16",
          "tensors": [
            {"id":0,"name":"x","shape":[1,4,4,2],"dtype":"f16","const":false},
            {"id":1,"name":"w","shape":[3,3,2,4],"dtype":"f32","const":true},
            {"id":2,"name":"y","shape":[1,4,4,4],"dtype":"f16","const":false}
          ],
          "ops": [
            {"id":0,"type":"CONV_2D","name":"c","inputs":[0,1],"outputs":[2],
             "attrs":{"kernel":3,"stride":1}}
          ]
        }"#;
        let g = from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(g.ops.len(), 1);
        assert_eq!(g.ops[0].ty, OpType::Conv2d);
        assert_eq!(g.ops[0].attr_i("kernel"), Some(3));
        assert_eq!(g.tensor(1).dtype, DType::F32);
        assert!(g.tensor(1).is_const);
    }

    #[test]
    fn rejects_unknown_op() {
        let src = r#"{"name":"t","tensors":[],"ops":[
          {"id":0,"type":"NOPE","name":"n","inputs":[],"outputs":[]}]}"#;
        assert!(from_json(&Json::parse(src).unwrap()).is_err());
    }
}
