//! TFLite-level computation-graph IR.
//!
//! This is the representation the paper's Sec. 3.1 operates on: named
//! operators (CONV_2D, FULLY_CONNECTED, BROADCAST_TO, ...) over shaped
//! tensors.  Graphs are loaded from `artifacts/*.graph.json` (emitted by
//! python/compile/graphspec.py) or built programmatically in tests; the
//! pass pipeline (crate::passes) rewrites them and the delegate
//! simulator (crate::delegate) partitions and costs them.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F16,
    F32,
    I8,
    I32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::F16 => 2,
            DType::F32 => 4,
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f16" => Some(DType::F16),
            "f32" => Some(DType::F32),
            "i8" => Some(DType::I8),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F16 => "f16",
            DType::F32 => "f32",
            DType::I8 => "i8",
            DType::I32 => "i32",
        }
    }

    /// Every dtype, for exhaustive round-trip tests and enumeration.
    pub const ALL: &'static [DType] =
        &[DType::F16, DType::F32, DType::I8, DType::I32];
}

/// TFLite operator kinds used by the Stable Diffusion graphs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpType {
    Conv2d,
    FullyConnected,
    Add,
    Sub,
    Mul,
    Mean,
    SquaredDifference,
    Rsqrt,
    Reshape,
    BroadcastTo,
    Softmax,
    BatchMatmul,
    Tanh,
    Minimum,
    Maximum,
    Logistic,
    Concatenation,
    ResizeNearestNeighbor,
    Gather,
    StridedSlice,
    Split,
    Transpose,
    Exp,
    Sum,
    Div,
    /// One-dispatch softmax produced by the `fused_softmax` rewrite
    /// (paper-adjacent: "Speed Is All You Need" fuses the softmax
    /// memory round-trips away).  Costed memory-bound in
    /// `delegate::cost` — one streaming pass over the logits.
    FusedSoftmax,
}

impl OpType {
    pub fn parse(s: &str) -> Option<OpType> {
        use OpType::*;
        Some(match s {
            "CONV_2D" => Conv2d,
            "FULLY_CONNECTED" => FullyConnected,
            "ADD" => Add,
            "SUB" => Sub,
            "MUL" => Mul,
            "MEAN" => Mean,
            "SQUARED_DIFFERENCE" => SquaredDifference,
            "RSQRT" => Rsqrt,
            "RESHAPE" => Reshape,
            "BROADCAST_TO" => BroadcastTo,
            "SOFTMAX" => Softmax,
            "BATCH_MATMUL" => BatchMatmul,
            "TANH" => Tanh,
            "MINIMUM" => Minimum,
            "MAXIMUM" => Maximum,
            "LOGISTIC" => Logistic,
            "CONCATENATION" => Concatenation,
            "RESIZE_NEAREST_NEIGHBOR" => ResizeNearestNeighbor,
            "GATHER" => Gather,
            "STRIDED_SLICE" => StridedSlice,
            "SPLIT" => Split,
            "TRANSPOSE" => Transpose,
            "EXP" => Exp,
            "SUM" => Sum,
            "DIV" => Div,
            "FUSED_SOFTMAX" => FusedSoftmax,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        use OpType::*;
        match self {
            Conv2d => "CONV_2D",
            FullyConnected => "FULLY_CONNECTED",
            Add => "ADD",
            Sub => "SUB",
            Mul => "MUL",
            Mean => "MEAN",
            SquaredDifference => "SQUARED_DIFFERENCE",
            Rsqrt => "RSQRT",
            Reshape => "RESHAPE",
            BroadcastTo => "BROADCAST_TO",
            Softmax => "SOFTMAX",
            BatchMatmul => "BATCH_MATMUL",
            Tanh => "TANH",
            Minimum => "MINIMUM",
            Maximum => "MAXIMUM",
            Logistic => "LOGISTIC",
            Concatenation => "CONCATENATION",
            ResizeNearestNeighbor => "RESIZE_NEAREST_NEIGHBOR",
            Gather => "GATHER",
            StridedSlice => "STRIDED_SLICE",
            Split => "SPLIT",
            Transpose => "TRANSPOSE",
            Exp => "EXP",
            Sum => "SUM",
            Div => "DIV",
            FusedSoftmax => "FUSED_SOFTMAX",
        }
    }

    /// Every operator kind, for exhaustive round-trip tests and
    /// enumeration (kept in declaration order).
    pub const ALL: &'static [OpType] = &[
        OpType::Conv2d,
        OpType::FullyConnected,
        OpType::Add,
        OpType::Sub,
        OpType::Mul,
        OpType::Mean,
        OpType::SquaredDifference,
        OpType::Rsqrt,
        OpType::Reshape,
        OpType::BroadcastTo,
        OpType::Softmax,
        OpType::BatchMatmul,
        OpType::Tanh,
        OpType::Minimum,
        OpType::Maximum,
        OpType::Logistic,
        OpType::Concatenation,
        OpType::ResizeNearestNeighbor,
        OpType::Gather,
        OpType::StridedSlice,
        OpType::Split,
        OpType::Transpose,
        OpType::Exp,
        OpType::Sum,
        OpType::Div,
        OpType::FusedSoftmax,
    ];

    /// Pure element-wise ops (fusable by the delegate's elementwise chain).
    pub fn is_elementwise(self) -> bool {
        use OpType::*;
        matches!(
            self,
            Add | Sub | Mul | Rsqrt | Tanh | Minimum | Maximum | Logistic
                | SquaredDifference | Exp | Div
        )
    }
}

pub type TensorId = usize;
pub type OpId = usize;

#[derive(Debug, Clone)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub is_const: bool,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }
}

#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub ty: OpType,
    pub name: String,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
    pub attrs: BTreeMap<String, f64>,
}

impl Op {
    pub fn attr_i(&self, key: &str) -> Option<i64> {
        self.attrs.get(key).map(|v| *v as i64)
    }
}

#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    pub ops: Vec<Op>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { name: name.to_string(), tensors: Vec::new(), ops: Vec::new() }
    }

    pub fn add_tensor(
        &mut self,
        name: &str,
        shape: &[usize],
        dtype: DType,
        is_const: bool,
    ) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor {
            id,
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype,
            is_const,
        });
        id
    }

    pub fn add_op(
        &mut self,
        ty: OpType,
        name: &str,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> OpId {
        self.add_op_with_attrs(ty, name, inputs, outputs, BTreeMap::new())
    }

    pub fn add_op_with_attrs(
        &mut self,
        ty: OpType,
        name: &str,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
        attrs: BTreeMap<String, f64>,
    ) -> OpId {
        let id = self.ops.len();
        self.ops.push(Op { id, ty, name: name.to_string(), inputs, outputs, attrs });
        id
    }

    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id]
    }

    /// Activation (non-const) inputs of an op.
    pub fn act_inputs<'a>(&'a self, op: &'a Op) -> impl Iterator<Item = &'a Tensor> {
        op.inputs.iter().map(|&t| self.tensor(t)).filter(|t| !t.is_const)
    }

    /// Const (weight) inputs of an op.
    pub fn const_inputs<'a>(&'a self, op: &'a Op) -> impl Iterator<Item = &'a Tensor> {
        op.inputs.iter().map(|&t| self.tensor(t)).filter(|t| t.is_const)
    }

    /// Total weight bytes (const tensors actually referenced by ops).
    pub fn weight_bytes(&self) -> usize {
        let mut used = vec![false; self.tensors.len()];
        for op in &self.ops {
            for &t in &op.inputs {
                used[t] = true;
            }
        }
        self.tensors
            .iter()
            .filter(|t| t.is_const && used[t.id])
            .map(|t| t.bytes())
            .sum()
    }

    /// Producer op of each tensor (None for graph inputs / consts).
    pub fn producers(&self) -> Vec<Option<OpId>> {
        let mut prod = vec![None; self.tensors.len()];
        for op in &self.ops {
            for &o in &op.outputs {
                prod[o] = Some(op.id);
            }
        }
        prod
    }

    /// Consumer ops of each tensor.
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut cons = vec![Vec::new(); self.tensors.len()];
        for op in &self.ops {
            for &i in &op.inputs {
                cons[i].push(op.id);
            }
        }
        cons
    }

    /// Structural validation: SSA (each tensor produced once), all ids in
    /// range, ops topologically ordered (inputs produced before use or
    /// graph inputs/consts).
    pub fn validate(&self) -> Result<(), String> {
        let mut produced = vec![false; self.tensors.len()];
        for (i, t) in self.tensors.iter().enumerate() {
            if t.id != i {
                return Err(format!("tensor id mismatch at {}", i));
            }
            if t.shape.iter().any(|&d| d == 0) {
                return Err(format!("tensor {} has zero dim", t.name));
            }
        }
        for op in &self.ops {
            for &i in &op.inputs {
                if i >= self.tensors.len() {
                    return Err(format!("op {} input {} out of range", op.name, i));
                }
            }
            for &o in &op.outputs {
                if o >= self.tensors.len() {
                    return Err(format!("op {} output {} out of range", op.name, o));
                }
                if produced[o] {
                    return Err(format!("tensor {} produced twice", o));
                }
                if self.tensors[o].is_const {
                    return Err(format!("op {} writes const tensor", op.name));
                }
                produced[o] = true;
            }
        }
        // topological: every activation input must be produced by an
        // earlier op or be a graph input (never produced at all)
        let mut seen = vec![false; self.tensors.len()];
        let producers = self.producers();
        for op in &self.ops {
            for &i in &op.inputs {
                if !self.tensors[i].is_const
                    && producers[i].is_some()
                    && !seen[i]
                {
                    return Err(format!(
                        "op {} uses tensor {} before production",
                        op.name, i
                    ));
                }
            }
            for &o in &op.outputs {
                seen[o] = true;
            }
        }
        Ok(())
    }

    /// Count ops by type.
    pub fn op_histogram(&self) -> BTreeMap<OpType, usize> {
        let mut h = BTreeMap::new();
        for op in &self.ops {
            *h.entry(op.ty).or_insert(0) += 1;
        }
        h
    }

    /// Maximum rank among tensors actually referenced by ops (rewrite
    /// passes orphan replaced tensors rather than renumbering the graph).
    pub fn max_rank(&self) -> usize {
        self.ops
            .iter()
            .flat_map(|op| op.inputs.iter().chain(op.outputs.iter()))
            .map(|&t| self.tensor(t).rank())
            .max()
            .unwrap_or(0)
    }
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "graph {} ({} ops, {} tensors, {:.1} MB weights)",
            self.name,
            self.ops.len(),
            self.tensors.len(),
            self.weight_bytes() as f64 / 1e6
        )?;
        for (ty, n) in self.op_histogram() {
            writeln!(f, "  {:<24} {}", ty.name(), n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new("t");
        let x = g.add_tensor("x", &[1, 8, 8, 4], DType::F16, false);
        let w = g.add_tensor("w", &[3, 3, 4, 8], DType::F32, true);
        let y = g.add_tensor("y", &[1, 8, 8, 8], DType::F16, false);
        g.add_op(OpType::Conv2d, "conv", vec![x, w], vec![y]);
        g
    }

    #[test]
    fn validate_ok() {
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn validate_catches_double_produce() {
        let mut g = tiny();
        let x = 0;
        let y = 2;
        g.add_op(OpType::Tanh, "t", vec![x], vec![y]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_catches_use_before_produce() {
        let mut g = Graph::new("t");
        let a = g.add_tensor("a", &[4], DType::F16, false);
        let b = g.add_tensor("b", &[4], DType::F16, false);
        g.add_op(OpType::Tanh, "t1", vec![b], vec![a]); // b produced later
        g.add_op(OpType::Tanh, "t2", vec![a], vec![b]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn bytes_accounting() {
        let g = tiny();
        assert_eq!(g.tensor(0).bytes(), 8 * 8 * 4 * 2);
        assert_eq!(g.weight_bytes(), 3 * 3 * 4 * 8 * 4);
    }

    #[test]
    fn histogram_and_display() {
        let g = tiny();
        assert_eq!(g.op_histogram()[&OpType::Conv2d], 1);
        assert!(format!("{}", g).contains("CONV_2D"));
    }

    #[test]
    fn op_type_names_round_trip() {
        // every kind — including the fused kinds the pattern engine
        // introduces — survives name() -> parse()
        for &ty in OpType::ALL {
            assert_eq!(OpType::parse(ty.name()), Some(ty), "{}", ty.name());
        }
        assert_eq!(OpType::ALL.len(), 26, "ALL must list every variant");
        assert_eq!(OpType::parse("FUSED_SOFTMAX"), Some(OpType::FusedSoftmax));
        assert_eq!(OpType::parse("TRANSPOSE"), Some(OpType::Transpose));
        assert_eq!(OpType::parse("EXP"), Some(OpType::Exp));
        assert_eq!(OpType::parse("SUM"), Some(OpType::Sum));
        assert_eq!(OpType::parse("DIV"), Some(OpType::Div));
        assert_eq!(OpType::parse("CONVOLUTION_9D"), None);
        assert_eq!(OpType::parse("conv_2d"), None, "names are case-sensitive");
    }

    #[test]
    fn dtype_names_round_trip() {
        for &dt in DType::ALL {
            assert_eq!(DType::parse(dt.name()), Some(dt), "{}", dt.name());
        }
        assert_eq!(DType::ALL.len(), 4);
        assert_eq!(DType::parse("f64"), None);
        assert_eq!(DType::parse("F16"), None, "names are case-sensitive");
    }
}
