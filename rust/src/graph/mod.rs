//! TFLite-level graph substrate: IR, JSON loader, and test builders.

pub mod builder;
pub mod ir;
pub mod loader;

pub use ir::{DType, Graph, Op, OpId, OpType, Tensor, TensorId};
pub use loader::{from_json, load};
