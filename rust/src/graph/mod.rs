//! TFLite-level graph substrate: IR, JSON loader, test builders, and
//! the declarative pattern-match/rewrite engine the pass layer runs on.

pub mod builder;
pub mod ir;
pub mod loader;
pub mod pattern;

pub use ir::{DType, Graph, Op, OpId, OpType, Tensor, TensorId};
pub use loader::{from_json, load};
pub use pattern::{Match, MatchCtx, OperandPattern, Pattern, PatternNode};
