//! Programmatic graph construction helpers (tests, benches, property
//! generators).  Mirrors python/compile/graphspec.py's composite emitters
//! at a smaller granularity.

use std::collections::BTreeMap;

use super::ir::{DType, Graph, OpType, TensorId};
use crate::util::rng::Rng;

pub struct GraphBuilder {
    pub g: Graph,
    act_dtype: DType,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder { g: Graph::new(name), act_dtype: DType::F16 }
    }

    pub fn input(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.g.add_tensor(name, shape, self.act_dtype, false)
    }

    pub fn weight(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.g.add_tensor(name, shape, DType::F32, true)
    }

    pub fn unary(&mut self, ty: OpType, name: &str, x: TensorId) -> TensorId {
        let shape = self.g.tensor(x).shape.clone();
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        self.g.add_op(ty, name, vec![x], vec![out]);
        out
    }

    pub fn binary(&mut self, ty: OpType, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let sa = self.g.tensor(a).shape.clone();
        let sb = self.g.tensor(b).shape.clone();
        let shape = if sa.len() >= sb.len() { sa } else { sb };
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        self.g.add_op(ty, name, vec![a, b], vec![out]);
        out
    }

    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        cout: usize,
        k: usize,
        stride: usize,
    ) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        assert_eq!(s.len(), 4, "conv input must be NHWC");
        let (n, h, w, cin) = (s[0], s[1], s[2], s[3]);
        let wt = self.weight(&format!("{name}/w"), &[k, k, cin, cout]);
        let bt = self.weight(&format!("{name}/b"), &[cout]);
        let out = self.g.add_tensor(
            &format!("{name}:out"),
            &[n, h / stride, w / stride, cout],
            self.act_dtype,
            false,
        );
        let mut attrs = BTreeMap::new();
        attrs.insert("kernel".to_string(), k as f64);
        attrs.insert("stride".to_string(), stride as f64);
        self.g.add_op_with_attrs(OpType::Conv2d, name, vec![x, wt, bt], vec![out], attrs);
        out
    }

    pub fn fully_connected(&mut self, name: &str, x: TensorId, d_out: usize) -> TensorId {
        let mut s = self.g.tensor(x).shape.clone();
        let d_in = *s.last().unwrap();
        *s.last_mut().unwrap() = d_out;
        let wt = self.weight(&format!("{name}/w"), &[d_in, d_out]);
        let bt = self.weight(&format!("{name}/b"), &[d_out]);
        let out = self.g.add_tensor(&format!("{name}:out"), &s, self.act_dtype, false);
        self.g.add_op(OpType::FullyConnected, name, vec![x, wt, bt], vec![out]);
        out
    }

    pub fn reshape(&mut self, name: &str, x: TensorId, shape: &[usize]) -> TensorId {
        let out = self.g.add_tensor(&format!("{name}:out"), shape, self.act_dtype, false);
        self.g.add_op(OpType::Reshape, name, vec![x], vec![out]);
        out
    }

    /// Dimension permutation: `out.shape[i] = in.shape[perm[i]]`.  The
    /// permutation is stored as `perm0..permN` op attrs, the form the
    /// `attention_reshape_elim` pass reads back.
    pub fn transpose(&mut self, name: &str, x: TensorId, perm: &[usize]) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        assert_eq!(s.len(), perm.len(), "perm rank mismatch");
        let shape: Vec<usize> = perm.iter().map(|&i| s[i]).collect();
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        let mut attrs = BTreeMap::new();
        for (i, &p) in perm.iter().enumerate() {
            attrs.insert(format!("perm{i}"), p as f64);
        }
        self.g.add_op_with_attrs(OpType::Transpose, name, vec![x], vec![out], attrs);
        out
    }

    /// `(B, M, K) @ (B, K, N) -> (B, M, N)` batched matmul.
    pub fn batch_matmul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let sa = self.g.tensor(a).shape.clone();
        let sb = self.g.tensor(b).shape.clone();
        assert_eq!(sa.len(), sb.len(), "batch_matmul rank mismatch");
        assert!(sa.len() >= 2, "batch_matmul needs matrix operands");
        assert_eq!(
            sa.last(),
            sb.get(sb.len() - 2),
            "batch_matmul contraction dim mismatch"
        );
        assert_eq!(
            sa[..sa.len() - 2],
            sb[..sb.len() - 2],
            "batch_matmul batch dims mismatch"
        );
        let mut shape = sa.clone();
        *shape.last_mut().unwrap() = *sb.last().unwrap();
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        self.g.add_op(OpType::BatchMatmul, name, vec![a, b], vec![out]);
        out
    }

    /// The export-form softmax island over the last axis: Exp ->
    /// Sum(keepdims) -> Div.  Three dispatches and one full-size
    /// intermediate — exactly what the `fused_softmax` pass collapses.
    pub fn softmax_decomposed(&mut self, name: &str, x: TensorId) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        let e = self.unary(OpType::Exp, &format!("{name}/exp"), x);
        let mut sum_shape = s.clone();
        *sum_shape.last_mut().unwrap() = 1;
        let sum = self.g.add_tensor(
            &format!("{name}/sum:out"),
            &sum_shape,
            self.act_dtype,
            false,
        );
        self.g.add_op(OpType::Sum, &format!("{name}/sum"), vec![e], vec![sum]);
        self.binary(OpType::Div, &format!("{name}/div"), e, sum)
    }

    pub fn broadcast_to(&mut self, name: &str, x: TensorId, shape: &[usize]) -> TensorId {
        let out = self.g.add_tensor(&format!("{name}:out"), shape, self.act_dtype, false);
        self.g.add_op(OpType::BroadcastTo, name, vec![x], vec![out]);
        out
    }

    /// The naive (export-form) group norm: rank-5 + BroadcastTo.
    pub fn group_norm_naive(&mut self, name: &str, x: TensorId, groups: usize) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
        let cg = c / groups;
        let x5 = self.reshape(&format!("{name}/r5"), x, &[n, h, w, groups, cg]);
        let mean = {
            let out = self.g.add_tensor(
                &format!("{name}/mean:out"),
                &[n, 1, 1, groups, 1],
                self.act_dtype,
                false,
            );
            self.g.add_op(OpType::Mean, &format!("{name}/mean"), vec![x5], vec![out]);
            out
        };
        let mean_b = self.broadcast_to(&format!("{name}/mean_b"), mean, &[n, h, w, groups, cg]);
        let sq = self.binary(OpType::SquaredDifference, &format!("{name}/sq"), x5, mean_b);
        let var = {
            let out = self.g.add_tensor(
                &format!("{name}/var:out"),
                &[n, 1, 1, groups, 1],
                self.act_dtype,
                false,
            );
            self.g.add_op(OpType::Mean, &format!("{name}/var"), vec![sq], vec![out]);
            out
        };
        let rstd = self.unary(OpType::Rsqrt, &format!("{name}/rsqrt"), var);
        let rstd_b = self.broadcast_to(&format!("{name}/rstd_b"), rstd, &[n, h, w, groups, cg]);
        let centered = self.binary(OpType::Sub, &format!("{name}/center"), x5, mean_b);
        let normed = self.binary(OpType::Mul, &format!("{name}/norm"), centered, rstd_b);
        let back = self.reshape(&format!("{name}/r4"), normed, &[n, h, w, c]);
        let gamma = self.weight(&format!("{name}/gamma"), &[c]);
        let beta = self.weight(&format!("{name}/beta"), &[c]);
        let scaled = self.binary(OpType::Mul, &format!("{name}/gmul"), back, gamma);
        self.binary(OpType::Add, &format!("{name}/badd"), scaled, beta)
    }

    /// A multi-head self-attention block as the TFLite export emits it
    /// (`x` is `[1, N, C]` tokens): Q/K/V projections, head split via
    /// Reshape/Transpose, scaled QK^T BatchMatmul, the decomposed
    /// softmax island, the attention-weighted V BatchMatmul, and the
    /// output projection.  Two layout redundancies the exporter leaves
    /// behind ride along on purpose — a cancelling Transpose pair on
    /// the K path (adj_y folded, then unfolded) and a cancelling
    /// Reshape pair on the V path (flatten/unflatten) — the sites
    /// `attention_reshape_elim` exists to remove.
    pub fn attention(&mut self, name: &str, x: TensorId, heads: usize) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        assert_eq!(s.len(), 3, "attention input must be [1, N, C]");
        let (n_tok, c) = (s[1], s[2]);
        assert_eq!(c % heads, 0, "heads must divide channels");
        let d = c / heads;

        let q = self.fully_connected(&format!("{name}/q"), x, c);
        let k = self.fully_connected(&format!("{name}/k"), x, c);
        let v = self.fully_connected(&format!("{name}/v"), x, c);

        // [1, N, C] -> [N, H, D] -> [H, N, D]
        let q3 = self.reshape(&format!("{name}/q_split"), q, &[n_tok, heads, d]);
        let qh = self.transpose(&format!("{name}/q_heads"), q3, &[1, 0, 2]);
        let k3 = self.reshape(&format!("{name}/k_split"), k, &[n_tok, heads, d]);
        let kh = self.transpose(&format!("{name}/k_heads"), k3, &[1, 0, 2]);
        // [H, N, D] -> [H, D, N] for QK^T
        let kt = self.transpose(&format!("{name}/k_swap"), kh, &[0, 2, 1]);
        // export artifact: adj_y folded into a transpose, then unfolded
        let k_adj = self.transpose(&format!("{name}/k_adj"), kt, &[0, 2, 1]);
        let k_unadj = self.transpose(&format!("{name}/k_unadj"), k_adj, &[0, 2, 1]);

        let logits = self.batch_matmul(&format!("{name}/qk"), qh, k_unadj);
        let scaled = self.unary(OpType::Mul, &format!("{name}/scale"), logits);
        let attn = self.softmax_decomposed(&format!("{name}/softmax"), scaled);

        let v3 = self.reshape(&format!("{name}/v_split"), v, &[n_tok, heads, d]);
        let vh = self.transpose(&format!("{name}/v_heads"), v3, &[1, 0, 2]);
        // export artifact: flatten/unflatten round trip
        let v_flat = self.reshape(&format!("{name}/v_flat"), vh, &[heads * n_tok, d]);
        let v_unflat = self.reshape(&format!("{name}/v_unflat"), v_flat, &[heads, n_tok, d]);

        let ctx = self.batch_matmul(&format!("{name}/av"), attn, v_unflat);
        let ctx_t = self.transpose(&format!("{name}/merge_heads"), ctx, &[1, 0, 2]);
        let merged = self.reshape(&format!("{name}/merge"), ctx_t, &[1, n_tok, c]);
        self.fully_connected(&format!("{name}/proj"), merged, c)
    }

    /// Decomposed tanh GELU (optionally with the paper's clamp).
    pub fn gelu(&mut self, name: &str, x: TensorId, stable: bool) -> TensorId {
        let mut gx = x;
        if stable {
            gx = self.unary(OpType::Minimum, &format!("{name}/min"), gx);
            gx = self.unary(OpType::Maximum, &format!("{name}/max"), gx);
        }
        let sq = self.binary(OpType::Mul, &format!("{name}/sq"), gx, gx);
        let cube = self.binary(OpType::Mul, &format!("{name}/cube"), sq, gx);
        let sc = self.unary(OpType::Mul, &format!("{name}/scale_cube"), cube);
        let sum = self.binary(OpType::Add, &format!("{name}/add"), gx, sc);
        let scaled = self.unary(OpType::Mul, &format!("{name}/scale"), sum);
        let t = self.unary(OpType::Tanh, &format!("{name}/tanh"), scaled);
        let one_plus = self.unary(OpType::Add, &format!("{name}/one_plus"), t);
        let half_x = self.unary(OpType::Mul, &format!("{name}/half_x"), x);
        self.binary(OpType::Mul, &format!("{name}/out"), half_x, one_plus)
    }

    pub fn finish(self) -> Graph {
        self.g
    }
}

/// Generate a random valid graph for property tests: a chain with
/// occasional branches, convs, FCs, group norms and GELUs.
pub fn random_graph(rng: &mut Rng, n_ops: usize) -> Graph {
    let mut b = GraphBuilder::new("random");
    let c0 = *rng.choose(&[8usize, 16, 32]);
    let hw = *rng.choose(&[4usize, 8, 16]);
    let mut cur = b.input("x", &[1, hw, hw, c0]);
    let mut spatial: Vec<TensorId> = vec![cur];
    for i in 0..n_ops {
        match rng.below(11) {
            0 => {
                let cout = *rng.choose(&[8usize, 16, 32, 64]);
                cur = b.conv2d(&format!("conv{i}"), cur, cout, 3, 1);
            }
            1 => {
                let cout = *rng.choose(&[8usize, 16, 32]);
                cur = b.conv2d(&format!("pconv{i}"), cur, cout, 1, 1);
            }
            2 => {
                let groups = *rng.choose(&[2usize, 4]);
                let c = *b.g.tensor(cur).shape.last().unwrap();
                if c % groups == 0 {
                    cur = b.group_norm_naive(&format!("gn{i}"), cur, groups);
                }
            }
            3 => {
                cur = b.gelu(&format!("gelu{i}"), cur, false);
            }
            4 => {
                // flatten -> FC -> restore
                let s = b.g.tensor(cur).shape.clone();
                let rows: usize = s[..s.len() - 1].iter().product();
                let d = *s.last().unwrap();
                let flat = b.reshape(&format!("flat{i}"), cur, &[rows, d]);
                let fc = b.fully_connected(&format!("fc{i}"), flat, d);
                cur = b.reshape(&format!("unflat{i}"), fc, &s);
            }
            5 => {
                cur = b.unary(OpType::Tanh, &format!("tanh{i}"), cur);
            }
            6 => {
                cur = b.unary(OpType::Logistic, &format!("sig{i}"), cur);
            }
            8 => {
                // export-form softmax island over the channel axis
                cur = b.softmax_decomposed(&format!("sm{i}"), cur);
            }
            9 => {
                // a cancelling transpose pair (exporter layout debris)
                let t = b.transpose(&format!("lay{i}"), cur, &[0, 3, 1, 2]);
                cur = b.transpose(&format!("unlay{i}"), t, &[0, 2, 3, 1]);
            }
            10 => {
                // tokenized attention block: NHWC -> [1, HW, C] -> back
                let s = b.g.tensor(cur).shape.clone();
                let (h, w, c) = (s[1], s[2], s[3]);
                if c % 2 == 0 {
                    let tok = b.reshape(&format!("tok{i}"), cur, &[1, h * w, c]);
                    let a = b.attention(&format!("attn{i}"), tok, 2);
                    cur = b.reshape(&format!("untok{i}"), a, &[1, h, w, c]);
                }
            }
            _ => {
                // residual add with an earlier same-shape tensor if any
                let shape = b.g.tensor(cur).shape.clone();
                let prev = spatial
                    .iter()
                    .rev()
                    .find(|&&t| b.g.tensor(t).shape == shape)
                    .copied();
                if let Some(p) = prev {
                    cur = b.binary(OpType::Add, &format!("res{i}"), cur, p);
                }
            }
        }
        spatial.push(cur);
    }
    let g = b.finish();
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_valid_graphs() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 16]);
        let y = b.conv2d("c1", x, 32, 3, 1);
        let z = b.group_norm_naive("gn", y, 4);
        let w = b.gelu("g", z, true);
        let _fc = {
            let flat = b.reshape("f", w, &[64, 32]);
            b.fully_connected("fc", flat, 8)
        };
        let g = b.finish();
        g.validate().unwrap();
        assert!(g.op_histogram()[&OpType::BroadcastTo] == 2);
    }

    #[test]
    fn random_graphs_always_valid() {
        for seed in 0..30 {
            let mut rng = Rng::new(seed);
            let g = random_graph(&mut rng, 20);
            g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert!(!g.ops.is_empty());
        }
    }
}
