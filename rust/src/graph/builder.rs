//! Programmatic graph construction helpers (tests, benches, property
//! generators).  Mirrors python/compile/graphspec.py's composite emitters
//! at a smaller granularity.

use std::collections::BTreeMap;

use super::ir::{DType, Graph, OpType, TensorId};
use crate::util::rng::Rng;

pub struct GraphBuilder {
    pub g: Graph,
    act_dtype: DType,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder { g: Graph::new(name), act_dtype: DType::F16 }
    }

    pub fn input(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.g.add_tensor(name, shape, self.act_dtype, false)
    }

    pub fn weight(&mut self, name: &str, shape: &[usize]) -> TensorId {
        self.g.add_tensor(name, shape, DType::F32, true)
    }

    pub fn unary(&mut self, ty: OpType, name: &str, x: TensorId) -> TensorId {
        let shape = self.g.tensor(x).shape.clone();
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        self.g.add_op(ty, name, vec![x], vec![out]);
        out
    }

    pub fn binary(&mut self, ty: OpType, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let sa = self.g.tensor(a).shape.clone();
        let sb = self.g.tensor(b).shape.clone();
        let shape = if sa.len() >= sb.len() { sa } else { sb };
        let out = self.g.add_tensor(&format!("{name}:out"), &shape, self.act_dtype, false);
        self.g.add_op(ty, name, vec![a, b], vec![out]);
        out
    }

    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        cout: usize,
        k: usize,
        stride: usize,
    ) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        assert_eq!(s.len(), 4, "conv input must be NHWC");
        let (n, h, w, cin) = (s[0], s[1], s[2], s[3]);
        let wt = self.weight(&format!("{name}/w"), &[k, k, cin, cout]);
        let bt = self.weight(&format!("{name}/b"), &[cout]);
        let out = self.g.add_tensor(
            &format!("{name}:out"),
            &[n, h / stride, w / stride, cout],
            self.act_dtype,
            false,
        );
        let mut attrs = BTreeMap::new();
        attrs.insert("kernel".to_string(), k as f64);
        attrs.insert("stride".to_string(), stride as f64);
        self.g.add_op_with_attrs(OpType::Conv2d, name, vec![x, wt, bt], vec![out], attrs);
        out
    }

    pub fn fully_connected(&mut self, name: &str, x: TensorId, d_out: usize) -> TensorId {
        let mut s = self.g.tensor(x).shape.clone();
        let d_in = *s.last().unwrap();
        *s.last_mut().unwrap() = d_out;
        let wt = self.weight(&format!("{name}/w"), &[d_in, d_out]);
        let bt = self.weight(&format!("{name}/b"), &[d_out]);
        let out = self.g.add_tensor(&format!("{name}:out"), &s, self.act_dtype, false);
        self.g.add_op(OpType::FullyConnected, name, vec![x, wt, bt], vec![out]);
        out
    }

    pub fn reshape(&mut self, name: &str, x: TensorId, shape: &[usize]) -> TensorId {
        let out = self.g.add_tensor(&format!("{name}:out"), shape, self.act_dtype, false);
        self.g.add_op(OpType::Reshape, name, vec![x], vec![out]);
        out
    }

    pub fn broadcast_to(&mut self, name: &str, x: TensorId, shape: &[usize]) -> TensorId {
        let out = self.g.add_tensor(&format!("{name}:out"), shape, self.act_dtype, false);
        self.g.add_op(OpType::BroadcastTo, name, vec![x], vec![out]);
        out
    }

    /// The naive (export-form) group norm: rank-5 + BroadcastTo.
    pub fn group_norm_naive(&mut self, name: &str, x: TensorId, groups: usize) -> TensorId {
        let s = self.g.tensor(x).shape.clone();
        let (n, h, w, c) = (s[0], s[1], s[2], s[3]);
        let cg = c / groups;
        let x5 = self.reshape(&format!("{name}/r5"), x, &[n, h, w, groups, cg]);
        let mean = {
            let out = self.g.add_tensor(
                &format!("{name}/mean:out"),
                &[n, 1, 1, groups, 1],
                self.act_dtype,
                false,
            );
            self.g.add_op(OpType::Mean, &format!("{name}/mean"), vec![x5], vec![out]);
            out
        };
        let mean_b = self.broadcast_to(&format!("{name}/mean_b"), mean, &[n, h, w, groups, cg]);
        let sq = self.binary(OpType::SquaredDifference, &format!("{name}/sq"), x5, mean_b);
        let var = {
            let out = self.g.add_tensor(
                &format!("{name}/var:out"),
                &[n, 1, 1, groups, 1],
                self.act_dtype,
                false,
            );
            self.g.add_op(OpType::Mean, &format!("{name}/var"), vec![sq], vec![out]);
            out
        };
        let rstd = self.unary(OpType::Rsqrt, &format!("{name}/rsqrt"), var);
        let rstd_b = self.broadcast_to(&format!("{name}/rstd_b"), rstd, &[n, h, w, groups, cg]);
        let centered = self.binary(OpType::Sub, &format!("{name}/center"), x5, mean_b);
        let normed = self.binary(OpType::Mul, &format!("{name}/norm"), centered, rstd_b);
        let back = self.reshape(&format!("{name}/r4"), normed, &[n, h, w, c]);
        let gamma = self.weight(&format!("{name}/gamma"), &[c]);
        let beta = self.weight(&format!("{name}/beta"), &[c]);
        let scaled = self.binary(OpType::Mul, &format!("{name}/gmul"), back, gamma);
        self.binary(OpType::Add, &format!("{name}/badd"), scaled, beta)
    }

    /// Decomposed tanh GELU (optionally with the paper's clamp).
    pub fn gelu(&mut self, name: &str, x: TensorId, stable: bool) -> TensorId {
        let mut gx = x;
        if stable {
            gx = self.unary(OpType::Minimum, &format!("{name}/min"), gx);
            gx = self.unary(OpType::Maximum, &format!("{name}/max"), gx);
        }
        let sq = self.binary(OpType::Mul, &format!("{name}/sq"), gx, gx);
        let cube = self.binary(OpType::Mul, &format!("{name}/cube"), sq, gx);
        let sc = self.unary(OpType::Mul, &format!("{name}/scale_cube"), cube);
        let sum = self.binary(OpType::Add, &format!("{name}/add"), gx, sc);
        let scaled = self.unary(OpType::Mul, &format!("{name}/scale"), sum);
        let t = self.unary(OpType::Tanh, &format!("{name}/tanh"), scaled);
        let one_plus = self.unary(OpType::Add, &format!("{name}/one_plus"), t);
        let half_x = self.unary(OpType::Mul, &format!("{name}/half_x"), x);
        self.binary(OpType::Mul, &format!("{name}/out"), half_x, one_plus)
    }

    pub fn finish(self) -> Graph {
        self.g
    }
}

/// Generate a random valid graph for property tests: a chain with
/// occasional branches, convs, FCs, group norms and GELUs.
pub fn random_graph(rng: &mut Rng, n_ops: usize) -> Graph {
    let mut b = GraphBuilder::new("random");
    let c0 = *rng.choose(&[8usize, 16, 32]);
    let hw = *rng.choose(&[4usize, 8, 16]);
    let mut cur = b.input("x", &[1, hw, hw, c0]);
    let mut spatial: Vec<TensorId> = vec![cur];
    for i in 0..n_ops {
        match rng.below(8) {
            0 => {
                let cout = *rng.choose(&[8usize, 16, 32, 64]);
                cur = b.conv2d(&format!("conv{i}"), cur, cout, 3, 1);
            }
            1 => {
                let cout = *rng.choose(&[8usize, 16, 32]);
                cur = b.conv2d(&format!("pconv{i}"), cur, cout, 1, 1);
            }
            2 => {
                let groups = *rng.choose(&[2usize, 4]);
                let c = *b.g.tensor(cur).shape.last().unwrap();
                if c % groups == 0 {
                    cur = b.group_norm_naive(&format!("gn{i}"), cur, groups);
                }
            }
            3 => {
                cur = b.gelu(&format!("gelu{i}"), cur, false);
            }
            4 => {
                // flatten -> FC -> restore
                let s = b.g.tensor(cur).shape.clone();
                let rows: usize = s[..s.len() - 1].iter().product();
                let d = *s.last().unwrap();
                let flat = b.reshape(&format!("flat{i}"), cur, &[rows, d]);
                let fc = b.fully_connected(&format!("fc{i}"), flat, d);
                cur = b.reshape(&format!("unflat{i}"), fc, &s);
            }
            5 => {
                cur = b.unary(OpType::Tanh, &format!("tanh{i}"), cur);
            }
            6 => {
                cur = b.unary(OpType::Logistic, &format!("sig{i}"), cur);
            }
            _ => {
                // residual add with an earlier same-shape tensor if any
                let shape = b.g.tensor(cur).shape.clone();
                let prev = spatial
                    .iter()
                    .rev()
                    .find(|&&t| b.g.tensor(t).shape == shape)
                    .copied();
                if let Some(p) = prev {
                    cur = b.binary(OpType::Add, &format!("res{i}"), cur, p);
                }
            }
        }
        spatial.push(cur);
    }
    let g = b.finish();
    debug_assert!(g.validate().is_ok());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_valid_graphs() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 16]);
        let y = b.conv2d("c1", x, 32, 3, 1);
        let z = b.group_norm_naive("gn", y, 4);
        let w = b.gelu("g", z, true);
        let _fc = {
            let flat = b.reshape("f", w, &[64, 32]);
            b.fully_connected("fc", flat, 8)
        };
        let g = b.finish();
        g.validate().unwrap();
        assert!(g.op_histogram()[&OpType::BroadcastTo] == 2);
    }

    #[test]
    fn random_graphs_always_valid() {
        for seed in 0..30 {
            let mut rng = Rng::new(seed);
            let g = random_graph(&mut rng, 20);
            g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert!(!g.ops.is_empty());
        }
    }
}
