//! Declarative subgraph pattern matching and rewriting — the compiler
//! core the pass layer is founded on.
//!
//! A [`Pattern`] is a tree of [`PatternNode`]s describing an op
//! island: each node constrains the op kind ([`PatternNode::op`] /
//! [`PatternNode::one_of`]), adds arbitrary predicates over the op and
//! its tensors, and walks backwards through producers via
//! [`OperandPattern`]s.  Tensor bindings unify — `Tensor("x")`
//! appearing twice must resolve to the same tensor id, which is how
//! the GELU cubic chain (`x*x*x`) is expressed.  Anchors can require
//! their outputs to be single-consumer ([`PatternNode::single_use`]),
//! or inspect the full consumer sets through the [`MatchCtx`] for
//! multi-consumer islands like the decomposed softmax (`Exp` feeding
//! both `Sum` and `Div`).
//!
//! [`apply`] is the rewrite driver: it scans for matches, hands each
//! to an imperative rewrite callback, and iterates to a fixed point.
//! After every accepted rewrite the driver renumbers op ids, re-runs
//! [`Graph::validate`], and checks the structural contract every pass
//! must keep: graph outputs keep their shape and dtype, and no
//! consumed activation tensor loses its producer.  A rewrite callback
//! may reject a site (return `false`) — e.g. when a cost model finds
//! no profitable serialization — but must then leave the graph
//! untouched.

use std::collections::BTreeMap;

use super::ir::{DType, Graph, Op, OpId, OpType, TensorId};

/// Adjacency snapshot of the graph being matched, handed to
/// predicates and guards.  Valid for one scan: op ids equal op
/// positions (the driver renumbers after every rewrite).
pub struct MatchCtx<'g> {
    pub graph: &'g Graph,
    /// producer op of each tensor (`None` for inputs/consts)
    pub producers: Vec<Option<OpId>>,
    /// consumer ops of each tensor
    pub consumers: Vec<Vec<OpId>>,
}

impl<'g> MatchCtx<'g> {
    pub fn new(graph: &'g Graph) -> MatchCtx<'g> {
        MatchCtx {
            graph,
            producers: graph.producers(),
            consumers: graph.consumers(),
        }
    }

    /// Number of ops reading `t`.
    pub fn consumer_count(&self, t: TensorId) -> usize {
        self.consumers[t].len()
    }

    /// The op producing `t`, if any.
    pub fn producer_op(&self, t: TensorId) -> Option<&'g Op> {
        self.producers[t].map(|i| &self.graph.ops[i])
    }
}

/// Named bindings captured by a successful match.
#[derive(Debug, Clone, Default)]
pub struct Match {
    /// the op the pattern root matched
    pub anchor: OpId,
    ops: BTreeMap<&'static str, OpId>,
    tensors: BTreeMap<&'static str, TensorId>,
}

impl Match {
    /// The op bound under `name`; panics when absent (a pattern bug,
    /// not a graph condition).
    pub fn op(&self, name: &str) -> OpId {
        match self.ops.get(name) {
            Some(&id) => id,
            None => panic!("pattern bound no op named '{name}'"),
        }
    }

    /// The tensor bound under `name`; panics when absent.
    pub fn tensor(&self, name: &str) -> TensorId {
        match self.tensors.get(name) {
            Some(&id) => id,
            None => panic!("pattern bound no tensor named '{name}'"),
        }
    }

    pub fn try_op(&self, name: &str) -> Option<OpId> {
        self.ops.get(name).copied()
    }

    pub fn try_tensor(&self, name: &str) -> Option<TensorId> {
        self.tensors.get(name).copied()
    }

    /// Bind `name` to `t`, or check consistency if already bound.
    fn unify_tensor(&mut self, name: &'static str, t: TensorId) -> bool {
        match self.tensors.get(name) {
            Some(&prev) => prev == t,
            None => {
                self.tensors.insert(name, t);
                true
            }
        }
    }
}

type Pred = Box<dyn Fn(&MatchCtx, &Op) -> bool>;
type Guard = Box<dyn Fn(&MatchCtx, &Match) -> bool>;

/// Constraint on one input slot of a matched op.
pub enum OperandPattern {
    /// Bind (or unify) the input tensor itself under a name.
    Tensor(&'static str),
    /// The input must be produced by an op matching the sub-pattern.
    Produced(PatternNode),
}

/// One node of a pattern tree: op-kind alternatives, predicates,
/// operand constraints, and capture bindings.
pub struct PatternNode {
    kinds: Vec<OpType>,
    preds: Vec<Pred>,
    capture: Option<&'static str>,
    operands: Vec<(usize, OperandPattern)>,
    commutative: bool,
    single_use: bool,
}

impl PatternNode {
    /// Match exactly this op kind.
    pub fn op(ty: OpType) -> PatternNode {
        PatternNode {
            kinds: vec![ty],
            preds: Vec::new(),
            capture: None,
            operands: Vec::new(),
            commutative: false,
            single_use: false,
        }
    }

    /// Match any of the given kinds.
    pub fn one_of(tys: &[OpType]) -> PatternNode {
        let mut n = PatternNode::op(tys.first().copied().expect("non-empty kinds"));
        n.kinds = tys.to_vec();
        n
    }

    /// Capture the matched op id under `name`.
    pub fn named(mut self, name: &'static str) -> PatternNode {
        self.capture = Some(name);
        self
    }

    /// Extra predicate over the candidate op (evaluated before
    /// operands are walked).
    pub fn pred(
        mut self,
        f: impl Fn(&MatchCtx, &Op) -> bool + 'static,
    ) -> PatternNode {
        self.preds.push(Box::new(f));
        self
    }

    /// Constrain input slot `slot`.
    pub fn operand(mut self, slot: usize, p: OperandPattern) -> PatternNode {
        self.operands.push((slot, p));
        self
    }

    /// With exactly two operand constraints: try them against input
    /// slots (0, 1) and, on failure, (1, 0).  Declared slots are
    /// ignored in this mode.
    ///
    /// Backtracking is local to this node's subtree: the swapped order
    /// is retried only when the forward order fails *structurally*
    /// (including unification failures inside the subtree).  A failure
    /// in a later sibling subtree or in a whole-match guard does not
    /// revisit the choice — write order-disambiguating constraints
    /// into the operand patterns themselves, not into guards.
    pub fn commutative(mut self) -> PatternNode {
        self.commutative = true;
        self
    }

    /// Every output of the matched op must have exactly one consumer.
    pub fn single_use(mut self) -> PatternNode {
        self.single_use = true;
        self
    }
}

/// A rooted pattern plus whole-match guards evaluated after the
/// structural walk succeeds.
pub struct Pattern {
    root: PatternNode,
    guards: Vec<Guard>,
}

impl Pattern {
    pub fn new(root: PatternNode) -> Pattern {
        Pattern { root, guards: Vec::new() }
    }

    /// Add a guard over the completed bindings (cross-binding checks
    /// the per-node predicates cannot express).
    pub fn guard(
        mut self,
        f: impl Fn(&MatchCtx, &Match) -> bool + 'static,
    ) -> Pattern {
        self.guards.push(Box::new(f));
        self
    }
}

fn match_operand(
    ctx: &MatchCtx,
    p: &OperandPattern,
    op: &Op,
    slot: usize,
    m: &mut Match,
) -> bool {
    let t = op.inputs[slot];
    match p {
        OperandPattern::Tensor(name) => m.unify_tensor(name, t),
        OperandPattern::Produced(sub) => match ctx.producers[t] {
            Some(pid) => match_node(ctx, sub, pid, m),
            None => false,
        },
    }
}

fn match_node(ctx: &MatchCtx, node: &PatternNode, op_id: OpId, m: &mut Match) -> bool {
    let op = &ctx.graph.ops[op_id];
    if !node.kinds.is_empty() && !node.kinds.contains(&op.ty) {
        return false;
    }
    for p in &node.preds {
        if !p(ctx, op) {
            return false;
        }
    }
    if node.single_use && !op.outputs.iter().all(|&t| ctx.consumers[t].len() == 1) {
        return false;
    }

    if node.commutative {
        assert_eq!(
            node.operands.len(),
            2,
            "commutative() requires exactly two operand constraints"
        );
        if op.inputs.len() < 2 {
            return false;
        }
        let save = m.clone();
        let forward = match_operand(ctx, &node.operands[0].1, op, 0, m)
            && match_operand(ctx, &node.operands[1].1, op, 1, m);
        if !forward {
            *m = save.clone();
            let swapped = match_operand(ctx, &node.operands[0].1, op, 1, m)
                && match_operand(ctx, &node.operands[1].1, op, 0, m);
            if !swapped {
                *m = save;
                return false;
            }
        }
    } else {
        for (slot, p) in &node.operands {
            if *slot >= op.inputs.len() {
                return false;
            }
            if !match_operand(ctx, p, op, *slot, m) {
                return false;
            }
        }
    }

    if let Some(name) = node.capture {
        m.ops.insert(name, op_id);
    }
    true
}

/// All matches of `pattern` against the current graph, in op order.
/// Op ids must equal op positions (use from inside [`apply`], or
/// renumber first).
pub fn find_matches(g: &Graph, pattern: &Pattern) -> Vec<Match> {
    let ctx = MatchCtx::new(g);
    let mut out = Vec::new();
    for op in &g.ops {
        let mut m = Match { anchor: op.id, ..Match::default() };
        if match_node(&ctx, &pattern.root, op.id, &mut m)
            && pattern.guards.iter().all(|gd| gd(&ctx, &m))
        {
            out.push(m);
        }
    }
    out
}

/// The first match whose anchor position is `>= start`, or `None`.
fn next_match(g: &Graph, pattern: &Pattern, start: usize) -> Option<Match> {
    let ctx = MatchCtx::new(g);
    for op in &g.ops[start.min(g.ops.len())..] {
        let mut m = Match { anchor: op.id, ..Match::default() };
        if match_node(&ctx, &pattern.root, op.id, &mut m)
            && pattern.guards.iter().all(|gd| gd(&ctx, &m))
        {
            return Some(m);
        }
    }
    None
}

/// Safety cap on fixed-point iteration: a rule applying more rewrites
/// than this is assumed non-terminating (every shipped pass consumes
/// its anchor, so applications are bounded by the op count).
pub const MAX_APPLICATIONS: usize = 100_000;

/// Shape/dtype contract snapshot taken before each rewrite.
struct OutputSnapshot {
    /// (tensor, shape, dtype) of every graph output — produced,
    /// unconsumed, non-const — before the rewrite
    outputs: Vec<(TensorId, Vec<usize>, DType)>,
    /// tensors with no producer before the rewrite (graph inputs)
    was_input: Vec<bool>,
}

impl OutputSnapshot {
    fn take(g: &Graph) -> OutputSnapshot {
        let producers = g.producers();
        let consumers = g.consumers();
        let mut outputs = Vec::new();
        for t in &g.tensors {
            if !t.is_const && producers[t.id].is_some() && consumers[t.id].is_empty() {
                outputs.push((t.id, t.shape.clone(), t.dtype));
            }
        }
        let was_input = producers.iter().map(|p| p.is_none()).collect();
        OutputSnapshot { outputs, was_input }
    }

    fn check(&self, g: &Graph, pass: &str) {
        if let Err(e) = g.validate() {
            panic!("pass '{pass}' broke graph validity: {e}");
        }
        let producers = g.producers();
        for (t, shape, dtype) in &self.outputs {
            assert!(
                producers[*t].is_some(),
                "pass '{pass}' stopped producing graph output tensor {t}"
            );
            let now = g.tensor(*t);
            assert_eq!(
                &now.shape, shape,
                "pass '{pass}' changed the shape of graph output {t}"
            );
            assert_eq!(
                now.dtype, *dtype,
                "pass '{pass}' changed the dtype of graph output {t}"
            );
        }
        // no consumed activation tensor may lose its producer (validate
        // alone would silently reclassify it as a graph input)
        for op in &g.ops {
            for &i in &op.inputs {
                if !g.tensor(i).is_const
                    && producers[i].is_none()
                    && !self.was_input.get(i).copied().unwrap_or(false)
                {
                    panic!(
                        "pass '{pass}' orphaned consumed tensor {i} ({})",
                        g.tensor(i).name
                    );
                }
            }
        }
    }
}

fn renumber(g: &mut Graph) {
    for (i, op) in g.ops.iter_mut().enumerate() {
        op.id = i;
    }
}

/// The rewrite driver: match `pattern`, hand each match to `rewrite`,
/// and iterate to a fixed point.  Returns the number of accepted
/// rewrites.
///
/// Per accepted rewrite the driver renumbers op ids, re-validates the
/// graph, and enforces the output shape/dtype contract (panicking on
/// violation — a pass bug, never a graph condition).  `rewrite` may
/// reject a site by returning `false`, in which case it must leave
/// the graph untouched; rejected sites are re-offered on the next
/// scan only if the graph changed since.
pub fn apply<F>(g: &mut Graph, name: &str, pattern: &Pattern, mut rewrite: F) -> usize
where
    F: FnMut(&mut Graph, &Match) -> bool,
{
    renumber(g);
    let mut applied = 0usize;
    // scan resume point: rejecting callbacks leave the graph untouched,
    // so after a rejection the scan continues past that anchor instead
    // of replaying the whole match list
    let mut start = 0usize;
    // contract snapshot, refreshed only when the graph actually changes
    let mut before = OutputSnapshot::take(g);
    loop {
        let m = match next_match(g, pattern, start) {
            Some(m) => m,
            // no match at or after `start`, and every earlier anchor was
            // rejected against this exact graph: fixed point reached
            None => return applied,
        };
        let anchor = m.anchor;
        if rewrite(g, &m) {
            renumber(g);
            before.check(g, name);
            applied += 1;
            assert!(
                applied <= MAX_APPLICATIONS,
                "pass '{name}' did not reach a fixed point"
            );
            start = 0; // op ids are stale; restart the scan
            before = OutputSnapshot::take(g);
        } else {
            start = anchor + 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn chain() -> Graph {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8]);
        let t = b.unary(OpType::Tanh, "t1", x);
        let s = b.unary(OpType::Logistic, "s1", t);
        b.unary(OpType::Tanh, "t2", s);
        b.finish()
    }

    #[test]
    fn matches_by_kind_and_walks_producers() {
        let g = chain();
        // Tanh fed by a Logistic: only t2 qualifies
        let p = Pattern::new(
            PatternNode::op(OpType::Tanh)
                .operand(0, OperandPattern::Produced(PatternNode::op(OpType::Logistic).named("sig"))),
        );
        let ms = find_matches(&g, &p);
        assert_eq!(ms.len(), 1);
        assert_eq!(g.ops[ms[0].anchor].name, "t2");
        assert_eq!(g.ops[ms[0].ops["sig"]].name, "s1");
    }

    #[test]
    fn tensor_bindings_unify() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let y = b.input("y", &[4]);
        let sq = b.binary(OpType::Mul, "sq", x, x);
        b.binary(OpType::Mul, "xy", x, y);
        let _ = sq;
        let g = b.finish();
        // Mul(x, x): only the square matches
        let p = Pattern::new(
            PatternNode::op(OpType::Mul)
                .operand(0, OperandPattern::Tensor("x"))
                .operand(1, OperandPattern::Tensor("x")),
        );
        let ms = find_matches(&g, &p);
        assert_eq!(ms.len(), 1);
        assert_eq!(g.ops[ms[0].anchor].name, "sq");
        assert_eq!(ms[0].tensor("x"), 0);
    }

    #[test]
    fn commutative_tries_both_orders() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let t = b.unary(OpType::Tanh, "t", x);
        b.binary(OpType::Add, "a", t, x); // tanh in slot 0
        b.binary(OpType::Add, "b", x, t); // tanh in slot 1
        let g = b.finish();
        let mk = || {
            Pattern::new(
                PatternNode::op(OpType::Add)
                    .operand(0, OperandPattern::Tensor("raw"))
                    .operand(1, OperandPattern::Produced(PatternNode::op(OpType::Tanh)))
                    .commutative(),
            )
        };
        let ms = find_matches(&g, &mk());
        assert_eq!(ms.len(), 2, "both operand orders match");
        for m in &ms {
            assert_eq!(m.tensor("raw"), 0, "raw always binds the non-tanh input");
        }
    }

    #[test]
    fn single_use_rejects_shared_tensors() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let t = b.unary(OpType::Tanh, "t", x);
        b.unary(OpType::Logistic, "s1", t);
        b.unary(OpType::Logistic, "s2", t);
        let g = b.finish();
        let p = Pattern::new(
            PatternNode::op(OpType::Logistic).operand(
                0,
                OperandPattern::Produced(PatternNode::op(OpType::Tanh).single_use()),
            ),
        );
        assert!(find_matches(&g, &p).is_empty(), "tanh output has two readers");
    }

    #[test]
    fn guards_see_the_full_binding_set() {
        let g = chain();
        let p = Pattern::new(PatternNode::op(OpType::Tanh).named("t"))
            .guard(|ctx, m| ctx.graph.ops[m.op("t")].name == "t1");
        let ms = find_matches(&g, &p);
        assert_eq!(ms.len(), 1);
        assert_eq!(g.ops[ms[0].anchor].name, "t1");
    }

    #[test]
    fn apply_reaches_fixed_point_and_validates() {
        // rewrite Tanh -> Logistic until none remain
        let mut g = chain();
        let p = Pattern::new(PatternNode::op(OpType::Tanh));
        let n = apply(&mut g, "tanh-to-logistic", &p, |g, m| {
            g.ops[m.anchor].ty = OpType::Logistic;
            true
        });
        assert_eq!(n, 2);
        assert_eq!(g.op_histogram().get(&OpType::Tanh), None);
        g.validate().unwrap();
    }

    #[test]
    fn rejected_sites_do_not_loop_forever() {
        let mut g = chain();
        let p = Pattern::new(PatternNode::op(OpType::Tanh));
        let n = apply(&mut g, "reject-all", &p, |_, _| false);
        assert_eq!(n, 0);
    }

    #[test]
    #[should_panic(expected = "changed the shape")]
    fn output_shape_contract_is_enforced() {
        let mut g = chain();
        let p = Pattern::new(PatternNode::op(OpType::Logistic));
        apply(&mut g, "bad-pass", &p, |g, _| {
            // mutate the graph output's shape — the driver must catch it
            let out = g.ops.last().unwrap().outputs[0];
            g.tensors[out].shape = vec![2, 2, 2];
            true
        });
    }
}
