//! W8A8 activation quantization: per-tensor symmetric int8 scales for
//! *activation* tensors, the runtime companion of the per-channel
//! weight quantization in [`super`].
//!
//! Weights quantize offline per output channel; activations are only
//! known at runtime, so they get a single per-tensor scale derived
//! from a recorded absolute-maximum range.  The stub backend's outputs
//! live in `[-0.5, 0.5)` by construction, so the testkit stamps every
//! STUBHLO program with [`stub_activation_scale`]; a real deployment
//! would record ranges during a calibration pass.  The planner turns
//! the mode on per `(device, variant)` only where the calibrated cost
//! model prices the bandwidth saving above the quant/dequant boundary
//! cost ([`crate::delegate::w8a8_gain`]).

/// Bytes per int8-quantized activation element — what the memory
/// ledger charges for activation buffers under W8A8 (fp32 charges 4).
pub const INT8_BYTES_PER_ELEM: usize = 1;

/// Absolute-maximum range of stub-backend activations: every output
/// element is in `[-0.5, 0.5)` by construction of the interpreter.
pub const STUB_ACT_AMAX: f32 = 0.5;

/// Per-tensor symmetric scale covering `[-amax, amax]` with int8.
pub fn scale_for_amax(amax: f32) -> f32 {
    if amax > 0.0 {
        amax / 127.0
    } else {
        1.0
    }
}

/// The per-tensor scale the testkit writes into STUBHLO `aquant`
/// lines.
pub fn stub_activation_scale() -> f32 {
    scale_for_amax(STUB_ACT_AMAX)
}

/// Worst-case round-trip error for values within the recorded range.
pub fn tolerance(scale: f32) -> f32 {
    scale * 0.5
}

/// Per-tensor symmetric int8 quantization.
pub fn quantize_per_tensor(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8).collect()
}

pub fn dequantize_per_tensor(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_is_within_tolerance_for_in_range_values() {
        let scale = stub_activation_scale();
        let x: Vec<f32> = (0..256).map(|i| (i as f32 / 255.0) - 0.5).collect();
        let dq = dequantize_per_tensor(&quantize_per_tensor(&x, scale), scale);
        let tol = tolerance(scale);
        for (a, b) in x.iter().zip(&dq) {
            assert!((a - b).abs() <= tol + 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn out_of_range_values_saturate_instead_of_wrapping() {
        let scale = scale_for_amax(1.0);
        let q = quantize_per_tensor(&[10.0, -10.0], scale);
        assert_eq!(q, vec![127, -127]);
    }

    #[test]
    fn zero_range_degrades_to_unit_scale() {
        assert_eq!(scale_for_amax(0.0), 1.0);
        assert_eq!(scale_for_amax(-1.0), 1.0);
    }

    #[test]
    fn property_quantized_activations_never_overflow() {
        crate::util::miniprop::forall("w8a8 bounds", 50, |g| {
            let n = g.usize_in(1, 64);
            let amax = g.f64_in(0.01, 10.0) as f32;
            let x = g.f32_vec(n, amax);
            let scale = scale_for_amax(amax);
            let q = quantize_per_tensor(&x, scale);
            assert!(q.iter().all(|&v| (-127..=127).contains(&(v as i32))));
            let dq = dequantize_per_tensor(&q, scale);
            let tol = tolerance(scale);
            for (a, b) in x.iter().zip(&dq) {
                // in-range values round-trip within half a step;
                // clamped ones stop at the range edge
                let bound = if a.abs() <= amax { tol + 1e-6 } else { a.abs() - 127.0 * scale + tol };
                assert!((a - b).abs() <= bound.max(tol + 1e-6), "{a} vs {b}");
            }
        });
    }
}
