//! Model compression (paper Sec. 3.4): weight storage, quantization
//! round-trips, structured pruning accounting, and the block-wise
//! reconstruction-error metric.

pub mod activations;
pub mod weights;

pub use activations::{
    dequantize_per_tensor, quantize_per_tensor, scale_for_amax, stub_activation_scale,
};
pub use weights::{Payload, WeightFile, WeightTensor};

/// Per-output-channel symmetric int8 quantization (the Rust mirror of
/// python/compile/quantize.py; used by tests and the ablation benches to
/// quantize on the fly).
pub fn quantize_per_channel(w: &[f32], cout: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(cout > 0 && w.len() % cout == 0);
    let rows = w.len() / cout;
    let mut scale = vec![1.0f32; cout];
    for c in 0..cout {
        let mut amax = 0f32;
        for r in 0..rows {
            amax = amax.max(w[r * cout + c].abs());
        }
        scale[c] = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    }
    let q = w
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let s = scale[i % cout];
            (v / s).round().clamp(-127.0, 127.0) as i8
        })
        .collect();
    (q, scale)
}

pub fn dequantize(q: &[i8], scale: &[f32]) -> Vec<f32> {
    let cout = scale.len();
    q.iter()
        .enumerate()
        .map(|(i, &v)| v as f32 * scale[i % cout])
        .collect()
}

/// Block-wise reconstruction error (Li et al. 2021 / Wei et al. 2022):
/// MSE of a compressed block's output against the full-precision block
/// on the same input — the paper's indirect quality metric.
pub fn reconstruction_error(y_ref: &[f32], y_cmp: &[f32]) -> f64 {
    crate::util::stats::mse(y_ref, y_cmp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_round_trip_error_bound() {
        let mut rng = crate::util::rng::Rng::new(5);
        let cout = 16;
        let w = rng.normal_f32_vec(64 * cout);
        let (q, scale) = quantize_per_channel(&w, cout);
        let dq = dequantize(&q, &scale);
        for (i, (&a, &b)) in w.iter().zip(&dq).enumerate() {
            let s = scale[i % cout];
            assert!((a - b).abs() <= s * 0.5 + 1e-7, "elem {i}");
        }
    }

    #[test]
    fn quant_matches_python_semantics() {
        // identical algorithm to quantize.quantize_per_channel
        let w = [1.0f32, -2.0, 0.5, 127.0, 0.0, -127.0];
        let (q, scale) = quantize_per_channel(&w, 2);
        assert!((scale[0] - 1.0 / 127.0 * 1.0).abs() < 1e-7 || scale[0] > 0.0);
        let dq = dequantize(&q, &scale);
        assert!((dq[3] - 127.0).abs() < 1e-3);
    }

    #[test]
    fn property_quant_never_overflows() {
        crate::util::miniprop::forall("quant bounds", 50, |g| {
            let cout = g.usize_in(1, 8);
            let rows = g.usize_in(1, 32);
            let scale = g.f64_in(0.001, 100.0) as f32;
            let w = g.f32_vec(rows * cout, scale);
            let (q, scale) = quantize_per_channel(&w, cout);
            assert!(q.iter().all(|&v| (-127..=127).contains(&(v as i32))));
            assert!(scale.iter().all(|&s| s > 0.0));
        });
    }

    #[test]
    fn reconstruction_error_zero_for_identical() {
        let y = vec![1.0f32, 2.0, 3.0];
        assert_eq!(reconstruction_error(&y, &y), 0.0);
    }
}
