//! MDWB weight-container reader — the Rust half of
//! python/compile/weightsbin.py (see that file for the layout).
//!
//! The coordinator owns weight *storage* the way the paper's app does
//! (Sec. 3.4): f32 payloads load as-is; int8 payloads are kept 8-bit in
//! memory (the ledger charges 1 byte/elem + scales) and cast up to f32
//! per tensor at executable-feed time — W8A16: 8-bit at rest, 16/32-bit
//! in compute.  Structurally pruned output channels are not stored and
//! re-inflate to zeros.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

const MAGIC: &[u8; 4] = b"MDWB";
const VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    /// int8 payload with per-output-channel scale and keep-mask
    I8 {
        data: Vec<i8>,          // rows x kept
        scale: Vec<f32>,        // cout
        keep: Vec<bool>,        // cout
    },
}

#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub path: String,
    /// logical (unpruned) shape
    pub shape: Vec<usize>,
    pub payload: Payload,
}

impl WeightTensor {
    pub fn logical_elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes this tensor occupies *at rest* (the memory-ledger number).
    pub fn stored_bytes(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len() * 4,
            Payload::I8 { data, scale, keep } => {
                data.len() + scale.len() * 4 + keep.len()
            }
        }
    }

    /// Dequantize / inflate to a dense f32 buffer in logical shape
    /// (the cast-up the paper performs before computation).
    ///
    /// fp32 payloads are returned as a *borrowed* view — the serving
    /// hot path uploads straight from the parsed container without
    /// doubling peak host memory.  Only int8 payloads allocate (the
    /// dequantized copy the caller cannot alias).
    pub fn to_f32(&self) -> Cow<'_, [f32]> {
        match &self.payload {
            Payload::F32(v) => Cow::Borrowed(v.as_slice()),
            Payload::I8 { data, scale, keep } => {
                let cout = keep.len();
                let rows = self.logical_elems() / cout;
                let kept: Vec<usize> = (0..cout).filter(|&c| keep[c]).collect();
                let mut out = vec![0f32; rows * cout];
                for r in 0..rows {
                    for (j, &c) in kept.iter().enumerate() {
                        out[r * cout + c] =
                            data[r * kept.len() + j] as f32 * scale[c];
                    }
                }
                Cow::Owned(out)
            }
        }
    }
}

#[derive(Debug, Default)]
pub struct WeightFile {
    pub tensors: BTreeMap<String, WeightTensor>,
    pub file_bytes: usize,
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            return Err(Error::Weights(format!(
                "truncated file at offset {}",
                self.pos
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

impl WeightFile {
    pub fn load(path: &Path) -> Result<WeightFile> {
        let data = std::fs::read(path)
            .map_err(|e| Error::Weights(format!("{}: {}", path.display(), e)))?;
        Self::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<WeightFile> {
        let mut c = Cursor { data, pos: 0 };
        if c.take(4)? != MAGIC {
            return Err(Error::Weights("bad magic".into()));
        }
        let version = c.u32()?;
        if version != VERSION {
            return Err(Error::Weights(format!("unsupported version {version}")));
        }
        let count = c.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let plen = c.u16()? as usize;
            let path = String::from_utf8(c.take(plen)?.to_vec())
                .map_err(|_| Error::Weights("bad utf8 path".into()))?;
            let dtype = c.u8()?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let elems: usize = shape.iter().product();
            let payload = match dtype {
                0 => {
                    let raw = c.take(elems * 4)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect();
                    Payload::F32(v)
                }
                1 => {
                    let cout = *shape.last().ok_or_else(|| {
                        Error::Weights("int8 tensor needs rank >= 1".into())
                    })?;
                    let scale: Vec<f32> = c
                        .take(cout * 4)?
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect();
                    let keep: Vec<bool> =
                        c.take(cout)?.iter().map(|&b| b != 0).collect();
                    let kept = keep.iter().filter(|&&k| k).count();
                    let rows = elems / cout;
                    let raw = c.take(rows * kept)?;
                    let v = raw.iter().map(|&b| b as i8).collect();
                    Payload::I8 { data: v, scale, keep }
                }
                d => return Err(Error::Weights(format!("bad dtype {d}"))),
            };
            tensors.insert(path.clone(), WeightTensor { path, shape, payload });
        }
        Ok(WeightFile { tensors, file_bytes: data.len() })
    }

    /// Sum of at-rest bytes over all tensors.
    pub fn stored_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.stored_bytes()).sum()
    }

    /// Dense f32 buffers in the manifest's sorted-path order.
    pub fn to_f32_ordered(&self, order: &[String]) -> Result<Vec<Vec<f32>>> {
        order
            .iter()
            .map(|p| {
                self.tensors
                    .get(p)
                    .map(|t| t.to_f32().into_owned())
                    .ok_or_else(|| Error::Weights(format!("missing tensor {p}")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny MDWB in memory matching the Python writer's layout.
    fn sample_file() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());

        // tensor 1: f32 "a/w" shape (2, 3)
        out.extend_from_slice(&(3u16).to_le_bytes());
        out.extend_from_slice(b"a/w");
        out.push(0); // f32
        out.push(2); // ndim
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&3u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }

        // tensor 2: int8 "b/w" shape (2, 4), channel 2 pruned
        out.extend_from_slice(&(3u16).to_le_bytes());
        out.extend_from_slice(b"b/w");
        out.push(1); // int8
        out.push(2);
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&4u32.to_le_bytes());
        for s in [0.5f32, 1.0, 2.0, 0.25] {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.extend_from_slice(&[1, 1, 0, 1]); // keep mask
        // payload rows=2, kept=3: values
        for v in [10i8, -20, 30, 40, 50, -60] {
            out.push(v as u8);
        }
        out
    }

    #[test]
    fn parses_f32() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        let t = &wf.tensors["a/w"];
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.to_f32(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.stored_bytes(), 24);
    }

    #[test]
    fn parses_int8_with_pruning() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        let t = &wf.tensors["b/w"];
        assert_eq!(t.shape, vec![2, 4]);
        let dense = t.to_f32();
        // row 0: [10*0.5, -20*1.0, 0 (pruned), 30*0.25]
        assert_eq!(dense, vec![5.0, -20.0, 0.0, 7.5, 20.0, 50.0, 0.0, -15.0]);
        // stored: 6 int8 + 4 scales*4 + 4 mask = 26 bytes << 32 f32 bytes
        assert_eq!(t.stored_bytes(), 26);
    }

    #[test]
    fn fp32_view_borrows_int8_view_allocates() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        assert!(
            matches!(wf.tensors["a/w"].to_f32(), Cow::Borrowed(_)),
            "fp32 uploads must not copy the payload"
        );
        assert!(matches!(wf.tensors["b/w"].to_f32(), Cow::Owned(_)));
    }

    #[test]
    fn ordered_fetch_and_missing() {
        let wf = WeightFile::parse(&sample_file()).unwrap();
        let v = wf.to_f32_ordered(&["a/w".into(), "b/w".into()]).unwrap();
        assert_eq!(v.len(), 2);
        assert!(wf.to_f32_ordered(&["nope".into()]).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let mut f = sample_file();
        f[0] = b'X';
        assert!(WeightFile::parse(&f).is_err());
        let f = sample_file();
        assert!(WeightFile::parse(&f[..f.len() - 3]).is_err());
    }
}
