//! The sampler family: one trait, three solvers.
//!
//! [`Sampler`] is the *identity* threaded through the serving stack —
//! request overrides, batch keys, checkpoints, routing — and each
//! identity resolves to a [`Solver`] implementing the actual numerics:
//!
//! * [`DdimSolver`] — the first-order deterministic DDIM update
//!   ([`Ddim::step`], unchanged numerics; bit-parity with the seed
//!   pipeline is pinned by the batching/continuous test suites);
//! * [`Dpm2mSolver`] — a DPM-Solver++(2M)-style second-order multistep
//!   solver.  It carries a bounded history of previous eps predictions
//!   per row; with history it extrapolates the noise estimate across
//!   the last two schedule points, without (the first step of a
//!   schedule, or the final step to t=0) it degrades to the first-order
//!   update — which is exactly the DDIM step, so the degraded path
//!   shares DDIM's arithmetic line for line;
//! * [`DistilledSolver`] — the distilled few-step family (4/8-step):
//!   progressive-distillation students take the halved schedules of a
//!   [`DISTILL_BASE_STEPS`]-step teacher
//!   ([`Ddim::progressive_timesteps_from`]) and are sampled with the
//!   first-order update they were distilled for (Salimans & Ho 2022).
//!   Their step count is *fixed* by the sampler, which is what makes
//!   tight deadlines feasible at admission: the router prices the
//!   request at the distilled count, not the configured default.
//!
//! Solver state (the eps history) is part of a row, not of the batch:
//! it rides [`Checkpoint`]s across preemptions and retries so a resumed
//! row is bit-identical to an uninterrupted one — the history is
//! restored, never recomputed.
//!
//! [`Checkpoint`]: crate::pipeline::continuous::Checkpoint

use crate::scheduler::Ddim;

/// Teacher schedule length of the distilled family: progressive
/// distillation halves a 32-step teacher (32 → 16 → 8 → 4), so both
/// distilled members are exact halving levels of one base schedule.
pub const DISTILL_BASE_STEPS: usize = 32;

/// Sampler identity carried by requests, batch keys and checkpoints.
/// Rows only share CFG dispatches with rows of the same sampler (see
/// [`crate::pipeline::batch::BatchKey`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum Sampler {
    /// first-order DDIM at the requested step count (the seed default)
    #[default]
    Ddim,
    /// DPM-Solver++(2M)-style multistep at the requested step count
    Dpm2m,
    /// distilled 4-step schedule (3 halvings of the 32-step teacher)
    Distilled4,
    /// distilled 8-step schedule (2 halvings of the 32-step teacher)
    Distilled8,
}

impl Sampler {
    pub const ALL: [Sampler; 4] =
        [Sampler::Ddim, Sampler::Dpm2m, Sampler::Distilled4, Sampler::Distilled8];

    /// The config/CLI token (also the metrics label).
    pub fn name(self) -> &'static str {
        self.solver().name()
    }

    /// Parse a config/CLI token.
    pub fn parse(name: &str) -> Option<Sampler> {
        Sampler::ALL.into_iter().find(|s| s.name() == name)
    }

    /// Known tokens, for error messages.
    pub fn names() -> Vec<&'static str> {
        Sampler::ALL.iter().map(|s| s.name()).collect()
    }

    /// The numerics behind this identity.
    pub fn solver(self) -> &'static dyn Solver {
        match self {
            Sampler::Ddim => &DdimSolver,
            Sampler::Dpm2m => &Dpm2mSolver,
            Sampler::Distilled4 => &DISTILLED4,
            Sampler::Distilled8 => &DISTILLED8,
        }
    }

    /// Denoise steps a request asking for `requested` actually runs —
    /// what admission routing must price (distilled members pin it).
    pub fn effective_steps(self, requested: usize) -> usize {
        self.solver().effective_steps(requested)
    }

    /// Bounded per-row history of previous eps predictions the solver
    /// consumes (0 for first-order members).
    pub fn history_len(self) -> usize {
        self.solver().history_len()
    }

    /// Step schedule for a request asking for `requested` steps.
    pub fn schedule(self, ddim: &Ddim, requested: usize) -> Vec<usize> {
        self.solver().schedule(ddim, requested)
    }

    /// One in-place solver update over the latent (see
    /// [`Solver::step`]).
    pub fn step(
        self,
        ddim: &Ddim,
        latent: &mut [f32],
        eps: &[f32],
        history: &[Vec<f32>],
        t: usize,
        t_prev: Option<usize>,
        t_last: Option<usize>,
    ) {
        self.solver().step(ddim, latent, eps, history, t, t_prev, t_last)
    }

    /// Record this step's eps prediction into the row's bounded
    /// history (oldest first).  A zero-history solver records nothing;
    /// at capacity the oldest entry's allocation is recycled so the
    /// steady-state denoise loop stays allocation-free.
    pub fn remember(self, history: &mut Vec<Vec<f32>>, eps: &[f32]) {
        let cap = self.history_len();
        if cap == 0 {
            return;
        }
        if history.len() >= cap {
            let mut old = history.remove(0);
            old.resize(eps.len(), 0.0);
            old.copy_from_slice(eps);
            history.push(old);
        } else {
            history.push(eps.to_vec());
        }
    }
}

/// One member of the sampler family: how to build a row's schedule and
/// advance its latent.  `history` holds the row's previous (guided) eps
/// predictions, oldest first; `t_last` is the timestep the newest
/// history entry was predicted at (`None` at a schedule head).
pub trait Solver {
    fn name(&self) -> &'static str;

    /// Schedule of descending train timesteps for a request asking for
    /// `requested` steps.
    fn schedule(&self, ddim: &Ddim, requested: usize) -> Vec<usize>;

    /// Steps actually run for a `requested` count (== schedule length).
    fn effective_steps(&self, requested: usize) -> usize {
        requested
    }

    /// Previous eps predictions [`Solver::step`] consumes.
    fn history_len(&self) -> usize {
        0
    }

    /// Advance `latent` from `t` to `t_prev` (`None` = the clean
    /// endpoint, alpha-bar 1) given this step's eps prediction.
    fn step(
        &self,
        ddim: &Ddim,
        latent: &mut [f32],
        eps: &[f32],
        history: &[Vec<f32>],
        t: usize,
        t_prev: Option<usize>,
        t_last: Option<usize>,
    );
}

/// The seed pipeline's first-order DDIM — numerics untouched.
pub struct DdimSolver;

impl Solver for DdimSolver {
    fn name(&self) -> &'static str {
        "ddim"
    }

    fn schedule(&self, ddim: &Ddim, requested: usize) -> Vec<usize> {
        ddim.timesteps(requested)
    }

    fn step(
        &self,
        ddim: &Ddim,
        latent: &mut [f32],
        eps: &[f32],
        _history: &[Vec<f32>],
        t: usize,
        t_prev: Option<usize>,
        _t_last: Option<usize>,
    ) {
        ddim.step(latent, eps, t, t_prev);
    }
}

/// DPM-Solver++(2M)-style second-order multistep solver in eps form.
///
/// With one remembered eps prediction the update extrapolates the
/// noise estimate linearly in log-SNR across the last two schedule
/// points (`D = (1 + 1/(2r)) eps_t - 1/(2r) eps_last`, `r` the
/// log-SNR step ratio) and applies the first-order transfer with `D`
/// in place of `eps` — so the history-less path (`D = eps`) *is* the
/// DDIM step.  The final step to the clean endpoint also runs first
/// order: its log-SNR step is unbounded, and lower-order final steps
/// are the standard stabilization for few-step schedules.
pub struct Dpm2mSolver;

impl Solver for Dpm2mSolver {
    fn name(&self) -> &'static str {
        "dpm2m"
    }

    fn schedule(&self, ddim: &Ddim, requested: usize) -> Vec<usize> {
        ddim.timesteps(requested)
    }

    fn history_len(&self) -> usize {
        1
    }

    fn step(
        &self,
        ddim: &Ddim,
        latent: &mut [f32],
        eps: &[f32],
        history: &[Vec<f32>],
        t: usize,
        t_prev: Option<usize>,
        t_last: Option<usize>,
    ) {
        assert_eq!(latent.len(), eps.len());
        let (prev_eps, t_last) = match (history.last(), t_last, t_prev) {
            (Some(p), Some(tl), Some(_)) => (p, tl),
            // schedule head (no history) or final step (unbounded
            // log-SNR step): degrade to first order == DDIM
            _ => return ddim.step(latent, eps, t, t_prev),
        };
        assert_eq!(prev_eps.len(), eps.len());
        let a_t = ddim.alphas_cumprod[t];
        let a_prev = t_prev.map(|p| ddim.alphas_cumprod[p]).unwrap_or(1.0);
        let a_last = ddim.alphas_cumprod[t_last];
        // log-SNR lambda(t) = ln(alpha_t / sigma_t); schedules are
        // strictly descending in t, so both half-steps are positive
        let lam = |a: f64| (a.sqrt() / (1.0 - a).sqrt()).ln();
        let h = lam(a_prev) - lam(a_t);
        let h_last = lam(a_t) - lam(a_last);
        let r = h_last / h;
        let c = 1.0 / (2.0 * r);
        let sqrt_at = a_t.sqrt();
        let sqrt_1mat = (1.0 - a_t).sqrt();
        let sqrt_aprev = a_prev.sqrt();
        let sqrt_1maprev = (1.0 - a_prev).sqrt();
        for (i, (l, &e)) in latent.iter_mut().zip(eps).enumerate() {
            let d = (1.0 + c) * e as f64 - c * prev_eps[i] as f64;
            let x0 = (*l as f64 - sqrt_1mat * d) / sqrt_at;
            *l = (sqrt_aprev * x0 + sqrt_1maprev * d) as f32;
        }
    }
}

/// A distilled few-step student: fixed halved schedule of the
/// [`DISTILL_BASE_STEPS`]-step teacher, sampled with the first-order
/// update it was distilled for.
pub struct DistilledSolver {
    name: &'static str,
    halvings: u32,
    steps: usize,
}

static DISTILLED4: DistilledSolver =
    DistilledSolver { name: "distilled4", halvings: 3, steps: 4 };
static DISTILLED8: DistilledSolver =
    DistilledSolver { name: "distilled8", halvings: 2, steps: 8 };

impl Solver for DistilledSolver {
    fn name(&self) -> &'static str {
        self.name
    }

    fn schedule(&self, ddim: &Ddim, _requested: usize) -> Vec<usize> {
        ddim.progressive_timesteps_from(DISTILL_BASE_STEPS, self.halvings)
            .expect("distilled halving level within the teacher schedule")
    }

    fn effective_steps(&self, _requested: usize) -> usize {
        self.steps
    }

    fn step(
        &self,
        ddim: &Ddim,
        latent: &mut [f32],
        eps: &[f32],
        _history: &[Vec<f32>],
        t: usize,
        t_prev: Option<usize>,
        _t_last: Option<usize>,
    ) {
        ddim.step(latent, eps, t, t_prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerParams;

    fn ddim() -> Ddim {
        Ddim::new(SchedulerParams::default())
    }

    #[test]
    fn names_round_trip() {
        for s in Sampler::ALL {
            assert_eq!(Sampler::parse(s.name()), Some(s));
        }
        assert_eq!(Sampler::parse("euler"), None);
        assert_eq!(Sampler::default(), Sampler::Ddim);
    }

    #[test]
    fn schedules_and_effective_steps() {
        let d = ddim();
        assert_eq!(Sampler::Ddim.schedule(&d, 50).len(), 50);
        assert_eq!(Sampler::Dpm2m.schedule(&d, 50).len(), 50);
        assert_eq!(Sampler::Distilled8.schedule(&d, 50).len(), 8);
        assert_eq!(Sampler::Distilled4.schedule(&d, 50).len(), 4);
        assert_eq!(Sampler::Ddim.effective_steps(50), 50);
        assert_eq!(Sampler::Dpm2m.effective_steps(8), 8);
        assert_eq!(Sampler::Distilled8.effective_steps(50), 8);
        assert_eq!(Sampler::Distilled4.effective_steps(50), 4);
        for s in Sampler::ALL {
            let ts = s.schedule(&d, 50);
            assert_eq!(ts.len(), s.effective_steps(50), "{}", s.name());
            assert!(ts.windows(2).all(|w| w[0] > w[1]), "{}", s.name());
            assert_eq!(*ts.last().unwrap(), 0, "{}", s.name());
        }
    }

    #[test]
    fn distilled_schedules_match_teacher_halvings() {
        // every distilled member IS a progressive_timesteps halving
        // level of the 32-step teacher — the previously dead path
        let d = ddim();
        let teacher = Ddim::new(SchedulerParams {
            num_inference_steps: DISTILL_BASE_STEPS,
            ..SchedulerParams::default()
        });
        assert_eq!(
            Sampler::Distilled8.schedule(&d, 20),
            teacher.progressive_timesteps(2).unwrap()
        );
        assert_eq!(
            Sampler::Distilled4.schedule(&d, 20),
            teacher.progressive_timesteps(3).unwrap()
        );
    }

    #[test]
    fn every_halving_level_of_the_distill_base() {
        // 32 → 16 → 8 → 4 → 2 → 1 → exhausted
        let d = ddim();
        for (h, want) in [(0u32, 32usize), (1, 16), (2, 8), (3, 4), (4, 2), (5, 1)] {
            let ts = d.progressive_timesteps_from(DISTILL_BASE_STEPS, h).unwrap();
            assert_eq!(ts.len(), want, "halvings = {h}");
            assert_eq!(*ts.last().unwrap(), 0, "halvings = {h}");
            assert!(ts.windows(2).all(|w| w[0] > w[1]), "halvings = {h}");
        }
        assert!(d.progressive_timesteps_from(DISTILL_BASE_STEPS, 6).is_none());
        assert!(d.progressive_timesteps_from(DISTILL_BASE_STEPS, 31).is_none());
    }

    #[test]
    fn dpm2m_without_history_is_exactly_ddim() {
        let d = ddim();
        let eps = [0.3f32, -1.2, 2.0];
        let mut a = vec![1.0f32, -2.0, 0.5];
        let mut b = a.clone();
        Sampler::Dpm2m.step(&d, &mut a, &eps, &[], 500, Some(450), None);
        d.step(&mut b, &eps, 500, Some(450));
        assert_eq!(a, b, "history-less 2M must share DDIM's arithmetic");
    }

    #[test]
    fn dpm2m_final_step_is_first_order() {
        let d = ddim();
        let eps = [0.3f32, -1.2, 2.0];
        let hist = vec![vec![0.1f32, 0.2, 0.3]];
        let mut a = vec![1.0f32, -2.0, 0.5];
        let mut b = a.clone();
        Sampler::Dpm2m.step(&d, &mut a, &eps, &hist, 50, None, Some(100));
        d.step(&mut b, &eps, 50, None);
        assert_eq!(a, b, "the final step degrades to first order");
    }

    #[test]
    fn dpm2m_with_constant_eps_matches_ddim() {
        // constant noise estimate: the extrapolation D collapses to
        // eps, so second order equals first order exactly
        let d = ddim();
        let eps = [0.7f32, -0.4];
        let hist = vec![eps.to_vec()];
        let mut a = vec![0.9f32, -1.1];
        let mut b = a.clone();
        Sampler::Dpm2m.step(&d, &mut a, &eps, &hist, 500, Some(450), Some(550));
        d.step(&mut b, &eps, 500, Some(450));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn dpm2m_second_order_matches_reference_formula() {
        let d = ddim();
        let (t_last, t, t_prev) = (550usize, 500usize, 450usize);
        let eps = [0.3f32, -1.2];
        let prev = [0.5f32, -1.0];
        let x = [1.0f32, -2.0];
        let mut got = x.to_vec();
        Sampler::Dpm2m.step(
            &d,
            &mut got,
            &eps,
            &[prev.to_vec()],
            t,
            Some(t_prev),
            Some(t_last),
        );
        let acp = &d.alphas_cumprod;
        let lam = |a: f64| (a.sqrt() / (1.0 - a).sqrt()).ln();
        let h = lam(acp[t_prev]) - lam(acp[t]);
        let h_last = lam(acp[t]) - lam(acp[t_last]);
        let c = h / (2.0 * h_last);
        for i in 0..2 {
            let dd = (1.0 + c) * eps[i] as f64 - c * prev[i] as f64;
            let x0 = (x[i] as f64 - (1.0 - acp[t]).sqrt() * dd) / acp[t].sqrt();
            let want = (acp[t_prev].sqrt() * x0 + (1.0 - acp[t_prev]).sqrt() * dd) as f32;
            assert!((got[i] - want).abs() < 1e-6, "elem {i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn remember_is_bounded_and_recycles() {
        let mut h: Vec<Vec<f32>> = Vec::new();
        Sampler::Ddim.remember(&mut h, &[1.0, 2.0]);
        assert!(h.is_empty(), "zero-history solvers record nothing");
        Sampler::Dpm2m.remember(&mut h, &[1.0, 2.0]);
        assert_eq!(h, vec![vec![1.0, 2.0]]);
        Sampler::Dpm2m.remember(&mut h, &[3.0, 4.0]);
        assert_eq!(h, vec![vec![3.0, 4.0]], "bounded at history_len");
    }

    #[test]
    fn multistep_trajectory_diverges_from_ddim_then_lands_close() {
        // same surrogate UNet (eps := 0.1 * latent), 8 steps: the two
        // solvers must agree on step one (no history), then differ
        let d = ddim();
        let ts = Sampler::Dpm2m.schedule(&d, 8);
        let run = |sampler: Sampler| -> Vec<f32> {
            let mut latent = vec![1.0f32, -0.5, 0.25, 2.0];
            let mut history: Vec<Vec<f32>> = Vec::new();
            for (i, &t) in ts.iter().enumerate() {
                let eps: Vec<f32> = latent.iter().map(|v| 0.1 * v).collect();
                let t_prev = ts.get(i + 1).copied();
                let t_last = if i > 0 { Some(ts[i - 1]) } else { None };
                sampler.step(&d, &mut latent, &eps, &history, t, t_prev, t_last);
                sampler.remember(&mut history, &eps);
            }
            latent
        };
        let a = run(Sampler::Ddim);
        let b = run(Sampler::Dpm2m);
        assert_ne!(a, b, "second order must actually change the trajectory");
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 0.2 * x.abs().max(1.0),
                "solvers should land near each other: {x} vs {y}"
            );
        }
    }
}
