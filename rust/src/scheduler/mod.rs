//! Samplers + distilled step schedules (the Rust mirror of
//! python/compile/scheduler.py; validated against the manifest's golden
//! traces in rust/tests/).
//!
//! The denoise loop lives here: the executor builds a row's schedule
//! through its [`Sampler`], runs the CFG-batched UNet executable per
//! step, applies [`guide`] + [`Sampler::step`].  [`Ddim`] holds the
//! beta/alpha tables and the first-order update every solver shares;
//! the sampler family (first-order DDIM, the DPM-Solver++(2M)-style
//! multistep solver, and the distilled 4/8-step schedules from
//! progressive distillation, Salimans & Ho 2022) lives in [`sampler`].

pub mod sampler;

pub use sampler::{Sampler, Solver, DISTILL_BASE_STEPS};

#[derive(Debug, Clone)]
pub struct SchedulerParams {
    pub num_train_timesteps: usize,
    pub beta_start: f64,
    pub beta_end: f64,
    pub num_inference_steps: usize,
    pub guidance_scale: f64,
}

impl Default for SchedulerParams {
    fn default() -> Self {
        SchedulerParams {
            num_train_timesteps: 1000,
            beta_start: 0.00085,
            beta_end: 0.012,
            num_inference_steps: 20,
            guidance_scale: 7.5,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Ddim {
    pub params: SchedulerParams,
    pub alphas_cumprod: Vec<f64>,
}

impl Ddim {
    /// Scaled-linear beta schedule (the SD default), cumulative alphas.
    pub fn new(params: SchedulerParams) -> Ddim {
        let n = params.num_train_timesteps;
        let (s0, s1) = (params.beta_start.sqrt(), params.beta_end.sqrt());
        let mut acp = Vec::with_capacity(n);
        let mut prod = 1.0f64;
        for i in 0..n {
            let frac = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
            let beta = (s0 + (s1 - s0) * frac).powi(2);
            prod *= 1.0 - beta;
            acp.push(prod);
        }
        Ddim { params, alphas_cumprod: acp }
    }

    /// Load alphas directly (from the manifest) for bit-parity with the
    /// Python build.
    pub fn from_alphas(params: SchedulerParams, alphas_cumprod: Vec<f64>) -> Ddim {
        Ddim { params, alphas_cumprod }
    }

    /// DDIM schedule: exactly `num_steps` evenly spaced timesteps,
    /// descending, ending at 0.  (`t_i = i * T / num_steps` — the
    /// linspace form; the old stride form returned *more* than
    /// `num_steps` entries whenever `T % num_steps != 0`.)
    pub fn timesteps(&self, num_steps: usize) -> Vec<usize> {
        let t = self.params.num_train_timesteps;
        let n = num_steps.clamp(1, t.max(1));
        (0..n).map(|i| i * t / n).rev().collect()
    }

    /// Progressive-distillation schedule: `halvings` halves the count.
    pub fn progressive_timesteps(&self, halvings: u32) -> Option<Vec<usize>> {
        self.progressive_timesteps_from(self.params.num_inference_steps, halvings)
    }

    /// Progressive-distillation schedule from an explicit teacher step
    /// count (the distilled sampler family halves a fixed
    /// [`DISTILL_BASE_STEPS`]-step teacher regardless of the configured
    /// inference count).  `None` once the halvings exhaust the base.
    pub fn progressive_timesteps_from(
        &self,
        base: usize,
        halvings: u32,
    ) -> Option<Vec<usize>> {
        let n = base >> halvings.min(usize::BITS - 1);
        if n == 0 {
            return None;
        }
        Some(self.timesteps(n))
    }

    /// One deterministic (eta = 0) DDIM update, in place over the latent.
    pub fn step(&self, latent: &mut [f32], eps: &[f32], t: usize, t_prev: Option<usize>) {
        assert_eq!(latent.len(), eps.len());
        let a_t = self.alphas_cumprod[t];
        let a_prev = t_prev.map(|p| self.alphas_cumprod[p]).unwrap_or(1.0);
        let sqrt_at = a_t.sqrt();
        let sqrt_1mat = (1.0 - a_t).sqrt();
        let sqrt_aprev = a_prev.sqrt();
        let sqrt_1maprev = (1.0 - a_prev).sqrt();
        for (l, &e) in latent.iter_mut().zip(eps) {
            let x0 = (*l as f64 - sqrt_1mat * e as f64) / sqrt_at;
            *l = (sqrt_aprev * x0 + sqrt_1maprev * e as f64) as f32;
        }
    }
}

/// Classifier-free guidance: uncond + s * (cond - uncond), elementwise.
pub fn guide(eps_uncond: &[f32], eps_cond: &[f32], scale: f64, out: &mut [f32]) {
    assert_eq!(eps_uncond.len(), eps_cond.len());
    assert_eq!(out.len(), eps_cond.len());
    for ((o, &u), &c) in out.iter_mut().zip(eps_uncond).zip(eps_cond) {
        let (u, c) = (u as f64, c as f64);
        *o = (u + scale * (c - u)) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ddim() -> Ddim {
        Ddim::new(SchedulerParams::default())
    }

    #[test]
    fn alphas_monotone_decreasing() {
        let d = ddim();
        assert_eq!(d.alphas_cumprod.len(), 1000);
        for w in d.alphas_cumprod.windows(2) {
            assert!(w[1] < w[0]);
        }
        assert!(d.alphas_cumprod[0] < 1.0 && d.alphas_cumprod[999] > 0.0);
    }

    #[test]
    fn timesteps_shape() {
        let d = ddim();
        let ts = d.timesteps(20);
        assert_eq!(ts.len(), 20);
        assert_eq!(ts[0], 950, "n | T keeps the classic stride schedule");
        assert_eq!(*ts.last().unwrap(), 0);
        assert!(ts.windows(2).all(|w| w[0] > w[1]));
    }

    #[test]
    fn timesteps_exact_count_for_non_divisible_n() {
        // the old stride schedule yielded 14 steps for n = 13
        let d = ddim();
        assert_eq!(d.timesteps(13).len(), 13);
        assert_eq!(d.timesteps(7).len(), 7);
    }

    #[test]
    fn timesteps_property_over_1_to_50() {
        let d = ddim();
        let t = d.params.num_train_timesteps;
        for n in 1..=50 {
            let ts = d.timesteps(n);
            assert_eq!(ts.len(), n, "exactly n steps for n = {n}");
            assert_eq!(*ts.last().unwrap(), 0, "ends at 0 for n = {n}");
            assert!(ts.iter().all(|&x| x < t), "in range for n = {n}");
            assert!(
                ts.windows(2).all(|w| w[0] > w[1]),
                "strictly descending for n = {n}: {ts:?}"
            );
            // evenly spaced: gaps differ by at most 1 (integer division)
            if n > 1 {
                let gaps: Vec<usize> = ts.windows(2).map(|w| w[0] - w[1]).collect();
                let (lo, hi) = (
                    *gaps.iter().min().unwrap(),
                    *gaps.iter().max().unwrap(),
                );
                assert!(hi - lo <= 1, "even spacing for n = {n}: {gaps:?}");
            }
        }
    }

    #[test]
    fn progressive_halving() {
        let d = ddim();
        assert_eq!(d.progressive_timesteps(0).unwrap().len(), 20);
        assert_eq!(d.progressive_timesteps(1).unwrap().len(), 10);
        assert_eq!(d.progressive_timesteps(2).unwrap().len(), 5);
        assert!(d.progressive_timesteps(10).is_none());
    }

    #[test]
    fn zero_eps_final_step_recovers_x0() {
        let d = ddim();
        let t = 100;
        let mut latent = vec![1.0f32, -2.0, 0.5];
        let expect: Vec<f32> = latent
            .iter()
            .map(|&v| (v as f64 / d.alphas_cumprod[t].sqrt()) as f32)
            .collect();
        d.step(&mut latent, &[0.0; 3], t, None);
        for (a, b) in latent.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn pure_noise_invariant() {
        let d = ddim();
        let (t, tp) = (500, 450);
        let eps = [0.3f32, -1.2, 2.0];
        let mut latent: Vec<f32> = eps
            .iter()
            .map(|&e| ((1.0 - d.alphas_cumprod[t]).sqrt() * e as f64) as f32)
            .collect();
        d.step(&mut latent, &eps, t, Some(tp));
        for (l, &e) in latent.iter().zip(&eps) {
            let want = ((1.0 - d.alphas_cumprod[tp]).sqrt() * e as f64) as f32;
            assert!((l - want).abs() < 1e-6);
        }
    }

    #[test]
    fn guidance_endpoints() {
        let u = [1.0f32, 2.0];
        let c = [3.0f32, -1.0];
        let mut out = [0.0f32; 2];
        guide(&u, &c, 1.0, &mut out);
        assert_eq!(out, c);
        guide(&u, &c, 0.0, &mut out);
        assert_eq!(out, u);
        guide(&u, &c, 7.5, &mut out);
        assert!((out[0] - (1.0 + 7.5 * 2.0)).abs() < 1e-6);
    }
}
