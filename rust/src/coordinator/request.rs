//! Request/response types for the serving stack.

use std::time::Duration;

use crate::coordinator::queue::Priority;
use crate::pipeline::{ExecOverrides, StageTimings};
use crate::scheduler::Sampler;

#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: String,
    pub seed: u64,
    /// override the configured step count (distilled schedules)
    pub num_steps: Option<usize>,
    /// override the configured UNet variant ("base" | "mobile")
    pub variant: Option<String>,
    /// override the configured guidance scale
    pub guidance_scale: Option<f64>,
    /// override the configured sampler (solver + schedule family)
    pub sampler: Option<Sampler>,
}

impl GenerateRequest {
    pub fn new(id: u64, prompt: &str, seed: u64) -> GenerateRequest {
        GenerateRequest {
            id,
            prompt: prompt.to_string(),
            seed,
            num_steps: None,
            variant: None,
            guidance_scale: None,
            sampler: None,
        }
    }

    /// The per-request executor overrides this request carries.
    pub fn overrides(&self) -> ExecOverrides {
        ExecOverrides {
            num_steps: self.num_steps,
            variant: self.variant.clone(),
            guidance_scale: self.guidance_scale,
            sampler: self.sampler,
        }
    }
}

/// Scheduling directives attached to a submission (not part of the
/// model inputs): priority class, deadline, plus the per-request
/// execution overrides.
#[derive(Debug, Clone, Default)]
pub struct SubmitOptions {
    pub priority: Priority,
    /// drop the request if it has not started within this budget
    pub deadline: Option<Duration>,
    pub num_steps: Option<usize>,
    pub variant: Option<String>,
    pub guidance_scale: Option<f64>,
    /// sampler token ("ddim" | "dpm2m" | "distilled4" | "distilled8");
    /// validated at admission — an unknown token is a config error.
    /// Admission routing prices the request at the sampler's
    /// *effective* step count, so a distilled8 request is feasible
    /// under deadlines a 50-step DDIM run can never meet.
    pub sampler: Option<String>,
}

impl SubmitOptions {
    pub fn with_priority(priority: Priority) -> SubmitOptions {
        SubmitOptions { priority, ..Default::default() }
    }
}

#[derive(Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub image: Vec<f32>,
    pub image_size: usize,
    pub latent: Vec<f32>,
    pub timings: StageTimings,
    pub peak_memory: usize,
    /// wall-clock the request waited in the queue
    pub queue_s: f64,
    /// pool worker that executed the request
    pub worker_id: usize,
    /// device class of that worker ("default" in homogeneous pools,
    /// the planner-registry name in `--fleet` pools)
    pub device_class: String,
    /// plan-predicted service time the router admitted this request
    /// under; `None` when no planner routed it
    pub predicted_s: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = GenerateRequest::new(1, "hi", 42);
        assert_eq!(r.id, 1);
        assert!(r.num_steps.is_none());
        assert!(r.variant.is_none());
        let ov = r.overrides();
        assert!(ov.num_steps.is_none() && ov.guidance_scale.is_none());
    }

    #[test]
    fn overrides_flow_through() {
        let mut r = GenerateRequest::new(2, "hi", 1);
        r.num_steps = Some(4);
        r.variant = Some("base".into());
        r.sampler = Some(Sampler::Dpm2m);
        let ov = r.overrides();
        assert_eq!(ov.num_steps, Some(4));
        assert_eq!(ov.variant.as_deref(), Some("base"));
        assert_eq!(ov.sampler, Some(Sampler::Dpm2m));
    }

    #[test]
    fn submit_options_default_to_normal_priority() {
        let o = SubmitOptions::default();
        assert_eq!(o.priority, Priority::Normal);
        assert!(o.deadline.is_none());
        let h = SubmitOptions::with_priority(Priority::High);
        assert_eq!(h.priority, Priority::High);
    }
}
