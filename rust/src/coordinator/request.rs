//! Request/response types for the serving loop.

use crate::pipeline::StageTimings;

#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: String,
    pub seed: u64,
    /// override the configured step count (distilled schedules)
    pub num_steps: Option<usize>,
}

impl GenerateRequest {
    pub fn new(id: u64, prompt: &str, seed: u64) -> GenerateRequest {
        GenerateRequest { id, prompt: prompt.to_string(), seed, num_steps: None }
    }
}

pub struct GenerateResponse {
    pub id: u64,
    pub image: Vec<f32>,
    pub image_size: usize,
    pub latent: Vec<f32>,
    pub timings: StageTimings,
    pub peak_memory: usize,
    /// wall-clock the request waited in the queue
    pub queue_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = GenerateRequest::new(1, "hi", 42);
        assert_eq!(r.id, 1);
        assert!(r.num_steps.is_none());
    }
}
