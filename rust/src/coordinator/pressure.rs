//! Per-device-class memory-pressure governor: the learning half of
//! OOM recovery (DESIGN.md "Memory pressure & degradation ladder").
//!
//! The shipped memory budget is a spec-sheet number; the headroom a
//! device actually grants varies by phone and OS version.  Workers
//! report every `Error::Oom` here; the governor climbs a degradation
//! ladder for the class and records a *learned* `effective_budget`
//! that admission consults instead of the shipped figure:
//!
//! * **on_oom** — ladder level rises one rung (capped at
//!   [`MAX_LEVEL`]) and the effective budget shrinks geometrically
//!   (`shrink` per OOM, floored at `floor * shipped`).  The worker
//!   translates the rung into a concrete degradation — smaller batch
//!   seat cap, evicted warm tier and residency, W8A8 under a reduced
//!   ledger budget — before retrying.  OOM work is *never* retried on
//!   an unchanged plan (`Error::is_oom`).
//! * **on_success** — breaker-style hysteresis: after `probe_streak`
//!   consecutive OOM-free completions the ladder steps back down one
//!   rung and the budget re-probes upward, restoring the shipped
//!   budget when the ladder reaches the ground.
//!
//! Admission consumes the learned budget through [`admits_peak`]:
//! `FleetRouter` filters out classes whose planned `peak_memory` no
//! longer fits, so requests reroute to classes with real headroom
//! instead of being fed to an exhausted allocator.
//!
//! [`admits_peak`]: PressureGovernor::admits_peak

use std::sync::Mutex;

/// Deepest ladder rung.  Rungs map to worker-side degradations:
/// 1 = halve the batch seat cap, 2 = also shed warm/idle residency,
/// 3 = also force W8A8 and re-plan under the learned budget.
pub const MAX_LEVEL: u8 = 3;

/// Governor tuning.  Defaults shrink aggressively (OOM is expensive)
/// and re-probe conservatively (an unwarranted probe re-OOMs).
#[derive(Debug, Clone, Copy)]
pub struct PressureOptions {
    /// Multiplier applied to the effective budget per OOM (in (0,1)).
    pub shrink: f64,
    /// The effective budget never drops below `floor * shipped`.
    pub floor: f64,
    /// Consecutive OOM-free completions before stepping one rung back
    /// down and re-probing the budget upward.
    pub probe_streak: u64,
}

impl Default for PressureOptions {
    fn default() -> PressureOptions {
        PressureOptions { shrink: 0.8, floor: 0.25, probe_streak: 24 }
    }
}

#[derive(Debug)]
struct ClassPressure {
    /// The budget the deployment shipped with (`usize::MAX` = none).
    shipped: usize,
    /// The learned budget capping admission; starts at `shipped`.
    effective: usize,
    /// Current degradation-ladder rung (0 = undegraded).
    level: u8,
    /// OOMs observed against the class.
    ooms: u64,
    /// Degraded retries issued after those OOMs.
    degraded: u64,
    /// Consecutive OOM-free completions since the last OOM or probe.
    streak: u64,
    /// Upward re-probes taken.
    probes: u64,
}

/// One ladder per device class, shared between the pool's workers
/// (producers of OOM/success events) and the server's admission path
/// (consumer of the learned budgets).
#[derive(Debug)]
pub struct PressureGovernor {
    classes: Vec<Mutex<ClassPressure>>,
    opts: PressureOptions,
}

impl PressureGovernor {
    /// One class per entry of `shipped` (the per-class planned memory
    /// budget in bytes; `usize::MAX` for unbudgeted deployments —
    /// the ladder and counters still work, only the byte figure stays
    /// unbounded).
    pub fn new(shipped: Vec<usize>, opts: PressureOptions) -> PressureGovernor {
        let shipped = if shipped.is_empty() { vec![usize::MAX] } else { shipped };
        PressureGovernor {
            classes: shipped
                .into_iter()
                .map(|s| {
                    Mutex::new(ClassPressure {
                        shipped: s,
                        effective: s,
                        level: 0,
                        ooms: 0,
                        degraded: 0,
                        streak: 0,
                        probes: 0,
                    })
                })
                .collect(),
            opts,
        }
    }

    /// One observed `Error::Oom` against the class: climb a rung,
    /// shrink the learned budget, reset the probe streak.  Returns the
    /// rung the worker should degrade to before retrying.
    pub fn on_oom(&self, class: usize) -> u8 {
        let Some(m) = self.classes.get(class) else { return 1 };
        let mut s = m.lock().unwrap();
        s.ooms += 1;
        s.streak = 0;
        s.level = (s.level + 1).min(MAX_LEVEL);
        if s.shipped != usize::MAX {
            let floor = (s.shipped as f64 * self.opts.floor) as usize;
            let shrunk = (s.effective as f64 * self.opts.shrink) as usize;
            s.effective = shrunk.max(floor).max(1);
        }
        s.level
    }

    /// One OOM-free completion.  After `probe_streak` of them the
    /// ladder steps down a rung and the budget re-probes upward;
    /// reaching the ground restores the shipped budget in full.
    pub fn on_success(&self, class: usize) {
        let Some(m) = self.classes.get(class) else { return };
        let mut s = m.lock().unwrap();
        if s.level == 0 {
            return;
        }
        s.streak += 1;
        if s.streak < self.opts.probe_streak {
            return;
        }
        s.streak = 0;
        s.level -= 1;
        s.probes += 1;
        if s.shipped != usize::MAX {
            s.effective = if s.level == 0 {
                s.shipped
            } else {
                ((s.effective as f64 / self.opts.shrink) as usize).min(s.shipped)
            };
        }
    }

    /// A degraded retry was issued for the class (metrics only).
    pub fn record_degraded(&self, class: usize) {
        if let Some(m) = self.classes.get(class) {
            m.lock().unwrap().degraded += 1;
        }
    }

    /// Whether a plan with the given `peak_memory` fits the class's
    /// *learned* headroom.  Pure — consulting it never transitions
    /// state, so admission can use it as a filter predicate.
    pub fn admits_peak(&self, class: usize, peak: usize) -> bool {
        self.effective_budget(class) >= peak
    }

    /// The learned budget capping admission for the class.
    pub fn effective_budget(&self, class: usize) -> usize {
        self.classes
            .get(class)
            .map_or(usize::MAX, |m| m.lock().unwrap().effective)
    }

    /// The budget the deployment shipped with.
    pub fn shipped_budget(&self, class: usize) -> usize {
        self.classes
            .get(class)
            .map_or(usize::MAX, |m| m.lock().unwrap().shipped)
    }

    /// Current degradation-ladder rung (0 = undegraded).
    pub fn level(&self, class: usize) -> u8 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().level)
    }

    pub fn ooms(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().ooms)
    }

    pub fn degraded(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().degraded)
    }

    pub fn probes(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().probes)
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Any class has seen memory pressure (is degraded now, or ever
    /// OOM'd) — the report-line trigger.
    pub fn any_pressure(&self) -> bool {
        self.classes.iter().any(|m| {
            let s = m.lock().unwrap();
            s.level > 0 || s.ooms > 0
        })
    }

    /// One report line, classes labelled by `names` (index order).
    pub fn status_line(&self, names: &[String]) -> String {
        let cells: Vec<String> = self
            .classes
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let s = m.lock().unwrap();
                let name = names.get(i).map(|n| n.as_str()).unwrap_or("?");
                format!(
                    "{name}=L{} ({} ooms, {} degraded, budget {}/{})",
                    s.level,
                    s.ooms,
                    s.degraded,
                    fmt_budget(s.effective),
                    fmt_budget(s.shipped),
                )
            })
            .collect();
        format!("pressure: {}\n", cells.join(", "))
    }
}

fn fmt_budget(bytes: usize) -> String {
    if bytes == usize::MAX {
        "unbounded".to_string()
    } else {
        format!("{:.1}MB", bytes as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gov(shipped: usize, probe_streak: u64) -> PressureGovernor {
        PressureGovernor::new(
            vec![shipped],
            PressureOptions { probe_streak, ..PressureOptions::default() },
        )
    }

    #[test]
    fn ooms_climb_the_ladder_and_shrink_the_learned_budget() {
        let g = gov(1_000_000, 4);
        assert_eq!(g.effective_budget(0), 1_000_000);
        assert!(g.admits_peak(0, 1_000_000));
        assert!(!g.any_pressure());
        assert_eq!(g.on_oom(0), 1);
        assert_eq!(g.on_oom(0), 2);
        assert_eq!(g.on_oom(0), 3);
        assert_eq!(g.on_oom(0), 3, "level saturates at MAX_LEVEL");
        assert_eq!(g.ooms(0), 4);
        assert!(g.any_pressure());
        let eff = g.effective_budget(0);
        assert!(eff < 1_000_000, "budget shrank: {eff}");
        assert!(!g.admits_peak(0, 1_000_000), "shipped peak no longer admitted");
        assert!(g.admits_peak(0, eff), "the learned budget itself admits");
        assert_eq!(g.shipped_budget(0), 1_000_000, "shipped figure untouched");
    }

    #[test]
    fn budget_converges_to_the_floor_not_zero() {
        let g = gov(1_000_000, 4);
        for _ in 0..64 {
            g.on_oom(0);
        }
        assert_eq!(
            g.effective_budget(0),
            250_000,
            "floored at floor * shipped"
        );
    }

    #[test]
    fn hysteresis_reprobes_upward_and_restores_shipped_at_ground() {
        let g = gov(1_000_000, 3);
        g.on_oom(0);
        g.on_oom(0);
        let degraded = g.effective_budget(0);
        assert_eq!(g.level(0), 2);
        // two successes: not enough for a probe
        g.on_success(0);
        g.on_success(0);
        assert_eq!(g.level(0), 2);
        assert_eq!(g.effective_budget(0), degraded);
        // third completes the streak: one rung down, budget up
        g.on_success(0);
        assert_eq!(g.level(0), 1);
        assert!(g.effective_budget(0) > degraded);
        assert_eq!(g.probes(0), 1);
        // an OOM mid-streak resets progress
        g.on_success(0);
        g.on_oom(0);
        assert_eq!(g.level(0), 2);
        for _ in 0..6 {
            g.on_success(0);
        }
        assert_eq!(g.level(0), 0, "fully recovered");
        assert_eq!(
            g.effective_budget(0),
            1_000_000,
            "ground rung restores the shipped budget"
        );
        // successes at ground level are free: no underflow, no probes
        g.on_success(0);
        assert_eq!(g.level(0), 0);
    }

    #[test]
    fn unbudgeted_deployments_keep_ladder_and_counters_only() {
        let g = gov(usize::MAX, 2);
        assert_eq!(g.on_oom(0), 1);
        assert_eq!(g.effective_budget(0), usize::MAX, "no byte figure to shrink");
        assert!(g.admits_peak(0, usize::MAX));
        g.record_degraded(0);
        assert_eq!(g.degraded(0), 1);
        let line = g.status_line(&["cpu".to_string()]);
        assert!(line.contains("cpu=L1"), "{line}");
        assert!(line.contains("1 ooms, 1 degraded"), "{line}");
        assert!(line.contains("unbounded/unbounded"), "{line}");
    }

    #[test]
    fn out_of_range_classes_are_ignored_not_panics() {
        let g = gov(1000, 2);
        assert_eq!(g.on_oom(9), 1, "unknown class degrades conservatively");
        g.on_success(9);
        g.record_degraded(9);
        assert_eq!(g.ooms(9), 0);
        assert!(g.admits_peak(9, usize::MAX), "unknown classes admit");
        assert_eq!(g.num_classes(), 1);
    }

    #[test]
    fn status_line_reports_learned_vs_shipped_budget() {
        let g = PressureGovernor::new(
            vec![2_000_000, 1_000_000],
            PressureOptions::default(),
        );
        g.on_oom(1);
        let line = g.status_line(&["fast".to_string(), "slow".to_string()]);
        assert!(line.starts_with("pressure: "), "{line}");
        assert!(line.contains("fast=L0 (0 ooms, 0 degraded, budget 2.0MB/2.0MB)"), "{line}");
        assert!(line.contains("slow=L1"), "{line}");
        assert!(line.contains("0.8MB/1.0MB"), "{line}");
    }
}
