//! The admission queue in front of the worker pool: bounded depth,
//! priority classes, deadline awareness.
//!
//! Policy:
//!
//! * **Admission control** — `push` never blocks; a full queue rejects
//!   the request immediately ([`AdmissionError::Full`]) so callers can
//!   shed load instead of building unbounded backlog.
//! * **Priority classes** — [`Priority::High`] drains before
//!   [`Priority::Normal`] before [`Priority::Low`].
//! * **Within a class** — earliest *effective* deadline first.  A
//!   request without a deadline is scheduled as if it were due
//!   [`FALLBACK_DEADLINE`] after submission, so deadline-less
//!   requests keep FIFO order among themselves, age ahead of
//!   later-arriving lax-deadline traffic, and can never be starved by
//!   a sustained stream of deadline-bearing submissions.
//!
//! The queue is generic over the job payload so scheduling policy is
//! testable without a PJRT device or a real executor.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduling deadline assumed for requests submitted without one:
/// within its priority class a deadline-less job competes as if due
/// this long after submission (EDF with aging — prevents starvation
/// by deadline-bearing traffic while preserving FIFO among
/// deadline-less jobs).
pub const FALLBACK_DEADLINE: Duration = Duration::from_secs(60);

/// Scheduling class, drained in declaration order.
///
/// NOTE: `Ord` follows *drain order*, not urgency magnitude:
/// `High < Normal < Low`, so the queue's `min_by` pop picks `High`
/// first.  Don't use `max()`/ascending sorts expecting "most urgent
/// last" — compare against the variants explicitly instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Parse a CLI/JSON priority name.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// Queue at capacity; caller should shed or retry later.
    Full { capacity: usize },
    /// Queue shut down; no further work is accepted.
    Closed,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::Full { capacity } => {
                write!(f, "queue full (capacity {capacity})")
            }
            AdmissionError::Closed => write!(f, "queue closed"),
        }
    }
}

/// Snapshot of the job the policy would run next (see
/// [`JobQueue::peek_where`]) — enough for a worker to judge deadline
/// feasibility without dequeuing anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeekInfo {
    pub priority: Priority,
    pub deadline: Option<Instant>,
    pub enqueued: Instant,
}

/// A scheduled unit of work.
#[derive(Debug)]
pub struct Job<T> {
    pub priority: Priority,
    /// absolute wall-clock deadline; expired jobs are failed by the pool
    pub deadline: Option<Instant>,
    pub enqueued: Instant,
    /// submission order within the queue (FIFO tiebreak)
    seq: u64,
    pub item: T,
}

impl<T> Job<T> {
    /// The deadline this job competes with inside its priority class.
    fn effective_deadline(&self) -> Instant {
        self.deadline.unwrap_or(self.enqueued + FALLBACK_DEADLINE)
    }
}

struct Inner<T> {
    jobs: VecDeque<Job<T>>,
    next_seq: u64,
    closed: bool,
    /// high-water mark of the queue depth (metrics)
    max_depth: usize,
}

/// Bounded, priority/deadline-aware MPMC job queue.
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    available: Condvar,
    capacity: usize,
}

impl<T> JobQueue<T> {
    pub fn new(capacity: usize) -> JobQueue<T> {
        JobQueue {
            inner: Mutex::new(Inner {
                jobs: VecDeque::new(),
                next_seq: 0,
                closed: false,
                max_depth: 0,
            }),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit a job or reject it without blocking.
    pub fn push(
        &self,
        item: T,
        priority: Priority,
        deadline: Option<Instant>,
    ) -> Result<(), AdmissionError> {
        self.try_push(item, priority, deadline).map_err(|(_, e)| e)
    }

    /// [`Self::push`] that hands the item back on rejection — requeue
    /// paths (preemption checkpoints) must be able to fail the caller
    /// explicitly instead of silently dropping its reply channel.
    pub fn try_push(
        &self,
        item: T,
        priority: Priority,
        deadline: Option<Instant>,
    ) -> Result<(), (T, AdmissionError)> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err((item, AdmissionError::Closed));
        }
        if inner.jobs.len() >= self.capacity {
            return Err((item, AdmissionError::Full { capacity: self.capacity }));
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.jobs.push_back(Job {
            priority,
            deadline,
            enqueued: Instant::now(),
            seq,
            item,
        });
        let depth = inner.jobs.len();
        inner.max_depth = inner.max_depth.max(depth);
        drop(inner);
        // heterogeneous pools pop with per-class filters: wake every
        // waiter so the job's own class cannot miss it behind a
        // notify_one that landed on the wrong class
        self.available.notify_all();
        Ok(())
    }

    /// Scheduling order between two jobs: highest priority class, then
    /// earliest effective deadline, then FIFO.
    fn policy_cmp(a: &Job<T>, b: &Job<T>) -> std::cmp::Ordering {
        a.priority
            .cmp(&b.priority)
            .then_with(|| a.effective_deadline().cmp(&b.effective_deadline()))
            .then_with(|| a.seq.cmp(&b.seq))
    }

    /// Index of the job the policy would run next among those passing
    /// `eligible`.  `None` when no eligible job is queued.
    fn next_index(inner: &Inner<T>, eligible: impl Fn(&T) -> bool) -> Option<usize> {
        inner
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| eligible(&j.item))
            .min_by(|(_, a), (_, b)| Self::policy_cmp(a, b))
            .map(|(i, _)| i)
    }

    /// Block until a job is available; `None` once closed and drained.
    pub fn pop(&self) -> Option<Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(i) = Self::next_index(&inner, |_| true) {
                return inner.jobs.remove(i);
            }
            if inner.closed {
                return None;
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    /// Batch-aware blocking pop: take the job the policy would run
    /// next, then up to `max_batch - 1` further queued jobs whose
    /// `key` matches it, in policy order — the worker dispatches them
    /// as one micro-batch.  Never waits for a batch to fill: whatever
    /// is compatible *now* rides along, a lone job runs solo.  The
    /// returned jobs are in submission (FIFO) order.  `None` once
    /// closed and drained.
    pub fn pop_batch<K: PartialEq>(
        &self,
        max_batch: usize,
        key: impl Fn(&T) -> K,
    ) -> Option<Vec<Job<T>>> {
        self.pop_batch_where(max_batch, |_| true, key)
    }

    /// [`Self::pop_batch`] restricted to jobs passing `eligible` — a
    /// heterogeneous pool's workers only drain jobs routed to their own
    /// device class.  Jobs the filter rejects are invisible to this
    /// caller: they neither head a batch nor block one.  `None` once
    /// the queue is closed and drained *of eligible jobs* (leftovers
    /// belong to other classes' workers).
    pub fn pop_batch_where<K: PartialEq>(
        &self,
        max_batch: usize,
        eligible: impl Fn(&T) -> bool,
        key: impl Fn(&T) -> K,
    ) -> Option<Vec<Job<T>>> {
        let cap = max_batch.max(1);
        let mut inner = self.inner.lock().unwrap();
        loop {
            let batch = Self::take_batch(&mut inner, cap, &eligible, &key, None);
            if !batch.is_empty() {
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    /// [`Self::pop_batch_where`] that waits at most `wait` for an
    /// eligible job: `Some(batch)` on success, `Some(vec![])` on
    /// timeout (queue still open — the caller re-evaluates its
    /// eligibility filter and loops), `None` once closed and drained
    /// of eligible jobs.  Workers whose eligibility depends on *time*
    /// (retry-backoff `not_before` gates) use this: a job can become
    /// eligible without any push to wake the condvar.
    pub fn pop_batch_where_timeout<K: PartialEq>(
        &self,
        max_batch: usize,
        eligible: impl Fn(&T) -> bool,
        key: impl Fn(&T) -> K,
        wait: Duration,
    ) -> Option<Vec<Job<T>>> {
        let cap = max_batch.max(1);
        let mut inner = self.inner.lock().unwrap();
        loop {
            let batch = Self::take_batch(&mut inner, cap, &eligible, &key, None);
            if !batch.is_empty() {
                return Some(batch);
            }
            if inner.closed {
                return None;
            }
            let (guard, timeout) = self.available.wait_timeout(inner, wait).unwrap();
            inner = guard;
            if timeout.timed_out() {
                let batch = Self::take_batch(&mut inner, cap, &eligible, &key, None);
                if !batch.is_empty() {
                    return Some(batch);
                }
                return if inner.closed { None } else { Some(Vec::new()) };
            }
        }
    }

    /// Non-blocking [`Self::pop_batch_where`] for mid-flight joins: the
    /// continuous-batching worker polls between denoise steps for up to
    /// `max_batch` eligible jobs compatible with the *running* batch.
    /// When `running_key` is `Some`, the selection is pinned to that
    /// key — only matching jobs are taken, regardless of what heads the
    /// policy order (an incompatible policy head stays queued for a
    /// free worker; it never forces the in-flight batch to drain).
    /// When `None`, the policy head picks the key as in
    /// [`Self::pop_batch_where`].  Returns an empty vec instead of
    /// waiting.
    pub fn try_pop_batch_where<K: PartialEq>(
        &self,
        max_batch: usize,
        eligible: impl Fn(&T) -> bool,
        key: impl Fn(&T) -> K,
        running_key: Option<&K>,
    ) -> Vec<Job<T>> {
        let cap = max_batch.max(1);
        let mut inner = self.inner.lock().unwrap();
        Self::take_batch(&mut inner, cap, &eligible, &key, running_key)
    }

    /// Selection shared by the blocking and non-blocking batch pops:
    /// take up to `cap` eligible jobs matching `pinned` (or, when
    /// `pinned` is `None`, matching the policy head's key), in policy
    /// order, returned in FIFO order.  Empty when nothing matches.
    fn take_batch<K: PartialEq>(
        inner: &mut Inner<T>,
        cap: usize,
        eligible: &impl Fn(&T) -> bool,
        key: &impl Fn(&T) -> K,
        pinned: Option<&K>,
    ) -> Vec<Job<T>> {
        // cap 1 without a pin (the default config) keeps the
        // allocation-free single-pop scan; only real batching pays for
        // the sort
        if cap == 1 && pinned.is_none() {
            if let Some(i) = Self::next_index(inner, eligible) {
                return inner.jobs.remove(i).into_iter().collect();
            }
            return Vec::new();
        }
        let mut order: Vec<usize> = (0..inner.jobs.len())
            .filter(|&i| eligible(&inner.jobs[i].item))
            .collect();
        if order.is_empty() {
            return Vec::new();
        }
        order.sort_by(|&a, &b| Self::policy_cmp(&inner.jobs[a], &inner.jobs[b]));
        let head_owned;
        let head_key: &K = match pinned {
            Some(k) => k,
            None => {
                head_owned = key(&inner.jobs[order[0]].item);
                &head_owned
            }
        };
        let mut picked: Vec<usize> = Vec::with_capacity(cap);
        for &i in &order {
            if picked.len() >= cap {
                break;
            }
            if key(&inner.jobs[i].item) == *head_key {
                picked.push(i);
            }
        }
        // remove back-to-front so indices stay valid
        picked.sort_unstable();
        let mut batch = Vec::with_capacity(picked.len());
        for i in picked.into_iter().rev() {
            if let Some(j) = inner.jobs.remove(i) {
                batch.push(j);
            }
        }
        batch.reverse();
        batch
    }

    /// Scheduling snapshot of the job the policy would run next among
    /// those passing `eligible`, without removing it — the continuous
    /// worker uses this between steps to decide whether the queue head
    /// needs a slot preempted to meet its deadline.
    pub fn peek_where(&self, eligible: impl Fn(&T) -> bool) -> Option<PeekInfo> {
        let inner = self.inner.lock().unwrap();
        Self::next_index(&inner, eligible).map(|i| {
            let j = &inner.jobs[i];
            PeekInfo { priority: j.priority, deadline: j.deadline, enqueued: j.enqueued }
        })
    }

    /// Non-blocking pop (tests, drain-on-shutdown).
    pub fn try_pop(&self) -> Option<Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        Self::next_index(&inner, |_| true).and_then(|i| inner.jobs.remove(i))
    }

    /// Current number of queued (not yet running) jobs.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    /// Highest queue depth observed since construction.
    pub fn max_depth(&self) -> usize {
        self.inner.lock().unwrap().max_depth
    }

    /// Stop admitting work and wake all waiting workers; queued jobs
    /// still drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_within_a_priority_class() {
        let q: JobQueue<u32> = JobQueue::new(8);
        for i in 0..5 {
            q.push(i, Priority::Normal, None).unwrap();
        }
        let order: Vec<u32> = (0..5).map(|_| q.try_pop().unwrap().item).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn priority_classes_drain_in_order() {
        let q: JobQueue<&'static str> = JobQueue::new(8);
        q.push("low", Priority::Low, None).unwrap();
        q.push("normal-1", Priority::Normal, None).unwrap();
        q.push("high", Priority::High, None).unwrap();
        q.push("normal-2", Priority::Normal, None).unwrap();
        let order: Vec<&str> = (0..4).map(|_| q.try_pop().unwrap().item).collect();
        assert_eq!(order, vec!["high", "normal-1", "normal-2", "low"]);
    }

    #[test]
    fn earlier_effective_deadline_wins_within_a_class() {
        let q: JobQueue<&'static str> = JobQueue::new(8);
        let now = Instant::now();
        // effective deadlines: late = now+600s, no-deadline = enqueue
        // time + FALLBACK_DEADLINE (60s), soon = now+1s
        q.push("late", Priority::Normal, Some(now + Duration::from_secs(600)))
            .unwrap();
        q.push("no-deadline", Priority::Normal, None).unwrap();
        q.push("soon", Priority::Normal, Some(now + Duration::from_secs(1)))
            .unwrap();
        assert_eq!(q.try_pop().unwrap().item, "soon");
        assert_eq!(q.try_pop().unwrap().item, "no-deadline");
        assert_eq!(q.try_pop().unwrap().item, "late");
    }

    #[test]
    fn deadline_traffic_cannot_starve_deadline_less_jobs() {
        let q: JobQueue<u32> = JobQueue::new(64);
        let now = Instant::now();
        q.push(0, Priority::Normal, None).unwrap();
        // a sustained stream of lax-deadline submissions arriving later
        for i in 1..=10 {
            q.push(i, Priority::Normal, Some(now + Duration::from_secs(600)))
                .unwrap();
        }
        // the deadline-less job ages ahead of all of them
        assert_eq!(q.try_pop().unwrap().item, 0);
    }

    #[test]
    fn admission_rejects_when_full() {
        let q: JobQueue<u32> = JobQueue::new(2);
        q.push(1, Priority::Normal, None).unwrap();
        q.push(2, Priority::Normal, None).unwrap();
        let e = q.push(3, Priority::High, None).unwrap_err();
        assert_eq!(e, AdmissionError::Full { capacity: 2 });
        // draining makes room again
        q.try_pop().unwrap();
        q.push(3, Priority::High, None).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
    }

    #[test]
    fn close_rejects_pushes_but_drains() {
        let q: JobQueue<u32> = JobQueue::new(4);
        q.push(1, Priority::Normal, None).unwrap();
        q.close();
        assert_eq!(q.push(2, Priority::Normal, None).unwrap_err(), AdmissionError::Closed);
        assert_eq!(q.pop().unwrap().item, 1);
        assert!(q.pop().is_none(), "closed and drained");
    }

    #[test]
    fn blocking_pop_wakes_on_push() {
        use std::sync::Arc;
        let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().map(|j| j.item));
        std::thread::sleep(Duration::from_millis(20));
        q.push(42, Priority::Normal, None).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn pop_batch_takes_only_compatible_jobs_in_policy_order() {
        // key = the job's parity; head decides the batch key
        let q: JobQueue<u32> = JobQueue::new(16);
        for v in [2u32, 3, 4, 5, 6] {
            q.push(v, Priority::Normal, None).unwrap();
        }
        let batch = q.pop_batch(3, |v| v % 2);
        let items: Vec<u32> = batch.unwrap().into_iter().map(|j| j.item).collect();
        // head is 2 (FIFO); evens ride along up to the cap of 3
        assert_eq!(items, vec![2, 4, 6]);
        // odds remain, FIFO
        let batch = q.pop_batch(3, |v| v % 2).unwrap();
        let items: Vec<u32> = batch.into_iter().map(|j| j.item).collect();
        assert_eq!(items, vec![3, 5]);
    }

    #[test]
    fn pop_batch_respects_priority_for_the_head() {
        let q: JobQueue<(u32, &'static str)> = JobQueue::new(16);
        q.push((1, "a"), Priority::Normal, None).unwrap();
        q.push((2, "b"), Priority::High, None).unwrap();
        q.push((3, "b"), Priority::Normal, None).unwrap();
        // head = the High job; key "b" pulls in job 3 but not job 1
        let items: Vec<u32> = q
            .pop_batch(4, |v| v.1)
            .unwrap()
            .into_iter()
            .map(|j| j.item.0)
            .collect();
        assert_eq!(items, vec![2, 3]);
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn pop_batch_of_one_behaves_like_pop() {
        let q: JobQueue<u32> = JobQueue::new(4);
        q.push(7, Priority::Normal, None).unwrap();
        q.push(8, Priority::Normal, None).unwrap();
        let b = q.pop_batch(1, |_| ()).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].item, 7);
        q.close();
        assert_eq!(q.pop_batch(1, |_| ()).unwrap()[0].item, 8);
        assert!(q.pop_batch(4, |_| ()).is_none(), "closed and drained");
    }

    #[test]
    fn pop_batch_where_sees_only_eligible_jobs() {
        // item = (class, variant); a class-1 worker must not steal or
        // be blocked by class-0 jobs, even higher-priority ones
        let q: JobQueue<(usize, u8)> = JobQueue::new(16);
        q.push((0, 7), Priority::High, None).unwrap();
        q.push((1, 7), Priority::Normal, None).unwrap();
        q.push((0, 7), Priority::Normal, None).unwrap();
        q.push((1, 7), Priority::Low, None).unwrap();

        let batch = q.pop_batch_where(4, |it| it.0 == 1, |it| it.1).unwrap();
        let classes: Vec<usize> = batch.iter().map(|j| j.item.0).collect();
        assert_eq!(classes, vec![1, 1], "only class-1 jobs drained");
        assert_eq!(q.depth(), 2, "class-0 jobs untouched");

        // cap-1 filtered pop takes the High class-0 job first
        let solo = q.pop_batch_where(1, |it| it.0 == 0, |it| it.1).unwrap();
        assert_eq!(solo.len(), 1);
        assert_eq!(solo[0].priority, Priority::High);

        // closed + drained-of-eligible returns None while other
        // classes' jobs remain
        q.close();
        assert!(q.pop_batch_where(4, |it| it.0 == 1, |it| it.1).is_none());
        assert_eq!(q.depth(), 1, "the class-0 job is still there");
        assert!(q.pop_batch_where(4, |it| it.0 == 0, |it| it.1).is_some());
    }

    #[test]
    fn try_pop_batch_where_pins_to_the_running_key() {
        // item = (class, variant); an in-flight batch on variant 7
        // polls for joiners: the higher-priority variant-9 head must
        // neither be taken nor block the variant-7 jobs behind it
        let q: JobQueue<(usize, u8)> = JobQueue::new(16);
        q.push((0, 9), Priority::High, None).unwrap();
        q.push((0, 7), Priority::Normal, None).unwrap();
        q.push((0, 7), Priority::Normal, None).unwrap();
        q.push((1, 7), Priority::Normal, None).unwrap();

        let joins = q.try_pop_batch_where(4, |it| it.0 == 0, |it| it.1, Some(&7));
        let variants: Vec<u8> = joins.iter().map(|j| j.item.1).collect();
        assert_eq!(variants, vec![7, 7], "only compatible class-0 jobs join");
        assert_eq!(q.depth(), 2, "the variant-9 head and class-1 job stay queued");

        // nothing compatible left: empty, never blocks
        assert!(q.try_pop_batch_where(4, |it| it.0 == 0, |it| it.1, Some(&7)).is_empty());

        // without a pin it behaves like pop_batch_where's selection
        let head = q.try_pop_batch_where(4, |it| it.0 == 0, |it| it.1, None);
        assert_eq!(head.len(), 1);
        assert_eq!(head[0].item, (0, 9));
    }

    #[test]
    fn peek_where_reports_the_policy_head_without_removing_it() {
        let q: JobQueue<(usize, u8)> = JobQueue::new(8);
        assert!(q.peek_where(|_| true).is_none());
        let now = Instant::now();
        q.push((0, 1), Priority::Normal, None).unwrap();
        q.push((0, 2), Priority::High, Some(now + Duration::from_secs(2))).unwrap();
        q.push((1, 3), Priority::High, Some(now + Duration::from_secs(1))).unwrap();

        let head = q.peek_where(|it| it.0 == 0).unwrap();
        assert_eq!(head.priority, Priority::High);
        assert_eq!(head.deadline, Some(now + Duration::from_secs(2)));
        assert_eq!(q.depth(), 3, "peek never dequeues");

        // the eligibility filter scopes the head to the caller's class
        let other = q.peek_where(|it| it.0 == 1).unwrap();
        assert_eq!(other.deadline, Some(now + Duration::from_secs(1)));
    }

    #[test]
    fn pop_batch_where_timeout_times_out_and_sees_late_eligibility() {
        use std::sync::Arc;
        let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new(8));
        // empty queue: times out with an empty batch, queue still open
        let b = q.pop_batch_where_timeout(4, |_| true, |_| (), Duration::from_millis(5));
        assert!(matches!(b, Some(ref v) if v.is_empty()));
        // a queued job that only becomes eligible later (a retry-backoff
        // gate) is picked up by a subsequent timed-out scan with no push
        // in between
        q.push(7, Priority::Normal, None).unwrap();
        let gate = Instant::now() + Duration::from_millis(30);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || loop {
            match q2.pop_batch_where_timeout(
                1,
                |_| Instant::now() >= gate,
                |_| (),
                Duration::from_millis(10),
            ) {
                Some(b) if !b.is_empty() => return Some(b[0].item),
                Some(_) => continue,
                None => return None,
            }
        });
        assert_eq!(h.join().unwrap(), Some(7));
        // closed and drained: None
        q.close();
        assert!(q
            .pop_batch_where_timeout(1, |_| true, |_| (), Duration::from_millis(5))
            .is_none());
    }

    #[test]
    fn priority_names_round_trip() {
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        // Ord is drain order: High pops first via min_by
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
    }
}
