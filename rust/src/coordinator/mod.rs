//! L3 coordinator: the serving loop (FIFO queue, single-device worker,
//! resident UNet) and per-request metrics.

pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse};
pub use server::Server;
