//! L3 coordinator: the serving stack — an admission-controlled,
//! priority/deadline-aware job queue ([`queue`]), a pool of supervised
//! device workers each owning a pipelined executor ([`pool`],
//! heterogeneous via [`crate::planner::FleetSpec`]; panics and device
//! loss rebuild the worker, transient faults retry from checkpoints),
//! the per-device-class circuit breakers behind degrading admission
//! ([`breaker`]), the memory-pressure governor whose learned budgets
//! cap admission after OOM ([`pressure`]), the fleet metrics
//! ([`metrics`], including
//! per-device-class predicted-vs-actual latency and fault counters),
//! and the front-door [`Server`] whose admission consults the planner.

pub mod breaker;
pub mod metrics;
pub mod pool;
pub mod pressure;
pub mod queue;
pub mod request;
pub mod server;

pub use breaker::{BreakerState, CircuitBreaker};
pub use metrics::{ClassMetrics, Metrics, PoolMetrics, SampleWindow, WorkerStats};
pub use pool::{
    ReplySlot, ResponseReceiver, SupervisionOptions, WorkItem, WorkerExecutor, WorkerPool,
};
pub use pressure::{PressureGovernor, PressureOptions};
pub use queue::{AdmissionError, Job, JobQueue, PeekInfo, Priority};
pub use request::{GenerateRequest, GenerateResponse, SubmitOptions};
pub use server::Server;
