//! L3 coordinator: the serving stack — an admission-controlled,
//! priority/deadline-aware job queue ([`queue`]), a pool of device
//! workers each owning a pipelined executor ([`pool`], heterogeneous
//! via [`crate::planner::FleetSpec`]), the fleet metrics ([`metrics`],
//! including per-device-class predicted-vs-actual latency), and the
//! front-door [`Server`] whose admission consults the planner.

pub mod metrics;
pub mod pool;
pub mod queue;
pub mod request;
pub mod server;

pub use metrics::{ClassMetrics, Metrics, PoolMetrics, SampleWindow, WorkerStats};
pub use pool::{ResponseReceiver, WorkItem, WorkerExecutor, WorkerPool};
pub use queue::{AdmissionError, Job, JobQueue, PeekInfo, Priority};
pub use request::{GenerateRequest, GenerateResponse, SubmitOptions};
pub use server::Server;
