//! Serving metrics: per-stage latency summaries plus pool-level
//! counters — queue depth high-water, admission rejections, end-to-end
//! latency percentiles, per-worker utilization, fleet-wide load
//! accounting (cold vs warm reloads, store hits vs misses), and the
//! per-class *observed* request overhead that feeds back into the
//! planner's admission predictions.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::pipeline::{LoadProfile, StageTimings};
use crate::util::stats::{summarize, Summary};

/// Default cap on retained samples per series (`--calib-window`
/// overrides it per pool).  The serving loop is a daemon; unbounded
/// per-request sample vectors would grow (and re-sort on every report)
/// forever, so percentiles are computed over a sliding window of the
/// most recent samples.
pub const MAX_SAMPLES: usize = 4096;

/// Fixed-capacity sliding window of latency samples.
#[derive(Debug)]
pub struct SampleWindow {
    samples: Vec<f64>,
    /// overwrite cursor once the window is full
    next: usize,
    /// retained-sample cap ([`MAX_SAMPLES`] unless configured)
    cap: usize,
}

impl Default for SampleWindow {
    fn default() -> Self {
        SampleWindow::with_capacity(MAX_SAMPLES)
    }
}

impl SampleWindow {
    /// A window retaining at most `cap` samples (clamped to 1).
    pub fn with_capacity(cap: usize) -> SampleWindow {
        SampleWindow { samples: Vec::new(), next: 0, cap: cap.max(1) }
    }

    pub fn push(&mut self, x: f64) {
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            self.samples[self.next] = x;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Order statistics over the retained window.
    pub fn summary(&self) -> Summary {
        summarize(&self.samples)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Stage-level latency samples for successful requests.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_ok: usize,
    pub requests_failed: usize,
    samples: BTreeMap<&'static str, SampleWindow>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_success(&mut self, t: &StageTimings) {
        self.requests_ok += 1;
        for (k, v) in [
            ("text_load", t.text_load_s),
            ("text_encode", t.text_encode_s),
            ("unet_load", t.unet_load_s),
            ("denoise", t.denoise_s),
            ("decoder_load", t.decoder_load_s),
            ("decode", t.decode_s),
            ("total", t.total_s),
        ] {
            self.samples.entry(k).or_default().push(v);
        }
        if t.denoise_steps > 0 {
            self.samples
                .entry("per_step")
                .or_default()
                .push(t.denoise_s / t.denoise_steps as f64);
        }
    }

    pub fn record_failure(&mut self) {
        self.requests_failed += 1;
    }

    pub fn summary(&self, key: &str) -> Option<Summary> {
        self.samples.get(key).map(|s| s.summary())
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "requests: {} ok, {} failed\n",
            self.requests_ok, self.requests_failed
        );
        for (k, v) in &self.samples {
            let s = v.summary();
            out.push_str(&format!(
                "  {:<14} mean {:>8.1} ms   p50 {:>8.1} ms   p99 {:>8.1} ms\n",
                k,
                s.mean * 1e3,
                s.p50 * 1e3,
                s.p99 * 1e3
            ));
        }
        out
    }
}

/// Per-worker accounting, updated by the worker thread after each job.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub requests_ok: usize,
    pub requests_failed: usize,
    /// wall-clock spent executing (utilization numerator)
    pub busy_s: f64,
}

/// Per-device-class accounting for a heterogeneous fleet: how many
/// requests the planner routed here and how its plan-predicted service
/// times compare with what the workers actually measured.
#[derive(Debug)]
pub struct ClassMetrics {
    /// planner-registry name of the device class
    pub name: String,
    predicted_s: SampleWindow,
    actual_s: SampleWindow,
    /// |actual - predicted| / predicted, per served request
    abs_rel_err: SampleWindow,
    /// measured non-denoise time per served request (loads + encode +
    /// decode), keyed by variant — the observed analog of the plan's
    /// per-`(device, variant)` `overhead_s`, so one variant's cheap
    /// overhead never vouches for another's
    overhead_s: BTreeMap<String, SampleWindow>,
    /// per-series retained-sample cap for this class's windows
    window: usize,
    /// served requests before a variant's measured overhead is trusted
    min_overhead: usize,
}

/// Default served requests a class must accumulate before its measured
/// overhead replaces the planner's modeled constant (`--calib-window`
/// shrinks it when the window is smaller).
pub const MIN_OVERHEAD_SAMPLES: usize = 4;

impl ClassMetrics {
    fn new(name: &str) -> ClassMetrics {
        ClassMetrics::with_config(name, MAX_SAMPLES, MIN_OVERHEAD_SAMPLES)
    }

    /// A class row with explicit observation-window capacity and
    /// overhead-trust threshold.
    fn with_config(name: &str, window: usize, min_overhead: usize) -> ClassMetrics {
        let window = window.max(1);
        ClassMetrics {
            name: name.to_string(),
            predicted_s: SampleWindow::with_capacity(window),
            actual_s: SampleWindow::with_capacity(window),
            abs_rel_err: SampleWindow::with_capacity(window),
            overhead_s: BTreeMap::new(),
            window,
            min_overhead: min_overhead.max(1),
        }
    }

    /// Mean measured per-request overhead of `variant` on this class,
    /// once enough requests have been served to trust it (`None` until
    /// then — the planner keeps its modeled constant).
    pub fn observed_overhead_s(&self, variant: &str) -> Option<f64> {
        let w = self.overhead_s.get(variant)?;
        if w.len() < self.min_overhead {
            return None;
        }
        Some(w.summary().mean)
    }

    /// Served requests of `variant` contributing overhead measurements.
    pub fn overhead_count(&self, variant: &str) -> usize {
        self.overhead_s.get(variant).map_or(0, |w| w.len())
    }

    /// Every variant whose measured overhead is trusted, with its mean.
    pub fn observed_overheads(&self) -> impl Iterator<Item = (&str, f64)> + '_ {
        self.overhead_s
            .iter()
            .filter(|(_, w)| w.len() >= self.min_overhead)
            .map(|(v, w)| (v.as_str(), w.summary().mean))
    }

    /// Successfully served requests that carried a plan prediction.
    pub fn prediction_count(&self) -> usize {
        self.abs_rel_err.len()
    }

    pub fn predicted_summary(&self) -> Summary {
        self.predicted_s.summary()
    }

    pub fn actual_summary(&self) -> Summary {
        self.actual_s.summary()
    }

    /// Relative prediction-error statistics (`|actual-predicted| /
    /// predicted`): how honest the cost model is on this class.
    pub fn error_summary(&self) -> Summary {
        self.abs_rel_err.summary()
    }
}

/// Fleet-level metrics shared by all workers of a pool.
#[derive(Debug)]
pub struct PoolMetrics {
    pub stage: Metrics,
    pub workers: Vec<WorkerStats>,
    /// per-device-class predicted-vs-actual accounting; a homogeneous
    /// pool has one "default" class that never records predictions
    pub classes: Vec<ClassMetrics>,
    /// submissions rejected by admission control (queue full)
    pub rejected_full: usize,
    /// submissions rejected at admission because no device class could
    /// meet their deadline (plan-predicted service time too long)
    pub rejected_infeasible: usize,
    /// jobs dropped because their deadline passed before execution
    pub rejected_deadline: usize,
    /// micro-batches dispatched by workers (a solo request counts as a
    /// batch of one).  Occupancy is the queue-level co-scheduling
    /// size; the executor may still split a group it cannot batch
    /// (legacy scalar-timestep artifacts) into solo dispatches.
    pub batches: usize,
    /// largest batch occupancy observed
    pub max_batch_occupancy: usize,
    /// continuous-batching sessions started (one worker occupancy
    /// period each; a session's initial pop also counts as a batch)
    pub sessions: usize,
    /// rows spliced into an in-flight session at a step boundary
    pub joins: usize,
    /// rows retired (decoded) while their batchmates kept running
    pub leaves: usize,
    /// rows checkpointed and requeued to free a slot
    pub preemptions: usize,
    /// rows readmitted from a preemption checkpoint
    pub resumes: usize,
    /// UNet dispatches recorded by continuous sessions
    pub steps: usize,
    /// faults injected by the device runtime's fault plan that
    /// surfaced as transient errors (retryable)
    pub injected_transient: u64,
    /// injected faults that surfaced as fatal (device-lost) errors
    pub injected_fatal: u64,
    /// injected latency spikes (slow dispatches, not errors)
    pub injected_spikes: u64,
    /// requests requeued after a transient device fault
    pub retries: usize,
    /// requests failed because their retry budget was spent
    pub retries_exhausted: usize,
    /// device OOMs observed by workers; each climbs the class's
    /// memory-pressure ladder (see `coordinator::pressure`)
    pub ooms: usize,
    /// requests requeued *degraded* after an OOM — the pool never
    /// retries OOM'd work on an unchanged plan
    pub degraded_retries: usize,
    /// worker executors rebuilt after a panic or device loss
    pub worker_restarts: usize,
    /// requests refused because every device class was quarantined
    pub shed: usize,
    /// admitted requests per resolved sampler (the sampler that actually
    /// priced the request at routing, post default-resolution)
    pub samplers: BTreeMap<String, u64>,
    /// reply slots dropped without a terminal reply (a worker died
    /// mid-request); the drop guard converted each into an explicit
    /// failure, so the count is diagnostic, not a leak
    pub reply_orphaned: usize,
    /// terminal replies that found no receiver (the caller had already
    /// dropped its end) — the silent-leak signal
    pub reply_dropped: usize,
    /// Σ step wall seconds (time-weighted occupancy denominator)
    step_time_s: f64,
    /// Σ step wall × rows live in that step (numerator)
    step_row_time_s: f64,
    /// fleet-wide load accounting summed over every served request:
    /// cold vs warm reload counts, store hit/miss counts, and the
    /// wall seconds each load stage consumed
    pub loads: LoadProfile,
    /// requests per dispatched batch
    batch_occupancy: SampleWindow,
    /// seconds each executed request waited in the queue
    queue_wait: SampleWindow,
    /// queue wait + execution, per executed request
    e2e_latency: SampleWindow,
    started: Instant,
}

impl PoolMetrics {
    pub fn new(num_workers: usize) -> PoolMetrics {
        Self::with_classes(num_workers, &["default".to_string()])
    }

    /// Metrics for a heterogeneous pool: one [`ClassMetrics`] row per
    /// device class, in pool class-index order.
    pub fn with_classes(num_workers: usize, class_names: &[String]) -> PoolMetrics {
        Self::with_classes_config(num_workers, class_names, MAX_SAMPLES, MIN_OVERHEAD_SAMPLES)
    }

    /// [`PoolMetrics::with_classes`] with explicit per-class
    /// observation-window capacity and overhead-trust threshold
    /// (`--calib-window`).
    pub fn with_classes_config(
        num_workers: usize,
        class_names: &[String],
        window: usize,
        min_overhead: usize,
    ) -> PoolMetrics {
        PoolMetrics {
            stage: Metrics::new(),
            workers: vec![WorkerStats::default(); num_workers],
            classes: class_names
                .iter()
                .map(|n| ClassMetrics::with_config(n, window, min_overhead))
                .collect(),
            rejected_full: 0,
            rejected_infeasible: 0,
            rejected_deadline: 0,
            batches: 0,
            max_batch_occupancy: 0,
            sessions: 0,
            joins: 0,
            leaves: 0,
            preemptions: 0,
            resumes: 0,
            steps: 0,
            injected_transient: 0,
            injected_fatal: 0,
            injected_spikes: 0,
            retries: 0,
            retries_exhausted: 0,
            ooms: 0,
            degraded_retries: 0,
            worker_restarts: 0,
            shed: 0,
            samplers: BTreeMap::new(),
            reply_orphaned: 0,
            reply_dropped: 0,
            step_time_s: 0.0,
            step_row_time_s: 0.0,
            loads: LoadProfile::default(),
            batch_occupancy: SampleWindow::default(),
            queue_wait: SampleWindow::default(),
            e2e_latency: SampleWindow::default(),
            started: Instant::now(),
        }
    }

    /// Record one executed request (success or failure) on `worker`.
    pub fn record_executed(
        &mut self,
        worker: usize,
        queue_s: f64,
        exec_s: f64,
        timings: Option<&StageTimings>,
    ) {
        self.record_batch_member(worker, queue_s, exec_s, exec_s, timings);
    }

    /// Record one member of a dispatched batch.  `wall_s` is the batch
    /// wall-clock (every member's end-to-end latency includes all of
    /// it); `busy_share_s` is this member's share of worker busy time
    /// (`wall / occupancy`), so utilization never exceeds 100% just
    /// because requests shared a dispatch.
    pub fn record_batch_member(
        &mut self,
        worker: usize,
        queue_s: f64,
        wall_s: f64,
        busy_share_s: f64,
        timings: Option<&StageTimings>,
    ) {
        if let Some(w) = self.workers.get_mut(worker) {
            w.busy_s += busy_share_s;
            match timings {
                Some(_) => w.requests_ok += 1,
                None => w.requests_failed += 1,
            }
        }
        match timings {
            Some(t) => {
                self.stage.record_success(t);
                self.absorb_loads(&t.loads);
            }
            None => self.stage.record_failure(),
        }
        self.queue_wait.push(queue_s);
        self.e2e_latency.push(queue_s + wall_s);
    }

    /// Fold one request's load accounting into the fleet totals.
    fn absorb_loads(&mut self, l: &LoadProfile) {
        self.loads.cold_loads += l.cold_loads;
        self.loads.warm_reloads += l.warm_reloads;
        self.loads.store_hits += l.store_hits;
        self.loads.store_misses += l.store_misses;
        self.loads.read_s += l.read_s;
        self.loads.parse_s += l.parse_s;
        self.loads.dequant_s += l.dequant_s;
        self.loads.compile_s += l.compile_s;
        self.loads.upload_s += l.upload_s;
    }

    /// Record one dispatched micro-batch of `occupancy` requests.
    pub fn record_batch(&mut self, occupancy: usize) {
        self.batches += 1;
        self.max_batch_occupancy = self.max_batch_occupancy.max(occupancy);
        self.batch_occupancy.push(occupancy as f64);
    }

    /// Mean requests per dispatched batch (0 before the first batch).
    /// This is *formation-time* occupancy — what the queue co-scheduled
    /// at pop.  Under continuous batching membership changes mid-flight;
    /// use [`Self::time_weighted_occupancy`] for utilization math.
    pub fn mean_batch_occupancy(&self) -> f64 {
        self.batch_occupancy.summary().mean
    }

    /// One continuous session started with `occupancy` initial rows.
    pub fn record_session(&mut self, occupancy: usize) {
        self.sessions += 1;
        self.record_batch(occupancy);
    }

    /// One denoise dispatch of a continuous session: `live` rows over
    /// `wall_s` seconds.  Feeds the time-weighted occupancy.
    pub fn record_step(&mut self, live: usize, wall_s: f64) {
        self.steps += 1;
        self.step_time_s += wall_s;
        self.step_row_time_s += wall_s * live as f64;
        self.max_batch_occupancy = self.max_batch_occupancy.max(live);
    }

    pub fn record_join(&mut self) {
        self.joins += 1;
    }

    pub fn record_leave(&mut self) {
        self.leaves += 1;
    }

    pub fn record_preemption(&mut self) {
        self.preemptions += 1;
    }

    pub fn record_resume(&mut self) {
        self.resumes += 1;
    }

    /// Rows live per denoise-second, averaged over every recorded step
    /// — the occupancy that is actually correct for utilization when
    /// rows join and leave mid-flight (0 before the first step).
    pub fn time_weighted_occupancy(&self) -> f64 {
        if self.step_time_s <= 0.0 {
            return 0.0;
        }
        self.step_row_time_s / self.step_time_s
    }

    pub fn record_rejected_full(&mut self) {
        self.rejected_full += 1;
    }

    /// A submission rejected at admission because the planner found no
    /// device class able to meet its deadline.
    pub fn record_rejected_infeasible(&mut self) {
        self.rejected_infeasible += 1;
    }

    /// One successfully served request's plan-predicted vs measured
    /// service time on device class `class` (heterogeneous pools
    /// only).  `actual_s` is the request's share of its batch's wall
    /// clock — the plan predicts one request's service, so a shared
    /// dispatch is not charged `B` times.  Failed requests are not
    /// recorded: they never exercised the cost model.
    pub fn record_prediction(&mut self, class: usize, predicted_s: f64, actual_s: f64) {
        if let Some(c) = self.classes.get_mut(class) {
            c.predicted_s.push(predicted_s);
            c.actual_s.push(actual_s);
            let denom = predicted_s.abs().max(1e-12);
            c.abs_rel_err.push((actual_s - predicted_s).abs() / denom);
        }
    }

    /// One served request's measured non-denoise overhead (its *share*
    /// of a batch, not the batch wall) on `class` for `variant`.  Once
    /// a `(class, variant)` has [`MIN_OVERHEAD_SAMPLES`] of these, the
    /// router swaps the plan's modeled overhead constant for the
    /// observed mean — the measured-load feedback loop.
    pub fn record_class_overhead(&mut self, class: usize, variant: &str, overhead_s: f64) {
        if let Some(c) = self.classes.get_mut(class) {
            let window = c.window;
            c.overhead_s
                .entry(variant.to_string())
                .or_insert_with(|| SampleWindow::with_capacity(window))
                .push(overhead_s.max(0.0));
        }
    }

    /// Injected-fault deltas observed by a worker since its last
    /// dispatch (the fault plan's counters, diffed by the pool).
    pub fn record_injected(&mut self, transient: u64, fatal: u64, spikes: u64) {
        self.injected_transient += transient;
        self.injected_fatal += fatal;
        self.injected_spikes += spikes;
    }

    /// One request requeued after a transient device fault.
    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    /// One request failed with its retry budget spent.
    pub fn record_retries_exhausted(&mut self) {
        self.retries_exhausted += 1;
    }

    /// One device OOM observed (capacity or injected).
    pub fn record_oom(&mut self) {
        self.ooms += 1;
    }

    /// One request requeued degraded after an OOM.
    pub fn record_degraded_retry(&mut self) {
        self.degraded_retries += 1;
    }

    /// One worker executor rebuilt after a panic or device loss.
    pub fn record_worker_restart(&mut self) {
        self.worker_restarts += 1;
    }

    /// One request shed because every device class was quarantined.
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// One admitted request counted against its resolved sampler.
    pub fn record_sampler(&mut self, name: &str) {
        *self.samplers.entry(name.to_string()).or_insert(0) += 1;
    }

    /// One reply slot dropped without a terminal reply (worker death);
    /// the drop guard delivered an explicit failure in its place.
    pub fn record_reply_orphaned(&mut self) {
        self.reply_orphaned += 1;
    }

    /// One terminal reply that found its receiver already gone.
    pub fn record_reply_dropped(&mut self) {
        self.reply_dropped += 1;
    }

    /// Any failure-domain activity worth a report line?
    fn faults_observed(&self) -> bool {
        self.injected_transient > 0
            || self.injected_fatal > 0
            || self.injected_spikes > 0
            || self.retries > 0
            || self.retries_exhausted > 0
            || self.ooms > 0
            || self.degraded_retries > 0
            || self.worker_restarts > 0
            || self.shed > 0
            || self.reply_orphaned > 0
            || self.reply_dropped > 0
    }

    /// An expired job dropped at pop time.  It never executed, so it
    /// counts only toward the pool-level `expired` line — per-worker
    /// counters track executed requests and must sum to the fleet
    /// totals.
    pub fn record_rejected_deadline(&mut self) {
        self.rejected_deadline += 1;
    }

    pub fn queue_wait_summary(&self) -> Summary {
        self.queue_wait.summary()
    }

    pub fn latency_summary(&self) -> Summary {
        self.e2e_latency.summary()
    }

    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Human-readable fleet report.  `queue_depth` / `queue_max_depth`
    /// are sampled from the live queue by the caller.
    pub fn report(&self, queue_depth: usize, queue_max_depth: usize) -> String {
        let up = self.uptime_s().max(1e-9);
        let mut out = format!(
            "pool: {} workers, {} ok, {} failed, {} rejected (queue full), \
             {} rejected (deadline infeasible), {} expired\n",
            self.workers.len(),
            self.stage.requests_ok,
            self.stage.requests_failed,
            self.rejected_full,
            self.rejected_infeasible,
            self.rejected_deadline,
        );
        out.push_str(&format!(
            "queue: depth {queue_depth}, high-water {queue_max_depth}\n"
        ));
        if self.batches > 0 {
            out.push_str(&format!(
                "batches: {} dispatched, occupancy mean {:.2}, max {}\n",
                self.batches,
                self.mean_batch_occupancy(),
                self.max_batch_occupancy,
            ));
        }
        if self.sessions > 0 {
            out.push_str(&format!(
                "continuous: {} sessions, {} steps, {} joins, {} leaves, \
                 {} preemptions, {} resumes, time-weighted occupancy {:.2}\n",
                self.sessions,
                self.steps,
                self.joins,
                self.leaves,
                self.preemptions,
                self.resumes,
                self.time_weighted_occupancy(),
            ));
        }
        if !self.samplers.is_empty() {
            let counts: Vec<String> = self
                .samplers
                .iter()
                .map(|(name, n)| format!("{name}={n}"))
                .collect();
            out.push_str(&format!("samplers: {}\n", counts.join(" ")));
        }
        if self.loads.loads() > 0 {
            out.push_str(&format!(
                "loads: {} cold, {} warm reloads; store {} hits / {} misses; \
                 stage wall {:.1} ms (read {:.1}, parse {:.1}, dequant {:.1}, \
                 compile {:.1}, upload {:.1})\n",
                self.loads.cold_loads,
                self.loads.warm_reloads,
                self.loads.store_hits,
                self.loads.store_misses,
                self.loads.total_s() * 1e3,
                self.loads.read_s * 1e3,
                self.loads.parse_s * 1e3,
                self.loads.dequant_s * 1e3,
                self.loads.compile_s * 1e3,
                self.loads.upload_s * 1e3,
            ));
        }
        if self.faults_observed() {
            out.push_str(&format!(
                "faults: {} injected transient, {} injected fatal, {} spikes; \
                 {} retries, {} exhausted, {} ooms, {} degraded retries, \
                 {} worker restarts, {} shed, \
                 {} orphaned replies, {} dropped replies\n",
                self.injected_transient,
                self.injected_fatal,
                self.injected_spikes,
                self.retries,
                self.retries_exhausted,
                self.ooms,
                self.degraded_retries,
                self.worker_restarts,
                self.shed,
                self.reply_orphaned,
                self.reply_dropped,
            ));
        }
        let lat = self.latency_summary();
        let wait = self.queue_wait_summary();
        if lat.count > 0 {
            out.push_str(&format!(
                "latency: p50 {:>7.1} ms   p95 {:>7.1} ms   p99 {:>7.1} ms   (queue wait p50 {:.1} ms, p95 {:.1} ms)\n",
                lat.p50 * 1e3,
                lat.p95 * 1e3,
                lat.p99 * 1e3,
                wait.p50 * 1e3,
                wait.p95 * 1e3,
            ));
        }
        for c in &self.classes {
            if c.prediction_count() == 0 {
                continue;
            }
            let p = c.predicted_summary();
            let a = c.actual_summary();
            let e = c.error_summary();
            let observed: String = c
                .observed_overheads()
                .map(|(v, o)| format!(", observed overhead[{v}] {:.1} ms", o * 1e3))
                .collect();
            out.push_str(&format!(
                "class {:<10} {:>4} served, predicted mean {:>8.1} ms, \
                 actual mean {:>8.1} ms, |rel err| mean {:>6.1}%{observed}\n",
                c.name,
                c.prediction_count(),
                p.mean * 1e3,
                a.mean * 1e3,
                e.mean * 100.0,
            ));
        }
        for (i, w) in self.workers.iter().enumerate() {
            out.push_str(&format!(
                "worker {i}: {:>4} ok, {:>3} failed, busy {:>7.2} s, utilization {:>5.1}%\n",
                w.requests_ok,
                w.requests_failed,
                w.busy_s,
                w.busy_s / up * 100.0,
            ));
        }
        out.push_str(&self.stage.report());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timings(total: f64) -> StageTimings {
        StageTimings {
            text_load_s: 0.1,
            text_encode_s: 0.05,
            unet_load_s: 0.5,
            denoise_s: 2.0,
            denoise_steps: 20,
            decoder_load_s: 0.2,
            decode_s: 0.3,
            total_s: total,
            ..Default::default()
        }
    }

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        let t = timings(3.0);
        m.record_success(&t);
        m.record_success(&t);
        m.record_failure();
        assert_eq!(m.requests_ok, 2);
        assert_eq!(m.requests_failed, 1);
        let s = m.summary("total").unwrap();
        assert_eq!(s.count, 2);
        assert!((s.mean - 3.0).abs() < 1e-9);
        let per_step = m.summary("per_step").unwrap();
        assert!((per_step.mean - 0.1).abs() < 1e-9);
        assert!(m.report().contains("denoise"));
    }

    #[test]
    fn pool_metrics_track_workers_and_rejections() {
        let mut p = PoolMetrics::new(2);
        let t = timings(1.0);
        p.record_executed(0, 0.5, 1.0, Some(&t));
        p.record_executed(1, 0.2, 2.0, Some(&t));
        p.record_executed(1, 0.0, 0.5, None); // a failure
        p.record_rejected_full();
        p.record_rejected_deadline();

        assert_eq!(p.stage.requests_ok, 2);
        assert_eq!(p.stage.requests_failed, 1);
        assert_eq!(p.rejected_full, 1);
        assert_eq!(p.rejected_deadline, 1);
        assert_eq!(p.workers[0].requests_ok, 1);
        assert_eq!(
            p.workers[0].requests_failed, 0,
            "deadline drops never executed, so they don't count against a worker"
        );
        let executed_failed: usize = p.workers.iter().map(|w| w.requests_failed).sum();
        assert_eq!(executed_failed, p.stage.requests_failed, "rows sum to the fleet line");
        assert!((p.workers[1].busy_s - 2.5).abs() < 1e-9);
        let lat = p.latency_summary();
        assert_eq!(lat.count, 3);
        assert!((lat.max - 2.2).abs() < 1e-9);

        let report = p.report(3, 7);
        assert!(report.contains("2 workers"), "{report}");
        assert!(report.contains("depth 3, high-water 7"), "{report}");
        assert!(report.contains("worker 0"), "{report}");
        assert!(report.contains("utilization"), "{report}");
        assert!(report.contains("p95"), "{report}");
    }

    #[test]
    fn batch_occupancy_is_tracked_and_reported() {
        let mut p = PoolMetrics::new(1);
        let t = timings(1.0);
        p.record_batch(4);
        for _ in 0..4 {
            p.record_batch_member(0, 0.1, 2.0, 0.5, Some(&t));
        }
        p.record_batch(2);
        for _ in 0..2 {
            p.record_batch_member(0, 0.1, 1.0, 0.5, Some(&t));
        }
        assert_eq!(p.batches, 2);
        assert_eq!(p.max_batch_occupancy, 4);
        assert!((p.mean_batch_occupancy() - 3.0).abs() < 1e-9);
        // busy time is the per-member share, not the batch wall x members
        assert!((p.workers[0].busy_s - 3.0).abs() < 1e-9);
        // e2e latency includes the full batch wall
        assert!((p.latency_summary().max - 2.1).abs() < 1e-9);
        let report = p.report(0, 0);
        assert!(report.contains("occupancy mean 3.00, max 4"), "{report}");
    }

    #[test]
    fn continuous_counters_and_time_weighted_occupancy() {
        let mut p = PoolMetrics::new(1);
        assert_eq!(p.time_weighted_occupancy(), 0.0, "no steps yet");
        let report = p.report(0, 0);
        assert!(!report.contains("continuous:"), "{report}");

        p.record_session(2);
        // 1s at 2 rows, 1s at 4 rows (two joins), 2s at 1 row
        p.record_step(2, 1.0);
        p.record_join();
        p.record_join();
        p.record_step(4, 1.0);
        p.record_leave();
        p.record_preemption();
        p.record_step(1, 2.0);
        p.record_resume();

        assert_eq!(p.sessions, 1);
        assert_eq!(p.batches, 1, "a session's pop is also a batch");
        assert_eq!(p.steps, 3);
        assert_eq!(p.joins, 2);
        assert_eq!(p.leaves, 1);
        assert_eq!(p.preemptions, 1);
        assert_eq!(p.resumes, 1);
        // (2*1 + 4*1 + 1*2) / (1 + 1 + 2) = 8/4 = 2.0
        assert!((p.time_weighted_occupancy() - 2.0).abs() < 1e-9);
        // mid-flight joins can push occupancy past the formation size
        assert_eq!(p.max_batch_occupancy, 4);

        let report = p.report(0, 0);
        assert!(report.contains("continuous: 1 sessions"), "{report}");
        assert!(report.contains("time-weighted occupancy 2.00"), "{report}");
    }

    #[test]
    fn class_predictions_are_tracked_and_reported() {
        let mut p = PoolMetrics::with_classes(
            2,
            &["adreno740".to_string(), "bigcore".to_string()],
        );
        // class 0: model says 2.0s, device measured 1.0s -> 50% error
        p.record_prediction(0, 2.0, 1.0);
        // class 1: spot-on
        p.record_prediction(1, 4.0, 4.0);
        p.record_prediction(1, 2.0, 2.0);
        p.record_rejected_infeasible();

        assert_eq!(p.classes[0].prediction_count(), 1);
        assert!((p.classes[0].error_summary().mean - 0.5).abs() < 1e-9);
        assert_eq!(p.classes[1].prediction_count(), 2);
        assert!(p.classes[1].error_summary().mean < 1e-9);
        assert!((p.classes[1].predicted_summary().mean - 3.0).abs() < 1e-9);
        assert_eq!(p.rejected_infeasible, 1);
        // out-of-range class ids are ignored, matching worker stats
        p.record_prediction(9, 1.0, 1.0);

        let report = p.report(0, 0);
        assert!(report.contains("class adreno740"), "{report}");
        assert!(report.contains("class bigcore"), "{report}");
        assert!(report.contains("rejected (deadline infeasible)"), "{report}");
    }

    #[test]
    fn load_accounting_is_totalled_and_reported() {
        let mut p = PoolMetrics::new(1);
        let mut t = timings(1.0);
        t.loads = LoadProfile {
            cold_loads: 3,
            warm_reloads: 0,
            store_hits: 0,
            store_misses: 3,
            read_s: 0.01,
            parse_s: 0.02,
            dequant_s: 0.0,
            compile_s: 0.03,
            upload_s: 0.04,
        };
        p.record_executed(0, 0.0, 1.0, Some(&t));
        let mut t2 = timings(1.0);
        t2.loads = LoadProfile {
            cold_loads: 0,
            warm_reloads: 2,
            store_hits: 2,
            store_misses: 0,
            upload_s: 0.01,
            ..Default::default()
        };
        p.record_executed(0, 0.0, 1.0, Some(&t2));
        assert_eq!(p.loads.cold_loads, 3);
        assert_eq!(p.loads.warm_reloads, 2);
        assert_eq!(p.loads.store_hits, 2);
        assert_eq!(p.loads.store_misses, 3);
        assert!((p.loads.upload_s - 0.05).abs() < 1e-12);
        let report = p.report(0, 0);
        assert!(report.contains("3 cold, 2 warm reloads"), "{report}");
        assert!(report.contains("store 2 hits / 3 misses"), "{report}");
    }

    #[test]
    fn observed_overhead_needs_enough_samples_and_is_per_variant() {
        let mut p = PoolMetrics::with_classes(1, &["adreno740".to_string()]);
        for _ in 0..(MIN_OVERHEAD_SAMPLES - 1) {
            p.record_class_overhead(0, "mobile", 0.5);
        }
        assert!(
            p.classes[0].observed_overhead_s("mobile").is_none(),
            "not yet trusted"
        );
        p.record_class_overhead(0, "mobile", 0.5);
        assert!((p.classes[0].observed_overhead_s("mobile").unwrap() - 0.5).abs() < 1e-9);
        assert_eq!(p.classes[0].overhead_count("mobile"), MIN_OVERHEAD_SAMPLES);
        // one variant's samples never vouch for another variant
        assert!(p.classes[0].observed_overhead_s("base").is_none());
        assert_eq!(p.classes[0].overhead_count("base"), 0);
        // negative measurements are clamped, out-of-range classes ignored
        p.record_class_overhead(0, "mobile", -1.0);
        assert!(p.classes[0].observed_overhead_s("mobile").unwrap() >= 0.0);
        p.record_class_overhead(9, "mobile", 1.0);

        p.record_prediction(0, 1.0, 1.0);
        let report = p.report(0, 0);
        assert!(report.contains("observed overhead[mobile]"), "{report}");
    }

    #[test]
    fn homogeneous_pools_skip_the_class_lines() {
        let mut p = PoolMetrics::new(1);
        assert_eq!(p.classes.len(), 1);
        let t = timings(1.0);
        p.record_executed(0, 0.1, 1.0, Some(&t));
        let report = p.report(0, 0);
        assert!(!report.contains("class default"), "{report}");
    }

    #[test]
    fn fault_counters_surface_only_when_something_failed() {
        let mut p = PoolMetrics::new(1);
        let report = p.report(0, 0);
        assert!(!report.contains("faults:"), "quiet fleets skip the line: {report}");

        p.record_injected(3, 1, 2);
        p.record_injected(1, 0, 0);
        p.record_retry();
        p.record_retry();
        p.record_retries_exhausted();
        p.record_oom();
        p.record_degraded_retry();
        p.record_worker_restart();
        p.record_shed();
        p.record_reply_orphaned();
        p.record_reply_dropped();
        assert_eq!(p.injected_transient, 4);
        assert_eq!(p.injected_fatal, 1);
        assert_eq!(p.injected_spikes, 2);
        assert_eq!(p.retries, 2);
        assert_eq!(p.retries_exhausted, 1);
        assert_eq!(p.ooms, 1);
        assert_eq!(p.degraded_retries, 1);
        assert_eq!(p.worker_restarts, 1);
        assert_eq!(p.shed, 1);
        assert_eq!(p.reply_orphaned, 1);
        assert_eq!(p.reply_dropped, 1);

        let report = p.report(0, 0);
        assert!(report.contains("faults: 4 injected transient"), "{report}");
        assert!(
            report.contains("2 retries, 1 exhausted, 1 ooms, 1 degraded retries"),
            "{report}"
        );
        assert!(report.contains("1 worker restarts, 1 shed"), "{report}");
    }

    #[test]
    fn sampler_counts_surface_only_when_recorded() {
        let mut p = PoolMetrics::new(1);
        let report = p.report(0, 0);
        assert!(!report.contains("samplers:"), "{report}");

        p.record_sampler("ddim");
        p.record_sampler("dpm2m");
        p.record_sampler("dpm2m");
        assert_eq!(p.samplers["dpm2m"], 2);
        let report = p.report(0, 0);
        assert!(report.contains("samplers: ddim=1 dpm2m=2"), "{report}");
    }

    #[test]
    fn an_oom_alone_surfaces_the_fault_line() {
        let mut p = PoolMetrics::new(1);
        p.record_oom();
        let report = p.report(0, 0);
        assert!(report.contains("1 ooms, 0 degraded retries"), "{report}");
    }

    #[test]
    fn configured_windows_bound_class_series_and_trust_threshold() {
        let mut p = PoolMetrics::with_classes_config(1, &["adreno740".to_string()], 8, 2);
        p.record_class_overhead(0, "mobile", 0.5);
        assert!(p.classes[0].observed_overhead_s("mobile").is_none());
        p.record_class_overhead(0, "mobile", 0.5);
        assert!(
            p.classes[0].observed_overhead_s("mobile").is_some(),
            "configured trust threshold of 2"
        );
        for i in 0..100 {
            p.record_prediction(0, 1.0, 1.0 + i as f64);
        }
        assert_eq!(p.classes[0].prediction_count(), 8, "configured window bound");
    }

    #[test]
    fn sample_window_is_bounded_and_slides() {
        let mut w = SampleWindow::default();
        assert!(w.is_empty());
        for i in 0..(MAX_SAMPLES + 100) {
            w.push(i as f64);
        }
        assert_eq!(w.len(), MAX_SAMPLES, "daemon-lifetime memory stays bounded");
        let s = w.summary();
        assert_eq!(s.count, MAX_SAMPLES);
        // the oldest 100 samples were overwritten by the newest 100
        assert!(s.min >= 100.0, "window slides: min is {}", s.min);
        assert_eq!(s.max, (MAX_SAMPLES + 99) as f64);
    }

    #[test]
    fn out_of_range_worker_ids_are_ignored() {
        let mut p = PoolMetrics::new(1);
        p.record_executed(5, 0.0, 1.0, None);
        assert_eq!(p.stage.requests_failed, 1);
        assert_eq!(p.workers[0].requests_failed, 0);
    }
}
