//! Serving metrics: request counters and latency summaries per stage.

use std::collections::BTreeMap;

use crate::pipeline::StageTimings;
use crate::util::stats::{summarize, Summary};

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_ok: usize,
    pub requests_failed: usize,
    samples: BTreeMap<&'static str, Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_success(&mut self, t: &StageTimings) {
        self.requests_ok += 1;
        for (k, v) in [
            ("text_load", t.text_load_s),
            ("text_encode", t.text_encode_s),
            ("unet_load", t.unet_load_s),
            ("denoise", t.denoise_s),
            ("decoder_load", t.decoder_load_s),
            ("decode", t.decode_s),
            ("total", t.total_s),
        ] {
            self.samples.entry(k).or_default().push(v);
        }
        if t.denoise_steps > 0 {
            self.samples
                .entry("per_step")
                .or_default()
                .push(t.denoise_s / t.denoise_steps as f64);
        }
    }

    pub fn record_failure(&mut self) {
        self.requests_failed += 1;
    }

    pub fn summary(&self, key: &str) -> Option<Summary> {
        self.samples.get(key).map(|s| summarize(s))
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "requests: {} ok, {} failed\n",
            self.requests_ok, self.requests_failed
        );
        for (k, v) in &self.samples {
            let s = summarize(v);
            out.push_str(&format!(
                "  {:<14} mean {:>8.1} ms   p50 {:>8.1} ms   p99 {:>8.1} ms\n",
                k,
                s.mean * 1e3,
                s.p50 * 1e3,
                s.p99 * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        let t = StageTimings {
            text_load_s: 0.1,
            text_encode_s: 0.05,
            unet_load_s: 0.5,
            denoise_s: 2.0,
            denoise_steps: 20,
            decoder_load_s: 0.2,
            decode_s: 0.3,
            total_s: 3.0,
        };
        m.record_success(&t);
        m.record_success(&t);
        m.record_failure();
        assert_eq!(m.requests_ok, 2);
        assert_eq!(m.requests_failed, 1);
        let s = m.summary("total").unwrap();
        assert_eq!(s.count, 2);
        assert!((s.mean - 3.0).abs() < 1e-9);
        let per_step = m.summary("per_step").unwrap();
        assert!((per_step.mean - 0.1).abs() < 1e-9);
        assert!(m.report().contains("denoise"));
    }
}
