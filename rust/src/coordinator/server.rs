//! The serving loop: a FIFO request queue in front of one pipelined
//! executor.
//!
//! A phone is a single-device server: concurrency 1, strict FIFO, with
//! the UNet kept resident across requests (the paper's app behaviour).
//! PJRT handles are not Send, so the executor lives on a dedicated
//! worker thread that owns the engine; callers talk to it over
//! channels.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use crate::config::AppConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::error::{Error, Result};
use crate::pipeline::{ExecOptions, PipelinedExecutor};
use crate::runtime::Manifest;

enum Msg {
    Generate(GenerateRequest, Instant, mpsc::Sender<Result<GenerateResponse>>),
    Report(mpsc::Sender<String>),
    Shutdown,
}

pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<()>>,
    next_id: u64,
}

impl Server {
    /// Start the worker; fails fast if the artifacts are unreadable.
    pub fn start(config: &AppConfig) -> Result<Server> {
        // parse the manifest on the caller thread for early errors
        let manifest = Manifest::load(&config.artifacts_dir)?;
        let options: ExecOptions = config.exec_options();
        let variant = config.variant.clone();

        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = thread::Builder::new()
            .name("md-worker".into())
            .spawn(move || worker(manifest, options, variant, rx, ready_tx))
            .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("worker died during startup".into()))??;
        Ok(Server { tx, handle: Some(handle), next_id: 0 })
    }

    /// Enqueue a generation; returns a receiver for the response.
    pub fn submit(
        &mut self,
        prompt: &str,
        seed: u64,
    ) -> mpsc::Receiver<Result<GenerateResponse>> {
        self.next_id += 1;
        let req = GenerateRequest::new(self.next_id, prompt, seed);
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Generate(req, Instant::now(), tx));
        rx
    }

    /// Blocking convenience wrapper.
    pub fn generate(&mut self, prompt: &str, seed: u64) -> Result<GenerateResponse> {
        self.submit(prompt, seed)
            .recv()
            .map_err(|_| Error::Runtime("worker dropped request".into()))?
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Report(tx))
            .map_err(|_| Error::Runtime("worker gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("worker gone".into()))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(
    manifest: Manifest,
    options: ExecOptions,
    variant: String,
    rx: mpsc::Receiver<Msg>,
    ready_tx: mpsc::Sender<Result<()>>,
) {
    let mut metrics = Metrics::new();
    let mut executor = match PipelinedExecutor::new(manifest, options) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Generate(req, enqueued, reply) => {
                let queue_s = enqueued.elapsed().as_secs_f64();
                let result = executor.generate(&req.prompt, req.seed, &variant);
                let resp = match result {
                    Ok(r) => {
                        metrics.record_success(&r.timings);
                        Ok(GenerateResponse {
                            id: req.id,
                            image: r.image,
                            image_size: r.image_size,
                            latent: r.latent,
                            timings: r.timings,
                            peak_memory: r.peak_memory,
                            queue_s,
                        })
                    }
                    Err(e) => {
                        metrics.record_failure();
                        Err(e)
                    }
                };
                let _ = reply.send(resp);
            }
            Msg::Report(reply) => {
                let _ = reply.send(metrics.report());
            }
            Msg::Shutdown => break,
        }
    }
}
