//! The serving front door: admission queue + worker pool + pipelined
//! executors, with the planner as the scheduling brain.
//!
//! `Server::start` parses the artifact manifest once (fail-fast on the
//! caller thread), then brings up a [`WorkerPool`].  Without a fleet
//! spec the pool is `config.num_workers` identical workers; with
//! `config.fleet` (e.g. `adreno740:2,bigcore:1`) each class resolves
//! against the planner's device registry, a shared
//! [`crate::planner::PlanRegistry`] prices every `(class, variant)`
//! combination up front, and a [`FleetRouter`] decides admission:
//! deadlines no class can meet are rejected immediately, everything
//! else is routed to the cheapest class whose plan-predicted service
//! time fits.  Each worker thread constructs its own
//! [`PipelinedExecutor`] — PJRT handles are not `Send`, so engine,
//! residency cache and memory budget are per worker, modelling a fleet
//! of single-device phones behind one queue.
//!
//! Requests carry per-submission scheduling directives (priority,
//! deadline) and execution overrides (step count, variant, guidance)
//! that are honored end-to-end: `SubmitOptions` -> `GenerateRequest` ->
//! `ExecOverrides` -> the denoise loop.
//!
//! By default (`config.continuous`) workers schedule *continuously*:
//! compatible requests join an in-flight batch at denoise-step
//! boundaries instead of waiting out its tail, and deadline pressure
//! can preempt low-priority rows (see `pipeline::continuous`).
//! `--no-continuous` restores run-to-completion batching.
//!
//! All workers load through one shared [`ArtifactStore`]: each
//! `(component, tag)` is read, parsed and dequantized from disk exactly
//! once per process no matter how many workers the fleet runs.  Once a
//! class has served enough requests, admission swaps the plan's modeled
//! overhead constant for the class's *measured* per-request overhead
//! ([`crate::coordinator::metrics::ClassMetrics::observed_overhead_s`]).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::config::AppConfig;
use crate::coordinator::breaker::CircuitBreaker;
use crate::coordinator::pool::{
    ResponseReceiver, SupervisionOptions, WorkerExecutor, WorkerPool,
};
use crate::coordinator::pressure::{PressureGovernor, PressureOptions};
use crate::coordinator::queue::Priority;
use crate::coordinator::request::{GenerateRequest, GenerateResponse, SubmitOptions};
use crate::error::{Error, Result};
use crate::pipeline::{
    BatchKey, BatchRequest, ContinuousControl, ContinuousJob, DispatchObserver,
    GenerateResult, PipelinedExecutor,
};
use crate::planner::{FleetCalibration, FleetRouter, FleetSpec, PlanRegistry};
use crate::runtime::{ArtifactStore, Manifest};
use crate::scheduler::Sampler;

/// Adapts a [`PipelinedExecutor`] to the pool's worker interface,
/// applying per-request overrides against the configured defaults.
struct PipelineWorker {
    executor: PipelinedExecutor,
    default_variant: String,
    /// seat count for a continuous session's dynamic batch
    max_batch: usize,
    /// configured seat count before any memory-pressure degradation,
    /// so `degrade`'s halving is cumulative-from-shipped, not
    /// cumulative-from-current (and recovery can restore it)
    base_batch: usize,
}

impl WorkerExecutor for PipelineWorker {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        self.executor
            .generate_with(&req.prompt, req.seed, &self.default_variant, &req.overrides())
    }

    /// A compatible batch shares one CFG-batched UNet dispatch per
    /// denoise step (see `pipeline::batch`).
    fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
        let batch: Vec<BatchRequest> = reqs
            .iter()
            .map(|r| BatchRequest {
                prompt: r.prompt.clone(),
                seed: r.seed,
                overrides: r.overrides(),
            })
            .collect();
        self.executor.generate_batch(&batch, &self.default_variant)
    }

    /// The real step-level continuous session: the seed jobs enter the
    /// denoise loop, which calls back into `control` at every step
    /// boundary for joins, slot reclamation and preemption (see
    /// `pipeline::continuous`).
    fn execute_continuous(
        &mut self,
        jobs: Vec<ContinuousJob>,
        control: &mut dyn ContinuousControl,
    ) -> Result<()> {
        let variant = jobs
            .first()
            .and_then(|j| j.req.overrides.variant.clone())
            .unwrap_or_else(|| self.default_variant.clone());
        let key = BatchKey {
            variant,
            weights_tag: self.executor.options.unet_weights.clone(),
            sampler: jobs
                .first()
                .and_then(|j| j.req.overrides.sampler)
                .unwrap_or(self.executor.options.sampler),
        };
        self.executor
            .run_continuous(&key, &self.default_variant, jobs, self.max_batch, control)
            .map(|_| ())
    }

    /// Cumulative injected-fault counters from this worker's device,
    /// diffed by the pool into the fleet metrics.
    fn fault_counts(&self) -> (u64, u64, u64) {
        let s = self.executor.engine.device_stats();
        (s.injected_transient(), s.injected_fatal(), s.injected_spikes())
    }

    /// The degradation ladder, one rung per OOM (see
    /// `coordinator::pressure`):
    ///
    /// 1. shrink the continuous session's seat cap (halved per rung) —
    ///    fewer concurrent rows means a smaller CFG-batched dispatch;
    /// 2. shed warm-tier and non-pinned residency, so the retry starts
    ///    from the smallest live set the pipeline can run with;
    /// 3. force W8A8 activations and re-plan the executor under the
    ///    governor's learned budget, the lowest-memory configuration
    ///    this executor has.
    ///
    /// Rung 1 always changes *something*, so an OOM'd request is
    /// always retried at least once — on a genuinely different plan.
    fn degrade(&mut self, level: u8, effective_budget: usize) -> Option<String> {
        let mut actions = Vec::new();
        self.max_batch = (self.base_batch >> level).max(1);
        actions.push(format!("seat cap {}", self.max_batch));
        if level >= 2 {
            let evicted = self.executor.shed_memory();
            actions.push(format!("shed {evicted} resident components"));
        }
        if level >= 3 {
            self.executor.engine.device_stats().set_activation_quant(true);
            if effective_budget < self.executor.options.memory_budget {
                let installed = self.executor.rebase_budget(effective_budget);
                actions.push(format!("w8a8 + budget {installed} B"));
            } else {
                actions.push("w8a8".to_string());
            }
        }
        Some(actions.join(", "))
    }
}

pub struct Server {
    pool: WorkerPool,
    next_id: u64,
    default_variant: String,
    default_steps: usize,
    default_sampler: Sampler,
    /// plan-driven admission routing; `None` for homogeneous pools
    router: Option<FleetRouter>,
    /// per-class memory-pressure governor: learned budgets from OOM
    /// events cap admission, and its ladder level drives worker
    /// degradation
    pressure: Arc<PressureGovernor>,
    /// process-wide host-artifact cache shared by every worker
    store: Arc<ArtifactStore>,
}

impl Server {
    /// Start the worker pool; fails fast if the artifacts are
    /// unreadable, the fleet spec doesn't resolve, or any worker
    /// cannot construct its executor.
    pub fn start(config: &AppConfig) -> Result<Server> {
        // parse the manifest on the caller thread for early errors
        let manifest = Manifest::load(&config.artifacts_dir)?;
        let options = config.exec_options();
        let default_sampler = options.sampler;
        let variant = config.variant.clone();

        let router = match &config.fleet {
            Some(spec) => {
                let fleet = FleetSpec::parse(spec)?;
                let plans = Arc::new(PlanRegistry::new());
                // price every (class, variant) combination up front so
                // admission never pays the pass pipeline
                for class in &fleet.classes {
                    for v in crate::planner::model::VARIANTS {
                        plans.plan(&class.device, v)?;
                    }
                }
                // online roofline calibration: workers stream dispatch
                // observations here; the metrics report folds fitted
                // models back into the plan cache (apply_calibration)
                let calibration = FleetCalibration::with_window(config.calib_window);
                Some(FleetRouter::with_calibration(fleet, plans, calibration))
            }
            None => None,
        };
        let classes: Vec<(String, usize)> = match &router {
            Some(r) => r
                .fleet()
                .classes
                .iter()
                .map(|c| (c.device.name.to_string(), c.count))
                .collect(),
            None => vec![("default".to_string(), config.num_workers)],
        };

        // per-class dispatch observers: each fleet worker reports every
        // dispatch's (modeled work signature, measured wall) into the
        // shared calibration windows, and starts with the planner's
        // W8A8 verdict for its default-variant plan applied to its
        // device's activation-quant toggle
        let observers: Vec<Option<(DispatchObserver, bool)>> = match &router {
            Some(r) => r
                .fleet()
                .classes
                .iter()
                .map(|c| {
                    let mut sigs = BTreeMap::new();
                    let mut w8a8 = false;
                    for &v in crate::planner::model::VARIANTS {
                        if let Ok(p) = r.plans().plan(&c.device, v) {
                            sigs.insert(
                                v.to_string(),
                                [p.text_sig, p.unet_sig, p.decode_sig],
                            );
                            if variant == v {
                                w8a8 = p.w8a8;
                            }
                        }
                    }
                    r.calibration().map(|cal| {
                        (
                            DispatchObserver {
                                sink: cal.clone(),
                                class: c.device.name.to_string(),
                                base: c.device.delegate.clone(),
                                sigs,
                            },
                            w8a8,
                        )
                    })
                })
                .collect(),
            None => vec![None; classes.len()],
        };

        // NOTE: every class's workers construct the same executor —
        // on real hardware a worker *is* its device, so the class
        // difference is physical; on the stub/PJRT backend there is
        // one substrate and the class only drives routing, admission
        // and the predicted-vs-actual accounting.  Per-class |rel err|
        // therefore measures the cost model against the *deployed*
        // substrate, which on the stub is expected to be large for
        // the slow classes.
        // one host-artifact store for the whole fleet: no matter how
        // many workers spin up (or how often they evict and reload),
        // each (component, tag) is read from disk once per process
        let store = Arc::new(ArtifactStore::new());
        let worker_store = Arc::clone(&store);
        let max_batch = config.max_batch;
        let device_mem_mb = config.device_mem_mb;

        // deterministic fault injection: a seeded plan installed on
        // every worker's device stats (each worker draws from the same
        // seed, so a fixed (config, submission order) replays the same
        // failures).  Empty plans are not installed at all.
        let fault_plan = {
            let seed = config.fault_seed.unwrap_or(0);
            let mut plan = match &config.fault_spec {
                Some(spec) => xla::FaultPlan::parse(spec, seed)
                    .map_err(|e| Error::Config(format!("fault spec: {e}")))?,
                None => xla::FaultPlan::seeded(seed),
            };
            if config.fault_rate > 0.0 {
                plan = plan.transient_dispatch_rate(config.fault_rate);
            }
            if plan.is_empty() { None } else { Some(plan) }
        };

        // the governor's shipped per-class budget: the worst-case
        // modeled resident peak across the class's priced plans, or
        // the configured executor budget for homogeneous pools.  OOMs
        // shrink the learned budget below this; sustained success
        // probes it back up (never past shipped).
        let shipped: Vec<usize> = match &router {
            Some(r) => r
                .fleet()
                .classes
                .iter()
                .map(|c| {
                    crate::planner::model::VARIANTS
                        .iter()
                        .filter_map(|v| r.plans().plan(&c.device, v).ok())
                        .map(|p| p.peak_memory)
                        .max()
                        .unwrap_or(usize::MAX)
                })
                .collect(),
            None => vec![options.memory_budget; classes.len()],
        };
        let pressure = Arc::new(PressureGovernor::new(shipped, PressureOptions::default()));

        let supervision = SupervisionOptions {
            retry_limit: config.retry_limit as u32,
            retry_backoff: Duration::from_millis(config.retry_backoff_ms),
            breaker: Some(Arc::new(CircuitBreaker::new(
                classes.len(),
                config.breaker_threshold,
                Duration::from_millis(config.breaker_cooldown_ms),
            ))),
            pressure: Some(Arc::clone(&pressure)),
            metrics_window: config.calib_window,
            ..SupervisionOptions::default()
        };

        let pool = WorkerPool::start_supervised(
            &classes,
            config.queue_depth,
            config.max_batch,
            config.continuous,
            supervision,
            move |_wid, class: usize, _name: &str| {
                let mut executor = PipelinedExecutor::with_store(
                    manifest.clone(),
                    options.clone(),
                    Arc::clone(&worker_store),
                )?;
                if let Some(plan) = &fault_plan {
                    executor.engine.device_stats().set_fault_plan(Some(plan.clone()));
                }
                // capacity-accounted device memory: live buffer bytes
                // are charged against this cap and allocations beyond
                // it fail with a real (uninjected) OOM
                if let Some(mb) = device_mem_mb {
                    executor
                        .engine
                        .device_stats()
                        .set_device_mem(Some((mb * 1e6) as u64));
                }
                if let Some(Some((obs, w8a8))) = observers.get(class) {
                    executor.set_observer(obs.clone());
                    if *w8a8 {
                        executor.engine.device_stats().set_activation_quant(true);
                    }
                }
                Ok(PipelineWorker {
                    executor,
                    default_variant: variant.clone(),
                    max_batch,
                    base_batch: max_batch,
                })
            },
        )?;
        Ok(Server {
            pool,
            next_id: 0,
            default_variant: config.variant.clone(),
            default_steps: config.num_steps,
            default_sampler,
            router,
            pressure,
            store,
        })
    }

    /// Enqueue a generation with default scheduling (normal priority,
    /// no deadline, configured step count).
    pub fn submit(&mut self, prompt: &str, seed: u64) -> Result<ResponseReceiver> {
        self.submit_with(prompt, seed, SubmitOptions::default())
    }

    /// Enqueue a generation with explicit scheduling directives and
    /// per-request overrides.  Admission control may reject it
    /// immediately: queue full, or (in a planned fleet) a deadline no
    /// device class can meet.
    pub fn submit_with(
        &mut self,
        prompt: &str,
        seed: u64,
        opts: SubmitOptions,
    ) -> Result<ResponseReceiver> {
        // degrading admission, last line: when *every* device class is
        // quarantined, queueing more work just ages in a queue nothing
        // drains — shed everything except high-priority load (which
        // rides the breakers' half-open probes back to health)
        if let Some(b) = self.pool.breaker() {
            if b.all_degraded() && opts.priority != Priority::High {
                self.pool.record_shed();
                return Err(Error::Queue(
                    "every device class is degraded; load shed".into(),
                ));
            }
        }
        self.next_id += 1;
        let mut req = GenerateRequest::new(self.next_id, prompt, seed);
        req.num_steps = opts.num_steps;
        // resolve the variant at admission so the queue's batch key
        // groups "explicit default" with "no override" requests
        req.variant = opts
            .variant
            .clone()
            .or_else(|| Some(self.default_variant.clone()));
        req.guidance_scale = opts.guidance_scale;
        // validate + resolve the sampler at admission, like the
        // variant: an unknown token is a config error before anything
        // queues, and "explicit default" groups with "no override"
        let sampler = match &opts.sampler {
            Some(token) => Sampler::parse(token).ok_or_else(|| {
                Error::Config(format!(
                    "unknown sampler {token:?} (expected one of: {})",
                    Sampler::names().join(", ")
                ))
            })?,
            None => self.default_sampler,
        };
        req.sampler = Some(sampler);
        match &self.router {
            Some(router) => {
                let variant = req
                    .variant
                    .clone()
                    .unwrap_or_else(|| self.default_variant.clone());
                // price the request at the sampler's *effective* step
                // count: a distilled 8-step schedule routes (and is
                // deadline-checked) as 8 steps even when the configured
                // count is 50 — this is what makes tight deadlines
                // feasible for few-step requests
                let steps = sampler
                    .effective_steps(req.num_steps.unwrap_or(self.default_steps));
                // measured-load feedback: once a (class, variant) has
                // served enough requests, its observed per-request
                // overhead replaces the plan's modeled constant here
                let pool = &self.pool;
                let observed = |class: usize| {
                    pool.with_metrics(|m| {
                        m.classes
                            .get(class)
                            .and_then(|c| c.observed_overhead_s(&variant))
                    })
                };
                // quarantined classes are routed around; high-priority
                // requests ignore the breakers (they are the half-open
                // probe traffic that re-admits a recovered class)
                let breaker = self.pool.breaker();
                let admit = |class: usize| match breaker {
                    Some(b) if opts.priority != Priority::High => b.admits(class),
                    _ => true,
                };
                // learned memory headroom: a class that has OOM'd gets
                // its governor budget enforced at admission, so plans
                // that cannot fit are rerouted (or refused) instead of
                // discovered mid-denoise
                let gov = &self.pressure;
                let headroom = |class: usize| match gov.effective_budget(class) {
                    usize::MAX => None,
                    b => Some(b),
                };
                match router.route_pressure_filtered(
                    &variant,
                    steps,
                    opts.deadline,
                    &observed,
                    &admit,
                    &headroom,
                ) {
                    Ok(route) => {
                        let rx = self.pool.submit_routed(
                            req,
                            opts.priority,
                            opts.deadline,
                            route.class,
                            Some(route.predicted_s),
                        )?;
                        self.pool.record_sampler(sampler.name());
                        Ok(rx)
                    }
                    Err(e) => {
                        // only genuine infeasibility counts toward the
                        // metric; config errors (unknown variant) don't
                        if matches!(e, Error::Queue(_)) {
                            self.pool.record_rejected_infeasible();
                        }
                        Err(e)
                    }
                }
            }
            None => {
                let rx = self.pool.submit(req, opts.priority, opts.deadline)?;
                self.pool.record_sampler(sampler.name());
                Ok(rx)
            }
        }
    }

    /// Blocking convenience wrapper.
    pub fn generate(&mut self, prompt: &str, seed: u64) -> Result<GenerateResponse> {
        self.generate_with(prompt, seed, SubmitOptions::default())
    }

    /// Blocking convenience wrapper with scheduling options.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        seed: u64,
        opts: SubmitOptions,
    ) -> Result<GenerateResponse> {
        self.submit_with(prompt, seed, opts)?
            .recv()
            .map_err(|_| Error::Runtime("worker dropped request".into()))?
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    pub fn queue_depth(&self) -> usize {
        self.pool.queue_depth()
    }

    /// The admission router, when this server fronts a planned fleet.
    pub fn router(&self) -> Option<&FleetRouter> {
        self.router.as_ref()
    }

    /// The per-class circuit breakers behind degrading admission
    /// (tests, dashboards, operator kill switch via `trip_now`).
    pub fn breaker(&self) -> Option<&Arc<CircuitBreaker>> {
        self.pool.breaker()
    }

    /// The fleet-shared host-artifact store (tests, dashboards).
    pub fn artifact_store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// The per-class memory-pressure governor (tests, dashboards).
    pub fn pressure(&self) -> &Arc<PressureGovernor> {
        &self.pressure
    }

    pub fn metrics_report(&self) -> Result<String> {
        let mut out = self.pool.metrics_report();
        // memory pressure: only interesting once something OOM'd (or a
        // ladder is still unwinding); a quiet fleet stays quiet
        if self.pressure.any_pressure() {
            out.push_str(&self.pressure.status_line(self.pool.class_names()));
        }
        out.push_str(&format!(
            "artifact store: {} cached, {} disk loads, {} hits\n",
            self.store.cached(),
            self.store.disk_loads(),
            self.store.hits(),
        ));
        if let Some(router) = &self.router {
            // fold the live calibration stream into the plan cache and
            // report what was re-planned because of it
            for line in router.apply_calibration() {
                out.push_str(&line);
                out.push('\n');
            }
            // predicted-vs-actual drift per class: how far the fitted
            // roofline has moved from the shipped constants
            if let Some(cal) = router.calibration() {
                for name in cal.class_names() {
                    if let Some(p) = cal.profile(&name) {
                        out.push_str(&format!(
                            "calibration {name}: {} obs, {}/6 classes fitted, \
                             divergence from shipped {:.0}%\n",
                            cal.observations(&name),
                            p.fitted_classes(),
                            p.divergence() * 100.0,
                        ));
                    }
                }
            }
            // the cost-gated pass schedule each (device class, variant)
            // plan settled on — what the fleet actually runs per class
            for plan in router.plans().cached() {
                out.push_str(&format!(
                    "pass schedule {}/{}: {}{}{}\n",
                    plan.device,
                    plan.variant,
                    crate::planner::schedule_display(&plan.unet_passes),
                    if plan.w8a8 { ", w8a8 on" } else { "" },
                    if plan.calibrated { " (calibrated)" } else { "" },
                ));
            }
        }
        Ok(out)
    }

    /// Read-only access to the pool metrics (dashboards, benches).
    pub fn with_metrics<R>(
        &self,
        f: impl FnOnce(&crate::coordinator::metrics::PoolMetrics) -> R,
    ) -> R {
        self.pool.with_metrics(f)
    }
}
