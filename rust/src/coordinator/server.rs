//! The serving front door: admission queue + worker pool + pipelined
//! executors.
//!
//! `Server::start` parses the artifact manifest once (fail-fast on the
//! caller thread), then brings up a [`WorkerPool`] of
//! `config.num_workers` workers.  Each worker thread constructs its own
//! [`PipelinedExecutor`] — PJRT handles are not `Send`, so engine,
//! residency cache and memory budget are per worker, modelling a fleet
//! of single-device phones behind one queue.
//!
//! Requests carry per-submission scheduling directives (priority,
//! deadline) and execution overrides (step count, variant, guidance)
//! that are honored end-to-end: `SubmitOptions` -> `GenerateRequest` ->
//! `ExecOverrides` -> the denoise loop.

use crate::config::AppConfig;
use crate::coordinator::pool::{ResponseReceiver, WorkerExecutor, WorkerPool};
use crate::coordinator::request::{GenerateRequest, GenerateResponse, SubmitOptions};
use crate::error::{Error, Result};
use crate::pipeline::{BatchRequest, GenerateResult, PipelinedExecutor};
use crate::runtime::Manifest;

/// Adapts a [`PipelinedExecutor`] to the pool's worker interface,
/// applying per-request overrides against the configured defaults.
struct PipelineWorker {
    executor: PipelinedExecutor,
    default_variant: String,
}

impl WorkerExecutor for PipelineWorker {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
        self.executor
            .generate_with(&req.prompt, req.seed, &self.default_variant, &req.overrides())
    }

    /// A compatible batch shares one CFG-batched UNet dispatch per
    /// denoise step (see `pipeline::batch`).
    fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
        let batch: Vec<BatchRequest> = reqs
            .iter()
            .map(|r| BatchRequest {
                prompt: r.prompt.clone(),
                seed: r.seed,
                overrides: r.overrides(),
            })
            .collect();
        self.executor.generate_batch(&batch, &self.default_variant)
    }
}

pub struct Server {
    pool: WorkerPool,
    next_id: u64,
    default_variant: String,
}

impl Server {
    /// Start the worker pool; fails fast if the artifacts are
    /// unreadable or any worker cannot construct its executor.
    pub fn start(config: &AppConfig) -> Result<Server> {
        // parse the manifest on the caller thread for early errors
        let manifest = Manifest::load(&config.artifacts_dir)?;
        let options = config.exec_options();
        let variant = config.variant.clone();

        let pool = WorkerPool::start_batched(
            config.num_workers,
            config.queue_depth,
            config.max_batch,
            move |_wid| {
                let executor = PipelinedExecutor::new(manifest.clone(), options.clone())?;
                Ok(PipelineWorker { executor, default_variant: variant.clone() })
            },
        )?;
        Ok(Server { pool, next_id: 0, default_variant: config.variant.clone() })
    }

    /// Enqueue a generation with default scheduling (normal priority,
    /// no deadline, configured step count).
    pub fn submit(&mut self, prompt: &str, seed: u64) -> Result<ResponseReceiver> {
        self.submit_with(prompt, seed, SubmitOptions::default())
    }

    /// Enqueue a generation with explicit scheduling directives and
    /// per-request overrides.  Admission control may reject it
    /// immediately (queue full).
    pub fn submit_with(
        &mut self,
        prompt: &str,
        seed: u64,
        opts: SubmitOptions,
    ) -> Result<ResponseReceiver> {
        self.next_id += 1;
        let mut req = GenerateRequest::new(self.next_id, prompt, seed);
        req.num_steps = opts.num_steps;
        // resolve the variant at admission so the queue's batch key
        // groups "explicit default" with "no override" requests
        req.variant = opts
            .variant
            .clone()
            .or_else(|| Some(self.default_variant.clone()));
        req.guidance_scale = opts.guidance_scale;
        self.pool.submit(req, opts.priority, opts.deadline)
    }

    /// Blocking convenience wrapper.
    pub fn generate(&mut self, prompt: &str, seed: u64) -> Result<GenerateResponse> {
        self.generate_with(prompt, seed, SubmitOptions::default())
    }

    /// Blocking convenience wrapper with scheduling options.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        seed: u64,
        opts: SubmitOptions,
    ) -> Result<GenerateResponse> {
        self.submit_with(prompt, seed, opts)?
            .recv()
            .map_err(|_| Error::Runtime("worker dropped request".into()))?
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers()
    }

    pub fn queue_depth(&self) -> usize {
        self.pool.queue_depth()
    }

    pub fn metrics_report(&self) -> Result<String> {
        Ok(self.pool.metrics_report())
    }

    /// Read-only access to the pool metrics (dashboards, benches).
    pub fn with_metrics<R>(
        &self,
        f: impl FnOnce(&crate::coordinator::metrics::PoolMetrics) -> R,
    ) -> R {
        self.pool.with_metrics(f)
    }
}
