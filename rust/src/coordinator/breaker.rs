//! Per-device-class circuit breakers: the degrading-admission half of
//! the failure domain.
//!
//! Workers feed each class's breaker with the faults they observe
//! (transient device errors, engine rebuilds after a panic or device
//! loss); admission consults it before routing.  The state machine per
//! class:
//!
//! * **Closed** — healthy.  `threshold` *consecutive* faults trip the
//!   class (any success resets the streak, so a steady trickle of
//!   retried-and-recovered faults never quarantines a mostly-healthy
//!   device).
//! * **Open** — quarantined for `cooldown`; admission routes around
//!   the class ([`crate::planner::FleetRouter::route_observed_filtered`]).
//! * **Half-open** — the cooldown elapsed; the class admits again as a
//!   probe.  The first success closes it, the first fault re-trips it
//!   for another cooldown.
//!
//! `admits` is a pure read (no state transition), so admission paths
//! can consult it as a filter predicate any number of times without
//! consuming probes; the transitions ride on the recorded outcomes.
//! When *every* class is quarantined the server sheds all but the
//! highest-priority load instead of queueing work no device will take
//! (see `Server::submit_with`).

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Observable state of one class's breaker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug, Default)]
struct ClassState {
    /// faults since the last success (trip trigger)
    streak: u32,
    /// quarantined until this instant; `None` = closed
    open_until: Option<Instant>,
    /// total faults ever recorded against the class
    faults: u64,
    /// times the class has been quarantined
    trips: u64,
}

/// One breaker per device class, shared between the pool's workers
/// (producers) and the server's admission path (consumer).
#[derive(Debug)]
pub struct CircuitBreaker {
    classes: Vec<Mutex<ClassState>>,
    threshold: u32,
    cooldown: Duration,
}

impl CircuitBreaker {
    /// `threshold` consecutive faults quarantine a class for
    /// `cooldown` (both clamped to sane minimums).
    pub fn new(num_classes: usize, threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            classes: (0..num_classes.max(1)).map(|_| Mutex::default()).collect(),
            threshold: threshold.max(1),
            cooldown,
        }
    }

    fn state_of(s: &ClassState) -> BreakerState {
        match s.open_until {
            None => BreakerState::Closed,
            Some(u) if Instant::now() < u => BreakerState::Open,
            Some(_) => BreakerState::HalfOpen,
        }
    }

    /// One observed fault (transient device error, injected or real).
    /// Trips the class at `threshold` consecutive faults; re-trips a
    /// half-open class immediately (the probe failed).
    pub fn record_fault(&self, class: usize) {
        let Some(m) = self.classes.get(class) else { return };
        let mut s = m.lock().unwrap();
        s.faults += 1;
        match Self::state_of(&s) {
            BreakerState::Open => {}
            BreakerState::HalfOpen => {
                s.open_until = Some(Instant::now() + self.cooldown);
                s.trips += 1;
            }
            BreakerState::Closed => {
                s.streak += 1;
                if s.streak >= self.threshold {
                    s.open_until = Some(Instant::now() + self.cooldown);
                    s.trips += 1;
                    s.streak = 0;
                }
            }
        }
    }

    /// A worker engine rebuild (panic or device loss) — serious enough
    /// to quarantine the class immediately, no streak required.
    pub fn record_restart(&self, class: usize) {
        let Some(m) = self.classes.get(class) else { return };
        let mut s = m.lock().unwrap();
        s.faults += 1;
        if !matches!(Self::state_of(&s), BreakerState::Open) {
            s.trips += 1;
        }
        s.open_until = Some(Instant::now() + self.cooldown);
        s.streak = 0;
    }

    /// A served request: resets the fault streak; closes a half-open
    /// class (the probe came back healthy).  Ignored while the class
    /// is still inside its cooldown.
    pub fn record_success(&self, class: usize) {
        let Some(m) = self.classes.get(class) else { return };
        let mut s = m.lock().unwrap();
        s.streak = 0;
        if matches!(Self::state_of(&s), BreakerState::HalfOpen) {
            s.open_until = None;
        }
    }

    pub fn state(&self, class: usize) -> BreakerState {
        self.classes
            .get(class)
            .map_or(BreakerState::Closed, |m| Self::state_of(&m.lock().unwrap()))
    }

    /// Whether admission may route to the class: closed and half-open
    /// (probe) classes admit, open ones do not.  Pure — consulting it
    /// never transitions state.
    pub fn admits(&self, class: usize) -> bool {
        !matches!(self.state(class), BreakerState::Open)
    }

    /// Every class is quarantined (still inside its cooldown) — the
    /// shed-load condition.
    pub fn all_degraded(&self) -> bool {
        self.classes
            .iter()
            .all(|m| matches!(Self::state_of(&m.lock().unwrap()), BreakerState::Open))
    }

    pub fn trips(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().trips)
    }

    pub fn faults(&self, class: usize) -> u64 {
        self.classes.get(class).map_or(0, |m| m.lock().unwrap().faults)
    }

    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Force-quarantine a class (tests, operator kill switch).
    pub fn trip_now(&self, class: usize) {
        self.record_restart(class);
    }

    /// One report line, classes labelled by `names` (index order).
    pub fn status_line(&self, names: &[String]) -> String {
        let cells: Vec<String> = self
            .classes
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let s = m.lock().unwrap();
                let name = names.get(i).map(|n| n.as_str()).unwrap_or("?");
                format!(
                    "{name}={} ({} faults, {} trips)",
                    Self::state_of(&s).as_str(),
                    s.faults,
                    s.trips,
                )
            })
            .collect();
        format!("breaker: {}\n", cells.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn breaker(threshold: u32, cooldown_ms: u64) -> CircuitBreaker {
        CircuitBreaker::new(2, threshold, Duration::from_millis(cooldown_ms))
    }

    #[test]
    fn trips_after_consecutive_faults_and_successes_reset_the_streak() {
        let b = breaker(3, 10_000);
        b.record_fault(0);
        b.record_fault(0);
        b.record_success(0); // streak broken
        b.record_fault(0);
        b.record_fault(0);
        assert_eq!(b.state(0), BreakerState::Closed, "streak never hit 3");
        assert!(b.admits(0));
        b.record_fault(0);
        assert_eq!(b.state(0), BreakerState::Open);
        assert!(!b.admits(0));
        assert_eq!(b.trips(0), 1);
        assert_eq!(b.faults(0), 5);
        // the other class is untouched
        assert_eq!(b.state(1), BreakerState::Closed);
        assert!(!b.all_degraded());
    }

    #[test]
    fn cooldown_half_opens_then_success_closes_or_fault_retrips() {
        let b = breaker(1, 20);
        b.record_fault(0);
        assert_eq!(b.state(0), BreakerState::Open);
        thread::sleep(Duration::from_millis(30));
        assert_eq!(b.state(0), BreakerState::HalfOpen);
        assert!(b.admits(0), "half-open admits a probe");
        // probe fails: straight back to open
        b.record_fault(0);
        assert_eq!(b.state(0), BreakerState::Open);
        assert_eq!(b.trips(0), 2);
        thread::sleep(Duration::from_millis(30));
        // probe succeeds: closed again
        b.record_success(0);
        assert_eq!(b.state(0), BreakerState::Closed);
    }

    #[test]
    fn restarts_quarantine_immediately_and_all_degraded_sheds() {
        let b = breaker(100, 10_000);
        b.record_restart(0);
        assert_eq!(b.state(0), BreakerState::Open, "no streak needed");
        assert!(!b.all_degraded(), "class 1 still healthy");
        b.trip_now(1);
        assert!(b.all_degraded());
        let line = b.status_line(&["fast".to_string(), "slow".to_string()]);
        assert!(line.contains("fast=open"), "{line}");
        assert!(line.contains("slow=open"), "{line}");
    }

    #[test]
    fn out_of_range_classes_are_ignored_not_panics() {
        let b = breaker(1, 10);
        b.record_fault(9);
        b.record_success(9);
        b.record_restart(9);
        assert_eq!(b.trips(9), 0);
        assert!(b.admits(9), "unknown classes default to admitting");
    }

    #[test]
    fn success_during_cooldown_does_not_close_early() {
        let b = breaker(1, 10_000);
        b.record_fault(0);
        b.record_success(0);
        assert_eq!(b.state(0), BreakerState::Open, "cooldown is served in full");
    }
}
