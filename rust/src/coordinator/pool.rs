//! The multi-worker scheduler: N device workers draining one
//! admission-controlled job queue.
//!
//! PJRT handles are not `Send`, so each worker thread *constructs* its
//! own executor via the factory it is handed (engine, residency cache
//! and all) and owns it for the pool's lifetime — the fleet-of-phones
//! model: one worker ~= one device, each with its own memory budget.
//! Only the queue and the metrics are shared.
//!
//! The pool is generic over [`WorkerExecutor`] so scheduling behaviour
//! (fairness, admission, deadline drops, per-request overrides) is
//! testable with mock executors and no device at all.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::PoolMetrics;
use crate::coordinator::queue::{AdmissionError, JobQueue, Priority};
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::error::{Error, Result};
use crate::pipeline::GenerateResult;

/// What a pool worker runs for each job.  Implemented by the pipelined
/// executor wrapper in the server, and by mocks in tests.
pub trait WorkerExecutor {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult>;

    /// Run a compatible micro-batch in one go, returning one result
    /// per request in order.  The default runs them sequentially;
    /// batching executors (the pipelined executor) override this to
    /// share one CFG-batched UNet dispatch per denoise step.
    fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
        reqs.iter().map(|r| self.execute(r)).collect()
    }
}

/// Channel on which a submitted request's response arrives.
pub type ResponseReceiver = mpsc::Receiver<Result<GenerateResponse>>;

/// A queued request plus the channel its response goes to.
pub struct WorkItem {
    pub req: GenerateRequest,
    pub reply: mpsc::Sender<Result<GenerateResponse>>,
    /// worker class this job was routed to (0 in homogeneous pools);
    /// only workers of that class will drain it
    pub class: usize,
    /// plan-predicted service time from admission routing, if any
    pub predicted_s: Option<f64>,
}

/// Handle to a running worker pool.
pub struct WorkerPool {
    queue: Arc<JobQueue<WorkItem>>,
    metrics: Arc<Mutex<PoolMetrics>>,
    /// device-class name per class index ("default" when homogeneous)
    class_names: Vec<String>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Start `num_workers` workers (min 1) that run one request at a
    /// time.  `factory(worker_id)` runs *on the worker thread* to build
    /// its executor; any factory error aborts startup.
    pub fn start<E, F>(num_workers: usize, queue_capacity: usize, factory: F) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_batched(num_workers, queue_capacity, 1, factory)
    }

    /// Start a pool whose workers drain micro-batches: each dequeue
    /// takes up to `max_batch` *compatible* queued requests (same
    /// variant) and hands them to the executor as one batch.  Workers
    /// never wait for a batch to fill — whatever is compatible at pop
    /// time rides along.
    pub fn start_batched<E, F>(
        num_workers: usize,
        queue_capacity: usize,
        max_batch: usize,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let classes = [("default".to_string(), num_workers.max(1))];
        Self::start_fleet(
            &classes,
            queue_capacity,
            max_batch,
            move |wid, _class: usize, _name: &str| factory(wid),
        )
    }

    /// Start a heterogeneous pool: one worker class per `(name, count)`
    /// entry, in order (the class index the router targets is the
    /// position in this slice).  Workers drain only jobs routed to
    /// their own class.  `factory(worker_id, class_index, class_name)`
    /// runs on the worker thread.
    pub fn start_fleet<E, F>(
        classes: &[(String, usize)],
        queue_capacity: usize,
        max_batch: usize,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize, usize, &str) -> Result<E> + Send + Sync + 'static,
    {
        let max_batch = max_batch.max(1);
        let class_names: Vec<String> = classes.iter().map(|(n, _)| n.clone()).collect();
        // (worker id, class index) assignments, classes in spec order
        let mut assignments: Vec<usize> = Vec::new();
        for (class_idx, (_, count)) in classes.iter().enumerate() {
            for _ in 0..(*count).max(1) {
                assignments.push(class_idx);
            }
        }
        let n = assignments.len();
        let queue: Arc<JobQueue<WorkItem>> = Arc::new(JobQueue::new(queue_capacity));
        let metrics = Arc::new(Mutex::new(PoolMetrics::with_classes(n, &class_names)));
        let factory = Arc::new(factory);

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(n);
        for (wid, &class_idx) in assignments.iter().enumerate() {
            let worker_queue = Arc::clone(&queue);
            let worker_metrics = Arc::clone(&metrics);
            let worker_factory = Arc::clone(&factory);
            let worker_ready = ready_tx.clone();
            let class_name = class_names[class_idx].clone();
            let spawned = thread::Builder::new()
                .name(format!("md-worker-{wid}"))
                .spawn(move || {
                    let executor = match worker_factory(wid, class_idx, &class_name) {
                        Ok(e) => {
                            let _ = worker_ready.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = worker_ready.send(Err(e));
                            return;
                        }
                    };
                    drop(worker_ready);
                    worker_loop(
                        wid,
                        class_idx,
                        &class_name,
                        executor,
                        &worker_queue,
                        &worker_metrics,
                        max_batch,
                    );
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // unblock and reap the workers already running
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Runtime(format!("spawn worker {wid}: {e}")));
                }
            }
        }
        drop(ready_tx);

        let pool = WorkerPool { queue, metrics, class_names, handles };
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    // pool drop closes the queue and joins the healthy workers
                    return Err(e);
                }
                Err(_) => {
                    return Err(Error::Runtime("worker died during startup".into()));
                }
            }
        }
        Ok(pool)
    }

    /// Admit a request; returns the receiver its response will arrive
    /// on, or an admission error when the queue is full/closed.
    pub fn submit(
        &self,
        req: GenerateRequest,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<ResponseReceiver> {
        self.submit_routed(req, priority, deadline, 0, None)
    }

    /// Admit a request onto a specific worker class (planner routing),
    /// carrying the plan-predicted service time the admission decision
    /// was based on.
    pub fn submit_routed(
        &self,
        req: GenerateRequest,
        priority: Priority,
        deadline: Option<Duration>,
        class: usize,
        predicted_s: Option<f64>,
    ) -> Result<ResponseReceiver> {
        if class >= self.class_names.len() {
            return Err(Error::Queue(format!(
                "no worker class {class} (pool has {})",
                self.class_names.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let absolute = deadline.map(|d| Instant::now() + d);
        let item = WorkItem { req, reply: tx, class, predicted_s };
        match self.queue.push(item, priority, absolute) {
            Ok(()) => Ok(rx),
            Err(e) => {
                if matches!(e, AdmissionError::Full { .. }) {
                    self.metrics.lock().unwrap().record_rejected_full();
                }
                Err(Error::Queue(e.to_string()))
            }
        }
    }

    /// Count one admission-time infeasible-deadline rejection (the
    /// router decided before anything was queued).
    pub fn record_rejected_infeasible(&self) {
        self.metrics.lock().unwrap().record_rejected_infeasible();
    }

    pub fn num_workers(&self) -> usize {
        self.handles.len()
    }

    /// Device-class names, pool class-index order.
    pub fn class_names(&self) -> &[String] {
        &self.class_names
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Fleet report: counters, queue depth, latency percentiles,
    /// per-worker utilization, stage breakdown.
    pub fn metrics_report(&self) -> String {
        self.metrics
            .lock()
            .unwrap()
            .report(self.queue.depth(), self.queue.max_depth())
    }

    /// Read-only access to the shared metrics (tests, dashboards).
    pub fn with_metrics<R>(&self, f: impl FnOnce(&PoolMetrics) -> R) -> R {
        f(&self.metrics.lock().unwrap())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<E: WorkerExecutor>(
    wid: usize,
    class_idx: usize,
    class_name: &str,
    mut executor: E,
    queue: &JobQueue<WorkItem>,
    metrics: &Mutex<PoolMetrics>,
    max_batch: usize,
) {
    // a worker drains only jobs routed to its own device class; batch
    // compatibility within the class: same requested variant (the
    // executor re-checks and re-groups defensively)
    while let Some(jobs) = queue.pop_batch_where(
        max_batch,
        |it: &WorkItem| it.class == class_idx,
        |it: &WorkItem| it.req.variant.clone(),
    ) {
        let mut reqs: Vec<GenerateRequest> = Vec::with_capacity(jobs.len());
        let mut meta: Vec<(mpsc::Sender<Result<GenerateResponse>>, f64, Option<f64>)> =
            Vec::with_capacity(jobs.len());
        for job in jobs {
            let queue_s = job.enqueued.elapsed().as_secs_f64();
            let WorkItem { req, reply, predicted_s, .. } = job.item;

            // deadline-aware: don't burn a device slot on an expired
            // request (its batchmates still run)
            if let Some(d) = job.deadline {
                if Instant::now() > d {
                    metrics.lock().unwrap().record_rejected_deadline();
                    let _ = reply.send(Err(Error::Queue(format!(
                        "request {} expired after {queue_s:.3}s in queue",
                        req.id
                    ))));
                    continue;
                }
            }
            reqs.push(req);
            meta.push((reply, queue_s, predicted_s));
        }
        if reqs.is_empty() {
            continue;
        }
        let occupancy = reqs.len();
        metrics.lock().unwrap().record_batch(occupancy);

        let t0 = Instant::now();
        let mut results = executor.execute_batch(&reqs);
        let wall_s = t0.elapsed().as_secs_f64();
        let busy_share_s = wall_s / occupancy as f64;
        let got = results.len();
        if got != reqs.len() {
            // defensive: a misbehaving executor must not strand callers
            results = reqs
                .iter()
                .map(|r| {
                    Err(Error::Runtime(format!(
                        "executor returned {got} results for a batch of {} (request {})",
                        reqs.len(),
                        r.id
                    )))
                })
                .collect();
        }

        for ((req, (reply, queue_s, predicted_s)), result) in
            reqs.into_iter().zip(meta).zip(results)
        {
            let resp = match result {
                Ok(r) => {
                    let mut m = metrics.lock().unwrap();
                    m.record_batch_member(
                        wid,
                        queue_s,
                        wall_s,
                        busy_share_s,
                        Some(&r.timings),
                    );
                    // plan accountability: predicted vs measured
                    // service time, per device class.  The measured
                    // side is the member's share of the batch wall —
                    // the plan predicts one request's service, so a
                    // shared dispatch must not be charged B times.
                    // Failures are excluded: an early error's
                    // microsecond wall would read as huge model
                    // drift when the model was never exercised.
                    if let Some(p) = predicted_s {
                        m.record_prediction(class_idx, p, busy_share_s);
                    }
                    // measured-load feedback: the member's share of the
                    // batch's non-denoise time (its busy share minus
                    // its own denoise share — `total_s` is the whole
                    // batch wall and would overcharge B-fold, the same
                    // trap record_prediction avoids) is the observed
                    // analog of the plan's overhead term; the router
                    // swaps the modeled constant for this mean once
                    // the (class, variant) has served enough requests
                    m.record_class_overhead(
                        class_idx,
                        req.variant.as_deref().unwrap_or("default"),
                        busy_share_s - r.timings.denoise_s,
                    );
                    drop(m);
                    Ok(GenerateResponse {
                        id: req.id,
                        image: r.image,
                        image_size: r.image_size,
                        latent: r.latent,
                        timings: r.timings,
                        peak_memory: r.peak_memory,
                        queue_s,
                        worker_id: wid,
                        device_class: class_name.to_string(),
                        predicted_s,
                    })
                }
                Err(e) => {
                    metrics.lock().unwrap().record_batch_member(
                        wid,
                        queue_s,
                        wall_s,
                        busy_share_s,
                        None,
                    );
                    Err(e)
                }
            };
            let _ = reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::StageTimings;

    /// Mock executor: sleeps, then succeeds with the request's step
    /// count echoed into the timings.
    struct SleepExec {
        sleep: Duration,
        default_steps: usize,
    }

    impl WorkerExecutor for SleepExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            thread::sleep(self.sleep);
            let steps = req.num_steps.unwrap_or(self.default_steps);
            Ok(GenerateResult {
                image: vec![0.0; 4],
                image_size: 2,
                latent: vec![req.seed as f32],
                timings: StageTimings {
                    denoise_steps: steps,
                    total_s: self.sleep.as_secs_f64(),
                    ..Default::default()
                },
                peak_memory: 1,
            })
        }
    }

    fn sleep_factory(
        ms: u64,
        default_steps: usize,
    ) -> impl Fn(usize) -> Result<SleepExec> + Send + Sync + 'static {
        move |_| Ok(SleepExec { sleep: Duration::from_millis(ms), default_steps })
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let pool = WorkerPool::start(3, 32, sleep_factory(5, 20)).unwrap();
        let receivers: Vec<_> = (0..9)
            .map(|i| {
                let req = GenerateRequest::new(i, "p", i);
                pool.submit(req, Priority::Normal, None).unwrap()
            })
            .collect();
        let mut workers_seen = std::collections::BTreeSet::new();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(resp.worker_id < 3);
            workers_seen.insert(resp.worker_id);
        }
        assert!(!workers_seen.is_empty());
        let report = pool.metrics_report();
        assert!(report.contains("9 ok"), "{report}");
    }

    #[test]
    fn num_steps_override_reaches_the_executor() {
        let pool = WorkerPool::start(1, 8, sleep_factory(1, 20)).unwrap();
        let mut req = GenerateRequest::new(1, "p", 1);
        req.num_steps = Some(4);
        let rx = pool.submit(req, Priority::Normal, None).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().timings.denoise_steps, 4);
        let rx = pool
            .submit(GenerateRequest::new(2, "p", 2), Priority::Normal, None)
            .unwrap();
        assert_eq!(
            rx.recv().unwrap().unwrap().timings.denoise_steps,
            20,
            "no override -> configured default"
        );
    }

    #[test]
    fn admission_rejection_is_counted() {
        // one slow worker; capacity-1 queue fills while it sleeps
        let pool = WorkerPool::start(1, 1, sleep_factory(150, 20)).unwrap();
        let rx0 = pool
            .submit(GenerateRequest::new(0, "p", 0), Priority::Normal, None)
            .unwrap();
        // give the worker time to pop the first job and start sleeping
        thread::sleep(Duration::from_millis(50));
        let _rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let err = pool
            .submit(GenerateRequest::new(2, "p", 2), Priority::Normal, None)
            .expect_err("queue full");
        assert!(err.to_string().contains("full"), "{err}");
        pool.with_metrics(|m| assert_eq!(m.rejected_full, 1));
        rx0.recv().unwrap().unwrap();
    }

    #[test]
    fn expired_deadlines_are_dropped_not_executed() {
        let pool = WorkerPool::start(1, 8, sleep_factory(100, 20)).unwrap();
        // first job occupies the worker...
        let rx0 = pool
            .submit(GenerateRequest::new(0, "p", 0), Priority::Normal, None)
            .unwrap();
        // let the worker pop the first job before queuing the second,
        // so the deadline is long past when the second is popped
        thread::sleep(Duration::from_millis(30));
        let rx1 = pool
            .submit(
                GenerateRequest::new(1, "p", 1),
                Priority::Normal,
                Some(Duration::from_millis(1)),
            )
            .unwrap();
        rx0.recv().unwrap().unwrap();
        let err = rx1.recv().unwrap().expect_err("expired");
        assert!(err.to_string().contains("expired"), "{err}");
        pool.with_metrics(|m| {
            assert_eq!(m.rejected_deadline, 1);
            assert_eq!(m.stage.requests_ok, 1);
        });
    }

    /// Mock batching executor: records each batch's request ids, gated
    /// so the test controls when each batch runs.
    struct BatchRecordExec {
        started: mpsc::Sender<()>,
        gate: Arc<Mutex<mpsc::Receiver<()>>>,
        batches: Arc<Mutex<Vec<Vec<u64>>>>,
    }

    impl WorkerExecutor for BatchRecordExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            Ok(GenerateResult {
                image: vec![0.0; 4],
                image_size: 2,
                latent: vec![req.seed as f32],
                timings: StageTimings { denoise_steps: 1, ..Default::default() },
                peak_memory: 1,
            })
        }

        fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
            let _ = self.started.send(());
            let _ = self.gate.lock().unwrap().recv();
            self.batches
                .lock()
                .unwrap()
                .push(reqs.iter().map(|r| r.id).collect());
            reqs.iter().map(|r| self.execute(r)).collect()
        }
    }

    #[test]
    fn workers_drain_compatible_batches() {
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let started_tx = Arc::new(Mutex::new(started_tx));
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches2 = Arc::clone(&batches);
        let pool = WorkerPool::start_batched(1, 16, 3, move |_| {
            Ok(BatchRecordExec {
                started: started_tx.lock().unwrap().clone(),
                gate: Arc::clone(&gate_rx),
                batches: Arc::clone(&batches2),
            })
        })
        .unwrap();

        // job 1 occupies the worker (a batch of one)...
        let rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        started_rx.recv().unwrap();
        // ...meanwhile 4 compatible + 1 incompatible requests queue up
        let mut rest = Vec::new();
        for i in 2..=5 {
            rest.push(
                pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                    .unwrap(),
            );
        }
        let mut base = GenerateRequest::new(6, "p", 6);
        base.variant = Some("base".into());
        rest.push(pool.submit(base, Priority::Normal, None).unwrap());

        // four batches will run: [1], [2,3,4], [5], [6]
        for _ in 0..4 {
            gate_tx.send(()).unwrap();
        }
        rx1.recv().unwrap().unwrap();
        for rx in rest {
            rx.recv().unwrap().unwrap();
        }
        // batch 1: the solo head; batch 2: three compatibles (cap 3);
        // then the leftover compatible rides with nothing — the "base"
        // request is incompatible and runs alone
        let seen = batches.lock().unwrap().clone();
        assert_eq!(seen.len(), 4, "{seen:?}");
        assert_eq!(seen[0], vec![1]);
        assert_eq!(seen[1], vec![2, 3, 4]);
        assert_eq!(seen[2], vec![5]);
        assert_eq!(seen[3], vec![6]);

        pool.with_metrics(|m| {
            assert_eq!(m.batches, 4);
            assert_eq!(m.max_batch_occupancy, 3);
            assert_eq!(m.stage.requests_ok, 6);
        });
        let report = pool.metrics_report();
        assert!(report.contains("occupancy"), "{report}");
    }

    #[test]
    fn expired_member_is_dropped_but_batchmates_run() {
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let started_tx = Arc::new(Mutex::new(started_tx));
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches2 = Arc::clone(&batches);
        let pool = WorkerPool::start_batched(1, 16, 4, move |_| {
            Ok(BatchRecordExec {
                started: started_tx.lock().unwrap().clone(),
                gate: Arc::clone(&gate_rx),
                batches: Arc::clone(&batches2),
            })
        })
        .unwrap();

        let rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        started_rx.recv().unwrap();
        // queued while the worker is busy: one with an immediate
        // deadline, one without
        let rx2 = pool
            .submit(
                GenerateRequest::new(2, "p", 2),
                Priority::Normal,
                Some(Duration::from_millis(1)),
            )
            .unwrap();
        let rx3 = pool
            .submit(GenerateRequest::new(3, "p", 3), Priority::Normal, None)
            .unwrap();
        thread::sleep(Duration::from_millis(30)); // let the deadline pass
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();

        rx1.recv().unwrap().unwrap();
        let err = rx2.recv().unwrap().expect_err("expired");
        assert!(err.to_string().contains("expired"), "{err}");
        rx3.recv().unwrap().unwrap();
        let seen = batches.lock().unwrap().clone();
        assert_eq!(seen, vec![vec![1], vec![3]], "request 2 never executed");
        pool.with_metrics(|m| assert_eq!(m.rejected_deadline, 1));
    }

    #[test]
    fn fleet_pool_routes_jobs_to_their_class_and_tracks_predictions() {
        // two classes, one worker each: worker 0 = "fast", worker 1 = "slow"
        let classes = [("fast".to_string(), 1usize), ("slow".to_string(), 1usize)];
        let pool = WorkerPool::start_fleet(&classes, 16, 1, |_wid, class: usize, _name: &str| {
            let ms = if class == 0 { 1 } else { 5 };
            Ok(SleepExec { sleep: Duration::from_millis(ms), default_steps: 2 })
        })
        .unwrap();
        assert_eq!(pool.num_workers(), 2);
        assert_eq!(pool.class_names().to_vec(), vec!["fast".to_string(), "slow".to_string()]);

        let mut rxs = Vec::new();
        for i in 0..4u64 {
            let class = (i % 2) as usize;
            let rx = pool
                .submit_routed(
                    GenerateRequest::new(i, "p", i),
                    Priority::Normal,
                    None,
                    class,
                    Some(0.01),
                )
                .unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.device_class, pool.class_names()[class]);
            assert_eq!(resp.predicted_s, Some(0.01));
            assert_eq!(resp.worker_id, class, "jobs never cross classes");
        }
        pool.with_metrics(|m| {
            assert_eq!(m.classes[0].prediction_count(), 2);
            assert_eq!(m.classes[1].prediction_count(), 2);
            assert!(m.classes[0].error_summary().count > 0);
        });
        let report = pool.metrics_report();
        assert!(report.contains("class fast"), "{report}");
        assert!(report.contains("class slow"), "{report}");

        // a class index the pool doesn't have is rejected outright
        let err = pool
            .submit_routed(GenerateRequest::new(9, "p", 9), Priority::Normal, None, 7, None)
            .expect_err("bad class");
        assert!(err.to_string().contains("class"), "{err}");
    }

    #[test]
    fn homogeneous_pools_never_record_predictions() {
        let pool = WorkerPool::start(1, 4, sleep_factory(1, 2)).unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.device_class, "default");
        assert!(resp.predicted_s.is_none());
        pool.with_metrics(|m| assert_eq!(m.classes[0].prediction_count(), 0));
    }

    #[test]
    fn factory_failure_aborts_startup() {
        let result = WorkerPool::start(2, 8, |wid| {
            if wid == 1 {
                Err(Error::Runtime("no device".into()))
            } else {
                Ok(SleepExec { sleep: Duration::from_millis(1), default_steps: 1 })
            }
        });
        assert!(result.is_err());
    }
}
