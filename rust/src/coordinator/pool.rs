//! The multi-worker scheduler: N device workers draining one
//! admission-controlled job queue.
//!
//! PJRT handles are not `Send`, so each worker thread *constructs* its
//! own executor via the factory it is handed (engine, residency cache
//! and all) and owns it for the pool's lifetime — the fleet-of-phones
//! model: one worker ~= one device, each with its own memory budget.
//! Only the queue and the metrics are shared.
//!
//! Workers run in one of two modes:
//!
//! * **Run-to-completion** — each blocking dequeue takes up to
//!   `max_batch` compatible jobs and executes them as one batch; the
//!   queue is not consulted again until the batch finishes.
//! * **Continuous** ([`WorkerPool::start_fleet_mode`] with
//!   `continuous = true`) — the dequeue *starts a session* and the
//!   worker's control ([`ContinuousControl`] over the shared queue)
//!   keeps scheduling at every denoise-step boundary: compatible
//!   queued jobs join the in-flight batch, finished rows free their
//!   slots for the next joiner, and when the queue head holds a
//!   deadline that cannot wait for a natural leave, a lower-priority
//!   row is checkpointed and requeued (at a bumped priority, so the
//!   preemption is paid back).  Expired jobs are dropped at admission
//!   in both modes.
//!
//! Every worker is *supervised* ([`SupervisionOptions`]): its loop
//! runs under `catch_unwind`, and a panic or a device-lost error
//! rebuilds the executor from the factory (the shared artifact store
//! stays warm) instead of silently shrinking the fleet.  The failure
//! contract callers rely on is **exactly one terminal reply per
//! submitted request**: every dequeued job's reply channel lives in a
//! [`ReplySlot`] drop guard, so even a panic unwinding through a
//! worker body fails the affected requests explicitly rather than
//! stranding their callers on a dead channel.  Transient device
//! faults ([`Error::is_transient`]) are retried with a bounded budget
//! and exponential backoff — a retried job re-enters the queue behind
//! a `not_before` gate and keeps its original priority and deadline.
//! Each class's faults and restarts feed the shared
//! [`CircuitBreaker`] so admission can route around a degrading
//! device class.
//!
//! The pool is generic over [`WorkerExecutor`] so scheduling behaviour
//! (fairness, admission, deadline drops, per-request overrides,
//! supervision) is testable with mock executors and no device at all.

use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::breaker::CircuitBreaker;
use crate::coordinator::metrics::PoolMetrics;
use crate::coordinator::pressure::PressureGovernor;
use crate::coordinator::queue::{AdmissionError, Job, JobQueue, Priority};
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::error::{Error, Result};
use crate::pipeline::{
    BatchKey, BatchRequest, Checkpoint, ContinuousControl, ContinuousJob, GenerateResult,
    LiveRow,
};
use crate::scheduler::Sampler;

/// What a pool worker runs for each job.  Implemented by the pipelined
/// executor wrapper in the server, and by mocks in tests.
pub trait WorkerExecutor {
    fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult>;

    /// Run a compatible micro-batch in one go, returning one result
    /// per request in order.  The default runs them sequentially;
    /// batching executors (the pipelined executor) override this to
    /// share one CFG-batched UNet dispatch per denoise step.
    fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
        reqs.iter().map(|r| self.execute(r)).collect()
    }

    /// Run one continuous session seeded with `jobs`, reporting every
    /// row outcome through `control` (which also feeds joins and
    /// preemption decisions at step boundaries).  The default ignores
    /// the step-boundary machinery and runs the seed jobs as one
    /// run-to-completion batch — mock executors keep their semantics
    /// under a continuous pool; the pipelined executor overrides this
    /// with the real step-level loop.
    fn execute_continuous(
        &mut self,
        jobs: Vec<ContinuousJob>,
        control: &mut dyn ContinuousControl,
    ) -> Result<()> {
        let reqs: Vec<GenerateRequest> = jobs
            .iter()
            .map(|j| {
                let mut r = GenerateRequest::new(j.token, &j.req.prompt, j.req.seed);
                r.num_steps = j.req.overrides.num_steps;
                r.variant = j.req.overrides.variant.clone();
                r.guidance_scale = j.req.overrides.guidance_scale;
                r
            })
            .collect();
        let results = self.execute_batch(&reqs);
        let mut oom: Option<Error> = None;
        for (job, result) in jobs.into_iter().zip(results) {
            match result {
                Err(e) if e.is_oom() => {
                    // hold the row back (it stays tracked in the
                    // control's metadata) and surface the OOM as the
                    // session outcome, so the worker loop degrades the
                    // executor before the row runs again — an OOM'd
                    // plan is never retried verbatim
                    oom = Some(e);
                }
                other => control.complete(job.token, other),
            }
        }
        match oom {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Step down one rung of the memory-degradation ladder after a
    /// device OOM.  `level` is the class's new ladder rung (1-based)
    /// and `effective_budget` the governor's learned byte budget.
    /// Return `Some(description)` when the executor actually changed
    /// something (shrunk batches, shed residency, re-planned under the
    /// reduced budget) — the pool only requeues OOM'd work after a
    /// *changed* plan; a `None` (the default: mocks, executors with
    /// nothing left to give up) fails the work instead of retrying a
    /// plan that just proved too big.
    fn degrade(&mut self, level: u8, effective_budget: usize) -> Option<String> {
        let _ = (level, effective_budget);
        None
    }

    /// Cumulative injected-fault counters from the executor's device
    /// stats: `(transient, fatal, latency spikes)`.  The worker loops
    /// diff these after every dispatch and fold the deltas into the
    /// pool metrics.  The default (mocks, executors without fault
    /// injection) reports nothing.
    fn fault_counts(&self) -> (u64, u64, u64) {
        (0, 0, 0)
    }
}

/// Fault-handling policy for a pool's workers: the retry budget and
/// backoff for transient device errors, the engine-rebuild budget for
/// panics and device loss, and the optional per-class circuit breaker
/// those events feed.
#[derive(Debug, Clone)]
pub struct SupervisionOptions {
    /// transient-failure retries per request (0 = fail on first fault)
    pub retry_limit: u32,
    /// delay before the first retry; doubles per attempt
    pub retry_backoff: Duration,
    /// ceiling on the exponential backoff
    pub retry_backoff_cap: Duration,
    /// executor rebuilds per worker (after a panic or device loss)
    /// before the worker stays down for good
    pub max_restarts: u32,
    /// per-class breaker fed by faults and restarts; `None` disables
    /// degrading admission (the pool still retries and restarts)
    pub breaker: Option<Arc<CircuitBreaker>>,
    /// per-class memory-pressure governor: OOMs climb its degradation
    /// ladder (shrinking seat caps and learned budgets) and sustained
    /// success re-probes upward; `None` means an OOM fails the request
    /// after the executor's one-shot [`WorkerExecutor::degrade`]
    pub pressure: Option<Arc<PressureGovernor>>,
    /// bound on the per-class metric sample windows (`--calib-window`);
    /// also caps the measured-overhead trust threshold
    pub metrics_window: usize,
}

impl Default for SupervisionOptions {
    fn default() -> SupervisionOptions {
        SupervisionOptions {
            retry_limit: 3,
            retry_backoff: Duration::from_millis(25),
            retry_backoff_cap: Duration::from_millis(400),
            max_restarts: 3,
            breaker: None,
            pressure: None,
            metrics_window: crate::coordinator::metrics::MAX_SAMPLES,
        }
    }
}

/// Channel on which a submitted request's response arrives.
pub type ResponseReceiver = mpsc::Receiver<Result<GenerateResponse>>;

/// The caller's reply channel wrapped in a terminal-outcome guard.
///
/// Invariant: every submitted request gets **exactly one** terminal
/// reply.  The first [`send`](Self::send) wins and later sends are
/// ignored (a row cannot be double-completed); dropping the slot
/// without sending — a panic unwinding through a worker, a supervisor
/// giving up on a rebuild — delivers an explicit failure instead of
/// silently disconnecting the channel.  A receiver that went away
/// (caller timed out and dropped its end) is counted, not ignored:
/// it is the silent-leak signal the metrics expose.
pub struct ReplySlot {
    tx: mpsc::Sender<Result<GenerateResponse>>,
    metrics: Arc<Mutex<PoolMetrics>>,
    sent: bool,
}

impl ReplySlot {
    fn new(
        tx: mpsc::Sender<Result<GenerateResponse>>,
        metrics: Arc<Mutex<PoolMetrics>>,
    ) -> ReplySlot {
        ReplySlot { tx, metrics, sent: false }
    }

    /// Deliver the terminal outcome; later calls are no-ops.
    pub fn send(&mut self, resp: Result<GenerateResponse>) {
        if self.sent {
            return;
        }
        self.sent = true;
        if self.tx.send(resp).is_err() {
            // the caller dropped its receiver: nothing to deliver to,
            // but the fact must not vanish
            if let Ok(mut m) = self.metrics.lock() {
                m.record_reply_dropped();
            }
        }
    }
}

impl Drop for ReplySlot {
    fn drop(&mut self) {
        if self.sent {
            return;
        }
        self.sent = true;
        let receiver_gone = self
            .tx
            .send(Err(Error::Runtime(
                "worker died before replying; request abandoned".into(),
            )))
            .is_err();
        // a panicking worker may have poisoned the metrics mutex —
        // never panic inside a drop on the unwind path
        if let Ok(mut m) = self.metrics.lock() {
            m.record_reply_orphaned();
            if receiver_gone {
                m.record_reply_dropped();
            }
        }
    }
}

/// A queued request plus the channel its response goes to.
pub struct WorkItem {
    pub req: GenerateRequest,
    pub reply: ReplySlot,
    /// worker class this job was routed to (0 in homogeneous pools);
    /// only workers of that class will drain it
    pub class: usize,
    /// plan-predicted service time from admission routing, if any
    pub predicted_s: Option<f64>,
    /// preemption checkpoint: `Some` when this job was checkpointed
    /// out of a continuous session and requeued; the next session that
    /// admits it resumes the denoise loop from here instead of
    /// re-encoding and re-seeding
    pub resume: Option<Checkpoint>,
    /// transient-fault retries already spent on this request
    pub attempts: u32,
    /// retry-backoff gate: ineligible for dequeue until this instant
    pub not_before: Option<Instant>,
}

impl WorkItem {
    fn ready(&self) -> bool {
        self.not_before.map_or(true, |t| t <= Instant::now())
    }
}

/// Handle to a running worker pool.
pub struct WorkerPool {
    queue: Arc<JobQueue<WorkItem>>,
    metrics: Arc<Mutex<PoolMetrics>>,
    /// device-class name per class index ("default" when homogeneous)
    class_names: Vec<String>,
    handles: Vec<thread::JoinHandle<()>>,
    breaker: Option<Arc<CircuitBreaker>>,
}

impl WorkerPool {
    /// Start `num_workers` workers (min 1) that run one request at a
    /// time.  `factory(worker_id)` runs *on the worker thread* to build
    /// its executor; any factory error aborts startup.
    pub fn start<E, F>(num_workers: usize, queue_capacity: usize, factory: F) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_batched(num_workers, queue_capacity, 1, factory)
    }

    /// Start a pool whose workers drain micro-batches: each dequeue
    /// takes up to `max_batch` *compatible* queued requests (same
    /// variant) and hands them to the executor as one batch.  Workers
    /// never wait for a batch to fill — whatever is compatible at pop
    /// time rides along.
    pub fn start_batched<E, F>(
        num_workers: usize,
        queue_capacity: usize,
        max_batch: usize,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let classes = [("default".to_string(), num_workers.max(1))];
        Self::start_fleet(
            &classes,
            queue_capacity,
            max_batch,
            move |wid, _class: usize, _name: &str| factory(wid),
        )
    }

    /// Start a heterogeneous pool: one worker class per `(name, count)`
    /// entry, in order (the class index the router targets is the
    /// position in this slice).  Workers drain only jobs routed to
    /// their own class.  `factory(worker_id, class_index, class_name)`
    /// runs on the worker thread.
    pub fn start_fleet<E, F>(
        classes: &[(String, usize)],
        queue_capacity: usize,
        max_batch: usize,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize, usize, &str) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_fleet_mode(classes, queue_capacity, max_batch, false, factory)
    }

    /// [`start_fleet`](Self::start_fleet) with an explicit scheduling
    /// mode: `continuous = false` is run-to-completion batching,
    /// `continuous = true` makes every worker reschedule at denoise-
    /// step boundaries (joins, slot reclamation, deadline-driven
    /// preemption) via [`WorkerExecutor::execute_continuous`].
    pub fn start_fleet_mode<E, F>(
        classes: &[(String, usize)],
        queue_capacity: usize,
        max_batch: usize,
        continuous: bool,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize, usize, &str) -> Result<E> + Send + Sync + 'static,
    {
        Self::start_supervised(
            classes,
            queue_capacity,
            max_batch,
            continuous,
            SupervisionOptions::default(),
            factory,
        )
    }

    /// [`start_fleet_mode`](Self::start_fleet_mode) with an explicit
    /// fault-handling policy.  The factory is kept for the pool's
    /// lifetime: the supervisor re-invokes it (same worker id, class)
    /// to rebuild a worker's executor after a panic or device loss.
    pub fn start_supervised<E, F>(
        classes: &[(String, usize)],
        queue_capacity: usize,
        max_batch: usize,
        continuous: bool,
        supervision: SupervisionOptions,
        factory: F,
    ) -> Result<WorkerPool>
    where
        E: WorkerExecutor + 'static,
        F: Fn(usize, usize, &str) -> Result<E> + Send + Sync + 'static,
    {
        let max_batch = max_batch.max(1);
        let class_names: Vec<String> = classes.iter().map(|(n, _)| n.clone()).collect();
        // (worker id, class index) assignments, classes in spec order
        let mut assignments: Vec<usize> = Vec::new();
        for (class_idx, (_, count)) in classes.iter().enumerate() {
            for _ in 0..(*count).max(1) {
                assignments.push(class_idx);
            }
        }
        let n = assignments.len();
        let queue: Arc<JobQueue<WorkItem>> = Arc::new(JobQueue::new(queue_capacity));
        let window = supervision.metrics_window.max(1);
        let metrics = Arc::new(Mutex::new(PoolMetrics::with_classes_config(
            n,
            &class_names,
            window,
            crate::coordinator::metrics::MIN_OVERHEAD_SAMPLES.min(window),
        )));
        let factory = Arc::new(factory);

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(n);
        for (wid, &class_idx) in assignments.iter().enumerate() {
            let worker_queue = Arc::clone(&queue);
            let worker_metrics = Arc::clone(&metrics);
            let worker_factory = Arc::clone(&factory);
            let worker_ready = ready_tx.clone();
            let worker_supervision = supervision.clone();
            let class_name = class_names[class_idx].clone();
            let spawned = thread::Builder::new()
                .name(format!("md-worker-{wid}"))
                .spawn(move || {
                    let executor = match worker_factory(wid, class_idx, &class_name) {
                        Ok(e) => {
                            let _ = worker_ready.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = worker_ready.send(Err(e));
                            return;
                        }
                    };
                    drop(worker_ready);
                    let rebuild = || worker_factory(wid, class_idx, &class_name);
                    supervise(
                        wid,
                        class_idx,
                        &class_name,
                        executor,
                        &worker_queue,
                        &worker_metrics,
                        max_batch,
                        continuous,
                        &worker_supervision,
                        rebuild,
                    );
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // unblock and reap the workers already running
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Runtime(format!("spawn worker {wid}: {e}")));
                }
            }
        }
        drop(ready_tx);

        let pool = WorkerPool {
            queue,
            metrics,
            class_names,
            handles,
            breaker: supervision.breaker,
        };
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    // pool drop closes the queue and joins the healthy workers
                    return Err(e);
                }
                Err(_) => {
                    return Err(Error::Runtime("worker died during startup".into()));
                }
            }
        }
        Ok(pool)
    }

    /// Admit a request; returns the receiver its response will arrive
    /// on, or an admission error when the queue is full/closed.
    pub fn submit(
        &self,
        req: GenerateRequest,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<ResponseReceiver> {
        self.submit_routed(req, priority, deadline, 0, None)
    }

    /// Admit a request onto a specific worker class (planner routing),
    /// carrying the plan-predicted service time the admission decision
    /// was based on.
    pub fn submit_routed(
        &self,
        req: GenerateRequest,
        priority: Priority,
        deadline: Option<Duration>,
        class: usize,
        predicted_s: Option<f64>,
    ) -> Result<ResponseReceiver> {
        if class >= self.class_names.len() {
            return Err(Error::Queue(format!(
                "no worker class {class} (pool has {})",
                self.class_names.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let absolute = deadline.map(|d| Instant::now() + d);
        let item = WorkItem {
            req,
            reply: ReplySlot::new(tx, Arc::clone(&self.metrics)),
            class,
            predicted_s,
            resume: None,
            attempts: 0,
            not_before: None,
        };
        match self.queue.try_push(item, priority, absolute) {
            Ok(()) => Ok(rx),
            Err((item, e)) => {
                if matches!(e, AdmissionError::Full { .. }) {
                    self.metrics.lock().unwrap().record_rejected_full();
                }
                // the slot never entered the queue: disarm its drop
                // guard so the rejection is the one terminal reply
                let mut item = item;
                item.reply.sent = true;
                Err(Error::Queue(e.to_string()))
            }
        }
    }

    /// Count one admission-time infeasible-deadline rejection (the
    /// router decided before anything was queued).
    pub fn record_rejected_infeasible(&self) {
        self.metrics.lock().unwrap().record_rejected_infeasible();
    }

    /// Count one request shed because every device class is degraded.
    pub fn record_shed(&self) {
        self.metrics.lock().unwrap().record_shed();
    }

    /// Count one admitted request against its resolved sampler.
    pub fn record_sampler(&self, name: &str) {
        self.metrics.lock().unwrap().record_sampler(name);
    }

    /// The shared per-class breaker, when supervision configured one.
    pub fn breaker(&self) -> Option<&Arc<CircuitBreaker>> {
        self.breaker.as_ref()
    }

    pub fn num_workers(&self) -> usize {
        self.handles.len()
    }

    /// Device-class names, pool class-index order.
    pub fn class_names(&self) -> &[String] {
        &self.class_names
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Fleet report: counters, queue depth, latency percentiles,
    /// per-worker utilization, stage breakdown, breaker states.
    pub fn metrics_report(&self) -> String {
        let mut report = self
            .metrics
            .lock()
            .unwrap()
            .report(self.queue.depth(), self.queue.max_depth());
        if let Some(b) = &self.breaker {
            report.push_str(&b.status_line(&self.class_names));
        }
        report
    }

    /// Read-only access to the shared metrics (tests, dashboards).
    pub fn with_metrics<R>(&self, f: impl FnOnce(&PoolMetrics) -> R) -> R {
        f(&self.metrics.lock().unwrap())
    }

    /// Shut down without executing the backlog: close admission, fail
    /// every queued job with an explicit terminal reply, then join the
    /// workers (in-flight batches still finish).  The graceful `Drop`
    /// path instead lets queued jobs drain; this is the
    /// fail-fast path for operators who need the fleet down *now*.
    pub fn shutdown_now(&mut self) {
        self.queue.close();
        // drain before joining: a worker mid-batch will not take these,
        // and failing them first keeps shutdown latency bounded by the
        // in-flight work only
        self.drain_queue();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // a retry requeued behind a backoff gate during the join, or a
        // job belonging to a class whose workers were already gone
        self.drain_queue();
    }

    /// Fail every queued (not yet running) job with a terminal reply.
    fn drain_queue(&self) {
        while let Some(job) = self.queue.try_pop() {
            let mut item = job.item;
            let id = item.req.id;
            item.reply
                .send(Err(Error::Queue(format!("request {id} dropped: pool shut down"))));
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // workers drain queued jobs of their own class before exiting;
        // anything left belongs to a class whose workers gave up (a
        // dead device) or re-entered the queue behind a backoff gate
        // after the workers checked out — fail it, don't strand it
        self.drain_queue();
    }
}

/// How a worker body ended: the queue closed (normal shutdown), or the
/// device handle died and the executor must be rebuilt.
enum LoopExit {
    Closed,
    DeviceLost,
}

/// Dequeue wait for the worker loops.  Short enough that a retry
/// parked behind a `not_before` backoff gate is picked up promptly
/// (the gate matures without any push waking the condvar), long
/// enough to keep an idle fleet's wakeup load trivial.
const RETRY_POLL: Duration = Duration::from_millis(25);

/// Exponential retry backoff: `retry_backoff * 2^(attempt-1)`, capped.
fn backoff_delay(opts: &SupervisionOptions, attempt: u32) -> Duration {
    let shift = attempt.saturating_sub(1).min(16);
    opts.retry_backoff
        .saturating_mul(1u32 << shift)
        .min(opts.retry_backoff_cap)
}

/// Fold the executor's cumulative injected-fault counters into the
/// pool metrics as deltas (the counters survive across batches; the
/// metrics must not double-count).
fn absorb_faults(
    seen: &mut (u64, u64, u64),
    now: (u64, u64, u64),
    metrics: &Mutex<PoolMetrics>,
) {
    let d = (
        now.0.saturating_sub(seen.0),
        now.1.saturating_sub(seen.1),
        now.2.saturating_sub(seen.2),
    );
    *seen = now;
    if d == (0, 0, 0) {
        return;
    }
    if let Ok(mut m) = metrics.lock() {
        m.record_injected(d.0, d.1, d.2);
    }
}

/// The worker supervisor: runs the worker body under `catch_unwind`,
/// rebuilding the executor from the factory after a panic or device
/// loss (at most `opts.max_restarts` times).  The body's [`ReplySlot`]
/// guards guarantee the requests in flight at the moment of a crash
/// were already failed explicitly during the unwind — the supervisor
/// only has to restore capacity, never to reconstruct who was owed an
/// answer.
fn supervise<E: WorkerExecutor>(
    wid: usize,
    class_idx: usize,
    class_name: &str,
    first: E,
    queue: &JobQueue<WorkItem>,
    metrics: &Mutex<PoolMetrics>,
    max_batch: usize,
    continuous: bool,
    opts: &SupervisionOptions,
    rebuild: impl Fn() -> Result<E>,
) {
    let mut executor = Some(first);
    let mut restarts = 0u32;
    loop {
        let exec = match executor.take() {
            Some(e) => e,
            None => match rebuild() {
                Ok(e) => e,
                // the device never came back; the pool's drop drains
                // whatever this class still had queued
                Err(_) => return,
            },
        };
        let body = AssertUnwindSafe(move || {
            if continuous {
                continuous_worker_loop(
                    wid, class_idx, class_name, exec, queue, metrics, max_batch, opts,
                )
            } else {
                worker_loop(wid, class_idx, class_name, exec, queue, metrics, max_batch, opts)
            }
        });
        match panic::catch_unwind(body) {
            Ok(LoopExit::Closed) => return,
            Ok(LoopExit::DeviceLost) | Err(_) => {
                if restarts >= opts.max_restarts {
                    return;
                }
                restarts += 1;
                if let Ok(mut m) = metrics.lock() {
                    m.record_worker_restart();
                }
                if let Some(b) = &opts.breaker {
                    b.record_restart(class_idx);
                }
            }
        }
    }
}

/// Per-member bookkeeping between dequeue and terminal outcome in the
/// run-to-completion loop.
struct RtcMeta {
    reply: ReplySlot,
    queue_s: f64,
    predicted_s: Option<f64>,
    attempts: u32,
    priority: Priority,
    deadline: Option<Instant>,
}

fn worker_loop<E: WorkerExecutor>(
    wid: usize,
    class_idx: usize,
    class_name: &str,
    mut executor: E,
    queue: &JobQueue<WorkItem>,
    metrics: &Mutex<PoolMetrics>,
    max_batch: usize,
    opts: &SupervisionOptions,
) -> LoopExit {
    let mut fault_seen = executor.fault_counts();
    loop {
        // a worker drains only jobs routed to its own device class
        // whose retry-backoff gate (if any) has matured; batch
        // compatibility within the class: same requested (variant,
        // sampler) pair (the executor re-checks and re-groups
        // defensively).  The timeout re-scans because a parked retry
        // becomes eligible with no push to wake the condvar.
        //
        // under memory pressure the governor's ladder rung halves the
        // seat cap per level (recomputed every dequeue, so the cap
        // recovers as the rung decays)
        let seats = opts
            .pressure
            .as_ref()
            .map_or(max_batch, |g| (max_batch >> g.level(class_idx).min(6)).max(1));
        let jobs = match queue.pop_batch_where_timeout(
            seats,
            |it: &WorkItem| it.class == class_idx && it.ready(),
            |it: &WorkItem| (it.req.variant.clone(), it.req.sampler),
            RETRY_POLL,
        ) {
            None => return LoopExit::Closed,
            Some(j) if j.is_empty() => continue, // a backoff gate may have matured
            Some(j) => j,
        };
        let mut reqs: Vec<GenerateRequest> = Vec::with_capacity(jobs.len());
        let mut meta: Vec<RtcMeta> = Vec::with_capacity(jobs.len());
        for job in jobs {
            let queue_s = job.enqueued.elapsed().as_secs_f64();
            let WorkItem { req, mut reply, predicted_s, attempts, .. } = job.item;

            // deadline-aware: don't burn a device slot on an expired
            // request (its batchmates still run)
            if let Some(d) = job.deadline {
                if Instant::now() > d {
                    metrics.lock().unwrap().record_rejected_deadline();
                    reply.send(Err(Error::Queue(format!(
                        "request {} expired after {queue_s:.3}s in queue",
                        req.id
                    ))));
                    continue;
                }
            }
            reqs.push(req);
            meta.push(RtcMeta {
                reply,
                queue_s,
                predicted_s,
                attempts,
                priority: job.priority,
                deadline: job.deadline,
            });
        }
        if reqs.is_empty() {
            continue;
        }
        let occupancy = reqs.len();
        metrics.lock().unwrap().record_batch(occupancy);

        let t0 = Instant::now();
        let mut results = executor.execute_batch(&reqs);
        let wall_s = t0.elapsed().as_secs_f64();
        absorb_faults(&mut fault_seen, executor.fault_counts(), metrics);
        // fallback split when the executor reports no per-member busy
        // share (mocks): even division, which misattributes mixed-
        // schedule batches — a 3-step member that shared dispatches
        // with 8-step peers did not occupy the device for wall/B
        let even_share_s = wall_s / occupancy as f64;
        let got = results.len();
        if got != reqs.len() {
            // defensive: a misbehaving executor must not strand callers
            results = reqs
                .iter()
                .map(|r| {
                    Err(Error::Runtime(format!(
                        "executor returned {got} results for a batch of {} (request {})",
                        reqs.len(),
                        r.id
                    )))
                })
                .collect();
        }

        let mut device_lost = false;
        // one OOM event per batch climbs the ladder once, however many
        // rows it faulted; `Some(desc)` remembers whether the executor
        // actually degraded (the gate on requeueing OOM'd rows)
        let mut oom_state: Option<Option<String>> = None;
        for ((req, mut m), result) in reqs.into_iter().zip(meta).zip(results) {
            match result {
                Ok(r) => {
                    // the member's device occupancy: the executor's
                    // time-weighted measurement when it provides one
                    // (stepwise wall / rows live that step), else the
                    // even split
                    let busy_share_s = if r.timings.busy_share_s > 0.0 {
                        r.timings.busy_share_s
                    } else {
                        even_share_s
                    };
                    let mut mm = metrics.lock().unwrap();
                    mm.record_batch_member(
                        wid,
                        m.queue_s,
                        wall_s,
                        busy_share_s,
                        Some(&r.timings),
                    );
                    // plan accountability: predicted vs measured
                    // service time, per device class.  The measured
                    // side is the member's share of the batch wall —
                    // the plan predicts one request's service, so a
                    // shared dispatch must not be charged B times.
                    // Failures are excluded: an early error's
                    // microsecond wall would read as huge model
                    // drift when the model was never exercised.
                    if let Some(p) = m.predicted_s {
                        mm.record_prediction(class_idx, p, busy_share_s);
                    }
                    // measured-load feedback: the member's share of the
                    // batch's non-denoise time (its busy share minus
                    // its own denoise share — `total_s` is the whole
                    // batch wall and would overcharge B-fold, the same
                    // trap record_prediction avoids) is the observed
                    // analog of the plan's overhead term; the router
                    // swaps the modeled constant for this mean once
                    // the (class, variant) has served enough requests
                    mm.record_class_overhead(
                        class_idx,
                        req.variant.as_deref().unwrap_or("default"),
                        busy_share_s - r.timings.denoise_s,
                    );
                    drop(mm);
                    if let Some(b) = &opts.breaker {
                        b.record_success(class_idx);
                    }
                    if let Some(g) = &opts.pressure {
                        g.on_success(class_idx);
                    }
                    m.reply.send(Ok(GenerateResponse {
                        id: req.id,
                        image: r.image,
                        image_size: r.image_size,
                        latent: r.latent,
                        timings: r.timings,
                        peak_memory: r.peak_memory,
                        queue_s: m.queue_s,
                        worker_id: wid,
                        device_class: class_name.to_string(),
                        predicted_s: m.predicted_s,
                    }));
                }
                Err(e) if e.is_oom() => {
                    // out of device memory: never retried verbatim.
                    // The first OOM'd row of the batch climbs the
                    // class's pressure ladder and asks the executor to
                    // degrade; rows are requeued only when the
                    // executor changed something, so the retry runs a
                    // *different* plan than the one that just OOM'd.
                    if let Some(b) = &opts.breaker {
                        b.record_fault(class_idx);
                    }
                    if oom_state.is_none() {
                        metrics.lock().unwrap().record_oom();
                        let (level, effective) = match &opts.pressure {
                            Some(g) => {
                                let level = g.on_oom(class_idx);
                                (level, g.effective_budget(class_idx))
                            }
                            None => (1, usize::MAX),
                        };
                        oom_state = Some(executor.degrade(level, effective));
                    }
                    let degraded = oom_state.as_ref().and_then(|d| d.as_ref());
                    if degraded.is_some() && m.attempts < opts.retry_limit {
                        let attempts = m.attempts + 1;
                        {
                            let mut mm = metrics.lock().unwrap();
                            mm.record_retry();
                            mm.record_degraded_retry();
                        }
                        if let Some(g) = &opts.pressure {
                            g.record_degraded(class_idx);
                        }
                        let delay = backoff_delay(opts, attempts);
                        let item = WorkItem {
                            req,
                            reply: m.reply,
                            class: class_idx,
                            predicted_s: m.predicted_s,
                            resume: None,
                            attempts,
                            not_before: Some(Instant::now() + delay),
                        };
                        if let Err((mut item, qe)) = queue.try_push(item, m.priority, m.deadline)
                        {
                            item.reply.send(Err(Error::Queue(format!(
                                "request {} could not requeue after a device OOM: {qe}",
                                item.req.id
                            ))));
                        }
                    } else {
                        let why = if degraded.is_some() {
                            "retry budget spent"
                        } else {
                            "no degradation left"
                        };
                        let mut mm = metrics.lock().unwrap();
                        if m.attempts >= opts.retry_limit {
                            mm.record_retries_exhausted();
                        }
                        mm.record_batch_member(wid, m.queue_s, wall_s, even_share_s, None);
                        drop(mm);
                        m.reply.send(Err(Error::Runtime(format!(
                            "request {} out of device memory ({why}, {} attempts): {e}",
                            req.id,
                            m.attempts + 1
                        ))));
                    }
                }
                Err(e) if e.is_transient() || e.is_device_lost() => {
                    // retryable: the fault feeds the breaker, and the
                    // request re-enters the queue behind a backoff
                    // gate with its original priority and deadline —
                    // unless its budget is spent
                    if e.is_device_lost() {
                        device_lost = true;
                    }
                    if let Some(b) = &opts.breaker {
                        b.record_fault(class_idx);
                    }
                    if m.attempts < opts.retry_limit {
                        let attempts = m.attempts + 1;
                        metrics.lock().unwrap().record_retry();
                        let delay = backoff_delay(opts, attempts);
                        let item = WorkItem {
                            req,
                            reply: m.reply,
                            class: class_idx,
                            predicted_s: m.predicted_s,
                            resume: None,
                            attempts,
                            not_before: Some(Instant::now() + delay),
                        };
                        // a retried attempt is not a terminal outcome:
                        // no batch-member record until it resolves
                        if let Err((mut item, qe)) = queue.try_push(item, m.priority, m.deadline)
                        {
                            item.reply.send(Err(Error::Queue(format!(
                                "request {} could not requeue after a device fault: {qe}",
                                item.req.id
                            ))));
                        }
                    } else {
                        let mut mm = metrics.lock().unwrap();
                        mm.record_retries_exhausted();
                        mm.record_batch_member(wid, m.queue_s, wall_s, even_share_s, None);
                        drop(mm);
                        m.reply.send(Err(Error::Runtime(format!(
                            "request {} gave up after {} attempts: {e}",
                            req.id,
                            m.attempts + 1
                        ))));
                    }
                }
                Err(e) => {
                    metrics.lock().unwrap().record_batch_member(
                        wid,
                        m.queue_s,
                        wall_s,
                        even_share_s,
                        None,
                    );
                    m.reply.send(Err(e));
                }
            }
        }
        if device_lost {
            return LoopExit::DeviceLost;
        }
    }
}

/// Per-row bookkeeping the continuous control keeps from admission to
/// terminal outcome (or requeue).
struct JobMeta {
    req: GenerateRequest,
    reply: ReplySlot,
    /// wait before this admission (a resumed row's earlier waits were
    /// spent; each admission accounts its own)
    queue_s: f64,
    admitted: Instant,
    predicted_s: Option<f64>,
    priority: Priority,
    deadline: Option<Instant>,
    /// admitted from a checkpoint — never a preemption victim again,
    /// so two deadline bursts cannot ping-pong one row forever
    preempted: bool,
    /// transient-fault retries already spent on this request
    attempts: u32,
}

/// The pool's [`ContinuousControl`]: joins come from the shared queue
/// pinned to the session's compatibility key, preemption is judged
/// against the queue head's deadline, and every terminal outcome is
/// folded into the shared metrics and sent on the caller's reply
/// channel.  One instance per session; row tokens are session-scoped.
struct PoolControl<'a> {
    wid: usize,
    class_idx: usize,
    class_name: &'a str,
    /// the raw requested (variant, sampler) of the session head — the
    /// same compatibility key run-to-completion batching groups by
    session_variant: Option<String>,
    session_sampler: Option<Sampler>,
    queue: &'a JobQueue<WorkItem>,
    metrics: &'a Mutex<PoolMetrics>,
    opts: &'a SupervisionOptions,
    meta: HashMap<u64, JobMeta>,
    next_token: u64,
    /// rolling denoise-step wall total, for deadline-feasibility ETAs
    step_s_sum: f64,
    steps_seen: u64,
}

impl PoolControl<'_> {
    /// Turn a dequeued job into a session row: expired jobs are failed
    /// here (never burning a batch slot), live ones get a token and
    /// their scheduling state is kept for the terminal callbacks.
    fn admit(&mut self, job: Job<WorkItem>) -> Option<ContinuousJob> {
        let queue_s = job.enqueued.elapsed().as_secs_f64();
        let WorkItem { req, mut reply, predicted_s, resume, attempts, .. } = job.item;
        if let Some(d) = job.deadline {
            if Instant::now() > d {
                self.metrics.lock().unwrap().record_rejected_deadline();
                reply.send(Err(Error::Queue(format!(
                    "request {} expired after {queue_s:.3}s in queue",
                    req.id
                ))));
                return None;
            }
        }
        let token = self.next_token;
        self.next_token += 1;
        let preempted = resume.is_some();
        if preempted {
            self.metrics.lock().unwrap().record_resume();
        }
        let mut breq = BatchRequest::new(&req.prompt, req.seed);
        breq.overrides = req.overrides();
        self.meta.insert(
            token,
            JobMeta {
                req,
                reply,
                queue_s,
                admitted: Instant::now(),
                predicted_s,
                priority: job.priority,
                deadline: job.deadline,
                preempted,
                attempts,
            },
        );
        Some(ContinuousJob { req: breq, token, resume })
    }

    /// A session-level executor failure (budget refusal, component
    /// load, decode) fails every row still tracked; the queue and the
    /// worker's next session are unaffected.
    fn fail_remaining(&mut self, e: &Error) {
        let mut m = self.metrics.lock().unwrap();
        for (_, mut meta) in self.meta.drain() {
            let wall_s = meta.admitted.elapsed().as_secs_f64();
            m.record_batch_member(self.wid, meta.queue_s, wall_s, 0.0, None);
            meta.reply.send(Err(e.clone()));
        }
    }

    /// A *transient* session-level failure: every row still tracked
    /// goes back through the bounded-retry path instead of failing
    /// outright.  The rows restart from their request (the session's
    /// in-flight latents died with it); seeded generation keeps the
    /// rerun bit-identical.
    fn retry_remaining(&mut self, e: &Error) {
        let tokens: Vec<u64> = self.meta.keys().copied().collect();
        for token in tokens {
            let m = &self.meta[&token];
            let mut breq = BatchRequest::new(&m.req.prompt, m.req.seed);
            breq.overrides = m.req.overrides();
            self.retry(ContinuousJob { req: breq, token, resume: None }, e);
        }
    }
}

impl ContinuousControl for PoolControl<'_> {
    fn poll_joins(&mut self, _key: &BatchKey, slots: usize) -> Vec<ContinuousJob> {
        if slots == 0 {
            return Vec::new();
        }
        let class = self.class_idx;
        let session_key = (self.session_variant.clone(), self.session_sampler);
        let jobs = self.queue.try_pop_batch_where(
            slots,
            |it: &WorkItem| it.class == class && it.ready(),
            |it: &WorkItem| (it.req.variant.clone(), it.req.sampler),
            Some(&session_key),
        );
        let joined: Vec<ContinuousJob> =
            jobs.into_iter().filter_map(|j| self.admit(j)).collect();
        if !joined.is_empty() {
            let mut m = self.metrics.lock().unwrap();
            for _ in &joined {
                m.record_join();
            }
        }
        joined
    }

    fn preempt_victims(&mut self, live: &[LiveRow], free_slots: usize) -> Vec<u64> {
        // last resort only: the batch must be full, a step-time
        // estimate must exist, and the queue head's deadline must be
        // infeasible waiting for the next natural leave
        if free_slots > 0 || live.is_empty() || self.steps_seen == 0 {
            return Vec::new();
        }
        let class = self.class_idx;
        let variant = self.session_variant.clone();
        let sampler = self.session_sampler;
        let head = match self.queue.peek_where(|it: &WorkItem| {
            it.class == class && it.req.variant == variant && it.req.sampler == sampler
        }) {
            Some(h) => h,
            None => return Vec::new(),
        };
        let deadline = match head.deadline {
            Some(d) => d,
            None => return Vec::new(),
        };
        let step_s = self.step_s_sum / self.steps_seen as f64;
        let wait_steps = live.iter().map(|r| r.steps_remaining).min().unwrap_or(0);
        let eta = Instant::now() + Duration::from_secs_f64(wait_steps as f64 * step_s);
        if eta <= deadline {
            return Vec::new(); // a natural leave frees a slot in time
        }
        // victim: strictly lower priority class than the head (Ord is
        // drain order — greater means less urgent), never a resumed
        // row, most work remaining (displacing it buys the most)
        live.iter()
            .filter(|r| {
                self.meta
                    .get(&r.token)
                    .is_some_and(|m| m.priority > head.priority && !m.preempted)
            })
            .max_by_key(|r| r.steps_remaining)
            .map(|r| vec![r.token])
            .unwrap_or_default()
    }

    fn requeue(&mut self, job: ContinuousJob) {
        let Some(meta) = self.meta.remove(&job.token) else {
            return;
        };
        let preempting = job.resume.is_some();
        let priority = if preempting {
            // pay the displacement back: the row re-enters a class
            // ahead of its old one, so the traffic that displaced it
            // cannot also starve it
            self.metrics.lock().unwrap().record_preemption();
            match meta.priority {
                Priority::Low => Priority::Normal,
                _ => Priority::High,
            }
        } else {
            // an incompatible joiner bounced by the executor goes back
            // exactly as it arrived
            meta.priority
        };
        let item = WorkItem {
            req: meta.req,
            reply: meta.reply,
            class: self.class_idx,
            predicted_s: meta.predicted_s,
            resume: job.resume,
            attempts: meta.attempts,
            not_before: None,
        };
        if let Err((mut item, e)) = self.queue.try_push(item, priority, meta.deadline) {
            item.reply.send(Err(Error::Queue(format!(
                "request {} displaced and could not requeue: {e}",
                item.req.id
            ))));
        }
    }

    fn retry(&mut self, job: ContinuousJob, cause: &Error) {
        let Some(mut meta) = self.meta.remove(&job.token) else {
            return;
        };
        if let Some(b) = &self.opts.breaker {
            b.record_fault(self.class_idx);
        }
        let attempts = meta.attempts + 1;
        if attempts > self.opts.retry_limit {
            let wall_s = meta.admitted.elapsed().as_secs_f64();
            let mut m = self.metrics.lock().unwrap();
            m.record_retries_exhausted();
            m.record_batch_member(self.wid, meta.queue_s, wall_s, 0.0, None);
            drop(m);
            meta.reply.send(Err(Error::Runtime(format!(
                "request {} gave up after {attempts} attempts: {cause}",
                meta.req.id
            ))));
            return;
        }
        {
            let mut m = self.metrics.lock().unwrap();
            m.record_retry();
            // an OOM'd row only reaches here after the worker degraded
            // the executor (checkpoint-drain or held-back rows), so
            // this requeue runs a changed plan — count it as such
            if cause.is_oom() {
                m.record_degraded_retry();
            }
        }
        if cause.is_oom() {
            if let Some(g) = &self.opts.pressure {
                g.record_degraded(self.class_idx);
            }
        }
        let delay = backoff_delay(self.opts, attempts);
        // the checkpoint (when the executor took one) rides along, so
        // a fault-retried row resumes mid-schedule instead of redoing
        // its applied steps; either way the numerics are bit-identical
        // to an uninterrupted run
        let item = WorkItem {
            req: meta.req,
            reply: meta.reply,
            class: self.class_idx,
            predicted_s: meta.predicted_s,
            resume: job.resume,
            attempts,
            not_before: Some(Instant::now() + delay),
        };
        if let Err((mut item, e)) = self.queue.try_push(item, meta.priority, meta.deadline) {
            item.reply.send(Err(Error::Queue(format!(
                "request {} could not requeue after a device fault: {e}",
                item.req.id
            ))));
        }
    }

    fn complete(&mut self, token: u64, result: Result<GenerateResult>) {
        if let Err(e) = &result {
            // a retryable per-row failure reaches the terminal callback
            // when the executor had no checkpoint to take (decode-stage
            // faults, the default run-to-completion fallback): route it
            // through the retry budget instead of failing the caller
            if (e.is_transient() || e.is_device_lost()) && self.meta.contains_key(&token) {
                let m = &self.meta[&token];
                let mut breq = BatchRequest::new(&m.req.prompt, m.req.seed);
                breq.overrides = m.req.overrides();
                let cause = e.clone();
                self.retry(ContinuousJob { req: breq, token, resume: None }, &cause);
                return;
            }
        }
        let Some(mut meta) = self.meta.remove(&token) else {
            return;
        };
        let wall_s = meta.admitted.elapsed().as_secs_f64();
        // a row finishing while batchmates stay live is a leave — its
        // slot goes back to the joiners
        let left_peers_behind = !self.meta.is_empty();
        let resp = match result {
            Ok(r) => {
                // the executor's time-weighted busy share (stepwise
                // wall / rows live that step, plus its own decode and
                // encode shares); a row is never charged wall it
                // shared with peers
                let busy_share_s = if r.timings.busy_share_s > 0.0 {
                    r.timings.busy_share_s
                } else {
                    wall_s
                };
                let mut m = self.metrics.lock().unwrap();
                if left_peers_behind {
                    m.record_leave();
                }
                m.record_batch_member(
                    self.wid,
                    meta.queue_s,
                    wall_s,
                    busy_share_s,
                    Some(&r.timings),
                );
                if let Some(p) = meta.predicted_s {
                    m.record_prediction(self.class_idx, p, busy_share_s);
                }
                m.record_class_overhead(
                    self.class_idx,
                    meta.req.variant.as_deref().unwrap_or("default"),
                    busy_share_s - r.timings.denoise_s,
                );
                drop(m);
                if let Some(b) = &self.opts.breaker {
                    b.record_success(self.class_idx);
                }
                if let Some(g) = &self.opts.pressure {
                    g.on_success(self.class_idx);
                }
                Ok(GenerateResponse {
                    id: meta.req.id,
                    image: r.image,
                    image_size: r.image_size,
                    latent: r.latent,
                    timings: r.timings,
                    peak_memory: r.peak_memory,
                    queue_s: meta.queue_s,
                    worker_id: self.wid,
                    device_class: self.class_name.to_string(),
                    predicted_s: meta.predicted_s,
                })
            }
            Err(e) => {
                // failed rows share the session wall evenly with the
                // rows still tracked — a whole-batch failure must not
                // charge the worker B times its elapsed time
                let share = wall_s / (self.meta.len() + 1) as f64;
                let mut m = self.metrics.lock().unwrap();
                if left_peers_behind {
                    m.record_leave();
                }
                m.record_batch_member(self.wid, meta.queue_s, wall_s, share, None);
                drop(m);
                Err(e)
            }
        };
        meta.reply.send(resp);
    }

    fn on_step(&mut self, live: usize, wall_s: f64) {
        self.step_s_sum += wall_s;
        self.steps_seen += 1;
        self.metrics.lock().unwrap().record_step(live, wall_s);
    }
}

/// The continuous-mode worker body: the blocking dequeue only *starts*
/// a session — every later scheduling decision (joins, slot
/// reclamation, preemption) flows through the [`PoolControl`] at
/// denoise-step boundaries inside
/// [`WorkerExecutor::execute_continuous`].
fn continuous_worker_loop<E: WorkerExecutor>(
    wid: usize,
    class_idx: usize,
    class_name: &str,
    mut executor: E,
    queue: &JobQueue<WorkItem>,
    metrics: &Mutex<PoolMetrics>,
    max_batch: usize,
    opts: &SupervisionOptions,
) -> LoopExit {
    let mut fault_seen = executor.fault_counts();
    loop {
        // ladder rung halves the session's seed seats, same as the
        // run-to-completion loop (the executor's own join cap shrinks
        // separately via `degrade`)
        let seats = opts
            .pressure
            .as_ref()
            .map_or(max_batch, |g| (max_batch >> g.level(class_idx).min(6)).max(1));
        let jobs = match queue.pop_batch_where_timeout(
            seats,
            |it: &WorkItem| it.class == class_idx && it.ready(),
            |it: &WorkItem| (it.req.variant.clone(), it.req.sampler),
            RETRY_POLL,
        ) {
            None => return LoopExit::Closed,
            Some(j) if j.is_empty() => continue, // a backoff gate may have matured
            Some(j) => j,
        };
        let session_variant = jobs[0].item.req.variant.clone();
        let session_sampler = jobs[0].item.req.sampler;
        let mut control = PoolControl {
            wid,
            class_idx,
            class_name,
            session_variant,
            session_sampler,
            queue,
            metrics,
            opts,
            meta: HashMap::new(),
            next_token: 0,
            step_s_sum: 0.0,
            steps_seen: 0,
        };
        let initial: Vec<ContinuousJob> =
            jobs.into_iter().filter_map(|j| control.admit(j)).collect();
        if initial.is_empty() {
            continue; // every popped job had already expired
        }
        metrics.lock().unwrap().record_session(initial.len());
        let session = executor.execute_continuous(initial, &mut control);
        absorb_faults(&mut fault_seen, executor.fault_counts(), metrics);
        if let Err(e) = session {
            if e.is_oom() {
                // the session ran out of device memory.  The pipelined
                // executor already checkpoint-drained its live rows
                // back into the queue (their requeues were counted as
                // degraded retries); rows still tracked here faulted
                // before a checkpoint existed (admission/encode, or
                // the default mock path's held-back rows).  Climb the
                // ladder once, degrade the executor, and only requeue
                // the leftovers if something actually changed.
                if let Some(b) = &opts.breaker {
                    b.record_fault(class_idx);
                }
                metrics.lock().unwrap().record_oom();
                let (level, effective) = match &opts.pressure {
                    Some(g) => {
                        let level = g.on_oom(class_idx);
                        (level, g.effective_budget(class_idx))
                    }
                    None => (1, usize::MAX),
                };
                if executor.degrade(level, effective).is_some() {
                    control.retry_remaining(&e);
                } else {
                    control.fail_remaining(&e);
                }
            } else if e.is_transient() || e.is_device_lost() {
                // rows the session still tracked go through the retry
                // budget (record_fault per row happens in retry)
                control.retry_remaining(&e);
            } else {
                control.fail_remaining(&e);
            }
            if e.is_device_lost() {
                return LoopExit::DeviceLost;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::StageTimings;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    /// Mock executor: sleeps, then succeeds with the request's step
    /// count echoed into the timings.
    struct SleepExec {
        sleep: Duration,
        default_steps: usize,
    }

    impl WorkerExecutor for SleepExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            thread::sleep(self.sleep);
            let steps = req.num_steps.unwrap_or(self.default_steps);
            Ok(GenerateResult {
                image: vec![0.0; 4],
                image_size: 2,
                latent: vec![req.seed as f32],
                timings: StageTimings {
                    denoise_steps: steps,
                    total_s: self.sleep.as_secs_f64(),
                    ..Default::default()
                },
                peak_memory: 1,
            })
        }
    }

    fn sleep_factory(
        ms: u64,
        default_steps: usize,
    ) -> impl Fn(usize) -> Result<SleepExec> + Send + Sync + 'static {
        move |_| Ok(SleepExec { sleep: Duration::from_millis(ms), default_steps })
    }

    fn quick_result(req: &GenerateRequest) -> GenerateResult {
        GenerateResult {
            image: vec![0.0; 4],
            image_size: 2,
            latent: vec![req.seed as f32],
            timings: StageTimings { denoise_steps: 1, total_s: 0.001, ..Default::default() },
            peak_memory: 1,
        }
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let pool = WorkerPool::start(3, 32, sleep_factory(5, 20)).unwrap();
        let receivers: Vec<_> = (0..9)
            .map(|i| {
                let req = GenerateRequest::new(i, "p", i);
                pool.submit(req, Priority::Normal, None).unwrap()
            })
            .collect();
        let mut workers_seen = std::collections::BTreeSet::new();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
            assert!(resp.worker_id < 3);
            workers_seen.insert(resp.worker_id);
        }
        assert!(!workers_seen.is_empty());
        let report = pool.metrics_report();
        assert!(report.contains("9 ok"), "{report}");
    }

    #[test]
    fn num_steps_override_reaches_the_executor() {
        let pool = WorkerPool::start(1, 8, sleep_factory(1, 20)).unwrap();
        let mut req = GenerateRequest::new(1, "p", 1);
        req.num_steps = Some(4);
        let rx = pool.submit(req, Priority::Normal, None).unwrap();
        assert_eq!(rx.recv().unwrap().unwrap().timings.denoise_steps, 4);
        let rx = pool
            .submit(GenerateRequest::new(2, "p", 2), Priority::Normal, None)
            .unwrap();
        assert_eq!(
            rx.recv().unwrap().unwrap().timings.denoise_steps,
            20,
            "no override -> configured default"
        );
    }

    #[test]
    fn admission_rejection_is_counted() {
        // one slow worker; capacity-1 queue fills while it sleeps
        let pool = WorkerPool::start(1, 1, sleep_factory(150, 20)).unwrap();
        let rx0 = pool
            .submit(GenerateRequest::new(0, "p", 0), Priority::Normal, None)
            .unwrap();
        // give the worker time to pop the first job and start sleeping
        thread::sleep(Duration::from_millis(50));
        let _rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let err = pool
            .submit(GenerateRequest::new(2, "p", 2), Priority::Normal, None)
            .expect_err("queue full");
        assert!(err.to_string().contains("full"), "{err}");
        pool.with_metrics(|m| assert_eq!(m.rejected_full, 1));
        rx0.recv().unwrap().unwrap();
    }

    #[test]
    fn expired_deadlines_are_dropped_not_executed() {
        let pool = WorkerPool::start(1, 8, sleep_factory(100, 20)).unwrap();
        // first job occupies the worker...
        let rx0 = pool
            .submit(GenerateRequest::new(0, "p", 0), Priority::Normal, None)
            .unwrap();
        // let the worker pop the first job before queuing the second,
        // so the deadline is long past when the second is popped
        thread::sleep(Duration::from_millis(30));
        let rx1 = pool
            .submit(
                GenerateRequest::new(1, "p", 1),
                Priority::Normal,
                Some(Duration::from_millis(1)),
            )
            .unwrap();
        rx0.recv().unwrap().unwrap();
        let err = rx1.recv().unwrap().expect_err("expired");
        assert!(err.to_string().contains("expired"), "{err}");
        pool.with_metrics(|m| {
            assert_eq!(m.rejected_deadline, 1);
            assert_eq!(m.stage.requests_ok, 1);
        });
    }

    /// Mock batching executor: records each batch's request ids, gated
    /// so the test controls when each batch runs.
    struct BatchRecordExec {
        started: mpsc::Sender<()>,
        gate: Arc<Mutex<mpsc::Receiver<()>>>,
        batches: Arc<Mutex<Vec<Vec<u64>>>>,
    }

    impl WorkerExecutor for BatchRecordExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            Ok(GenerateResult {
                image: vec![0.0; 4],
                image_size: 2,
                latent: vec![req.seed as f32],
                timings: StageTimings { denoise_steps: 1, ..Default::default() },
                peak_memory: 1,
            })
        }

        fn execute_batch(&mut self, reqs: &[GenerateRequest]) -> Vec<Result<GenerateResult>> {
            let _ = self.started.send(());
            let _ = self.gate.lock().unwrap().recv();
            self.batches
                .lock()
                .unwrap()
                .push(reqs.iter().map(|r| r.id).collect());
            reqs.iter().map(|r| self.execute(r)).collect()
        }
    }

    #[test]
    fn workers_drain_compatible_batches() {
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let started_tx = Arc::new(Mutex::new(started_tx));
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches2 = Arc::clone(&batches);
        let pool = WorkerPool::start_batched(1, 16, 3, move |_| {
            Ok(BatchRecordExec {
                started: started_tx.lock().unwrap().clone(),
                gate: Arc::clone(&gate_rx),
                batches: Arc::clone(&batches2),
            })
        })
        .unwrap();

        // job 1 occupies the worker (a batch of one)...
        let rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        started_rx.recv().unwrap();
        // ...meanwhile 4 compatible + 1 incompatible requests queue up
        let mut rest = Vec::new();
        for i in 2..=5 {
            rest.push(
                pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                    .unwrap(),
            );
        }
        let mut base = GenerateRequest::new(6, "p", 6);
        base.variant = Some("base".into());
        rest.push(pool.submit(base, Priority::Normal, None).unwrap());

        // four batches will run: [1], [2,3,4], [5], [6]
        for _ in 0..4 {
            gate_tx.send(()).unwrap();
        }
        rx1.recv().unwrap().unwrap();
        for rx in rest {
            rx.recv().unwrap().unwrap();
        }
        // batch 1: the solo head; batch 2: three compatibles (cap 3);
        // then the leftover compatible rides with nothing — the "base"
        // request is incompatible and runs alone
        let seen = batches.lock().unwrap().clone();
        assert_eq!(seen.len(), 4, "{seen:?}");
        assert_eq!(seen[0], vec![1]);
        assert_eq!(seen[1], vec![2, 3, 4]);
        assert_eq!(seen[2], vec![5]);
        assert_eq!(seen[3], vec![6]);

        pool.with_metrics(|m| {
            assert_eq!(m.batches, 4);
            assert_eq!(m.max_batch_occupancy, 3);
            assert_eq!(m.stage.requests_ok, 6);
        });
        let report = pool.metrics_report();
        assert!(report.contains("occupancy"), "{report}");
    }

    #[test]
    fn expired_member_is_dropped_but_batchmates_run() {
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let started_tx = Arc::new(Mutex::new(started_tx));
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches2 = Arc::clone(&batches);
        let pool = WorkerPool::start_batched(1, 16, 4, move |_| {
            Ok(BatchRecordExec {
                started: started_tx.lock().unwrap().clone(),
                gate: Arc::clone(&gate_rx),
                batches: Arc::clone(&batches2),
            })
        })
        .unwrap();

        let rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        started_rx.recv().unwrap();
        // queued while the worker is busy: one with an immediate
        // deadline, one without
        let rx2 = pool
            .submit(
                GenerateRequest::new(2, "p", 2),
                Priority::Normal,
                Some(Duration::from_millis(1)),
            )
            .unwrap();
        let rx3 = pool
            .submit(GenerateRequest::new(3, "p", 3), Priority::Normal, None)
            .unwrap();
        thread::sleep(Duration::from_millis(30)); // let the deadline pass
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();

        rx1.recv().unwrap().unwrap();
        let err = rx2.recv().unwrap().expect_err("expired");
        assert!(err.to_string().contains("expired"), "{err}");
        rx3.recv().unwrap().unwrap();
        let seen = batches.lock().unwrap().clone();
        assert_eq!(seen, vec![vec![1], vec![3]], "request 2 never executed");
        pool.with_metrics(|m| assert_eq!(m.rejected_deadline, 1));
    }

    #[test]
    fn fleet_pool_routes_jobs_to_their_class_and_tracks_predictions() {
        // two classes, one worker each: worker 0 = "fast", worker 1 = "slow"
        let classes = [("fast".to_string(), 1usize), ("slow".to_string(), 1usize)];
        let pool = WorkerPool::start_fleet(&classes, 16, 1, |_wid, class: usize, _name: &str| {
            let ms = if class == 0 { 1 } else { 5 };
            Ok(SleepExec { sleep: Duration::from_millis(ms), default_steps: 2 })
        })
        .unwrap();
        assert_eq!(pool.num_workers(), 2);
        assert_eq!(pool.class_names().to_vec(), vec!["fast".to_string(), "slow".to_string()]);

        let mut rxs = Vec::new();
        for i in 0..4u64 {
            let class = (i % 2) as usize;
            let rx = pool
                .submit_routed(
                    GenerateRequest::new(i, "p", i),
                    Priority::Normal,
                    None,
                    class,
                    Some(0.01),
                )
                .unwrap();
            rxs.push((class, rx));
        }
        for (class, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.device_class, pool.class_names()[class]);
            assert_eq!(resp.predicted_s, Some(0.01));
            assert_eq!(resp.worker_id, class, "jobs never cross classes");
        }
        pool.with_metrics(|m| {
            assert_eq!(m.classes[0].prediction_count(), 2);
            assert_eq!(m.classes[1].prediction_count(), 2);
            assert!(m.classes[0].error_summary().count > 0);
        });
        let report = pool.metrics_report();
        assert!(report.contains("class fast"), "{report}");
        assert!(report.contains("class slow"), "{report}");

        // a class index the pool doesn't have is rejected outright
        let err = pool
            .submit_routed(GenerateRequest::new(9, "p", 9), Priority::Normal, None, 7, None)
            .expect_err("bad class");
        assert!(err.to_string().contains("class"), "{err}");
    }

    #[test]
    fn homogeneous_pools_never_record_predictions() {
        let pool = WorkerPool::start(1, 4, sleep_factory(1, 2)).unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.device_class, "default");
        assert!(resp.predicted_s.is_none());
        pool.with_metrics(|m| assert_eq!(m.classes[0].prediction_count(), 0));
    }

    #[test]
    fn continuous_pool_serves_with_the_default_executor_fallback() {
        // mocks don't override execute_continuous: the session runs its
        // seed jobs run-to-completion, but the pool-side wiring (session
        // accounting, admission, replies) is the continuous path
        let classes = [("default".to_string(), 1usize)];
        let pool =
            WorkerPool::start_fleet_mode(&classes, 16, 4, true, |_wid, _c: usize, _n: &str| {
                Ok(SleepExec { sleep: Duration::from_millis(2), default_steps: 3 })
            })
            .unwrap();
        let rxs: Vec<_> = (0..5u64)
            .map(|i| {
                pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.device_class, "default");
        }
        pool.with_metrics(|m| {
            assert!(m.sessions >= 1, "continuous sessions recorded");
            assert_eq!(m.stage.requests_ok, 5);
        });
        let report = pool.metrics_report();
        assert!(report.contains("continuous:"), "{report}");
    }

    #[test]
    fn factory_failure_aborts_startup() {
        let result = WorkerPool::start(2, 8, |wid| {
            if wid == 1 {
                Err(Error::Runtime("no device".into()))
            } else {
                Ok(SleepExec { sleep: Duration::from_millis(1), default_steps: 1 })
            }
        });
        assert!(result.is_err());
    }

    #[test]
    fn shutdown_now_terminates_queued_and_in_flight_replies() {
        let (started_tx, started_rx) = mpsc::channel();
        let (gate_tx, gate_rx) = mpsc::channel();
        let started_tx = Arc::new(Mutex::new(started_tx));
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let batches = Arc::new(Mutex::new(Vec::new()));
        let batches2 = Arc::clone(&batches);
        let mut pool = WorkerPool::start_batched(1, 16, 1, move |_| {
            Ok(BatchRecordExec {
                started: started_tx.lock().unwrap().clone(),
                gate: Arc::clone(&gate_rx),
                batches: Arc::clone(&batches2),
            })
        })
        .unwrap();

        // job 1 is in flight, parked at the executor's gate
        let rx_a = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        started_rx.recv().unwrap();
        // three more queue up behind it
        let queued: Vec<_> = (2..=4u64)
            .map(|i| {
                pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                    .unwrap()
            })
            .collect();
        // release the in-flight batch while shutdown is underway; the
        // queued jobs are drained before the join, so this never
        // deadlocks on the gated worker
        let release = thread::spawn(move || {
            thread::sleep(Duration::from_millis(50));
            let _ = gate_tx.send(());
        });
        pool.shutdown_now();
        release.join().unwrap();

        // the in-flight job finished; every queued job got exactly one
        // terminal reply, none hang
        assert!(rx_a.recv().unwrap().is_ok(), "in-flight batch still completes");
        for rx in queued {
            let err = rx.recv().unwrap().expect_err("queued job failed at shutdown");
            assert!(err.to_string().contains("shut down"), "{err}");
            assert!(rx.recv().is_err(), "exactly one terminal reply per request");
        }
        assert_eq!(batches.lock().unwrap().len(), 1, "queued jobs never executed");
    }

    /// Panics on request id 1, but only in its first incarnation —
    /// rebuilt generations serve everything.
    struct PanicOnceExec {
        generation: usize,
    }

    impl WorkerExecutor for PanicOnceExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            if self.generation == 0 && req.id == 1 {
                panic!("injected worker crash");
            }
            Ok(quick_result(req))
        }
    }

    #[test]
    fn a_worker_panic_is_supervised_and_never_strands_the_caller() {
        let builds = Arc::new(AtomicUsize::new(0));
        let builds2 = Arc::clone(&builds);
        let pool = WorkerPool::start(1, 8, move |_| {
            Ok(PanicOnceExec { generation: builds2.fetch_add(1, Ordering::SeqCst) })
        })
        .unwrap();

        let rx1 = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        // the reply-slot drop guard fires during the unwind: an
        // explicit failure, not a dead channel
        let err = rx1.recv().unwrap().expect_err("crashed request fails explicitly");
        assert!(err.to_string().contains("worker died"), "{err}");
        assert!(rx1.recv().is_err(), "exactly one terminal reply");

        // the supervisor rebuilt the executor; the pool still serves
        let rx2 = pool
            .submit(GenerateRequest::new(2, "p", 2), Priority::Normal, None)
            .unwrap();
        rx2.recv().unwrap().unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 2, "factory re-ran for the rebuild");
        pool.with_metrics(|m| {
            assert_eq!(m.worker_restarts, 1);
            assert_eq!(m.reply_orphaned, 1);
        });
    }

    /// Fails each request's first `fails_before` attempts with a
    /// transient error, then succeeds.
    struct FlakyExec {
        fails_before: u32,
        calls: HashMap<u64, u32>,
    }

    impl WorkerExecutor for FlakyExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            let n = self.calls.entry(req.id).or_insert(0);
            *n += 1;
            if *n <= self.fails_before {
                return Err(Error::Transient(format!("injected fault #{n}")));
            }
            Ok(quick_result(req))
        }
    }

    #[test]
    fn transient_failures_are_retried_with_backoff_until_success() {
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 3,
            retry_backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let pool = WorkerPool::start_supervised(
            &classes,
            8,
            1,
            false,
            supervision,
            |_wid, _c: usize, _n: &str| Ok(FlakyExec { fails_before: 2, calls: HashMap::new() }),
        )
        .unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1, "third attempt succeeded");
        pool.with_metrics(|m| {
            assert_eq!(m.retries, 2);
            assert_eq!(m.retries_exhausted, 0);
            assert_eq!(m.stage.requests_ok, 1);
            assert_eq!(m.stage.requests_failed, 0);
        });
    }

    #[test]
    fn retry_budget_exhaustion_fails_the_caller() {
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 1,
            retry_backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let pool = WorkerPool::start_supervised(
            &classes,
            8,
            1,
            false,
            supervision,
            |_wid, _c: usize, _n: &str| {
                Ok(FlakyExec { fails_before: u32::MAX, calls: HashMap::new() })
            },
        )
        .unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let err = rx.recv().unwrap().expect_err("budget spent");
        assert!(err.to_string().contains("gave up"), "{err}");
        pool.with_metrics(|m| {
            assert_eq!(m.retries, 1);
            assert_eq!(m.retries_exhausted, 1);
            assert_eq!(m.stage.requests_failed, 1);
        });
    }

    /// OOMs each request's first `fails_before` attempts, then
    /// succeeds — a device that recovers once the plan is degraded.
    /// `execute` calls are counted so tests can pin down exactly how
    /// many times an OOM'd request hit the device.
    struct OomExec {
        fails_before: u32,
        calls: HashMap<u64, u32>,
        executions: Arc<AtomicUsize>,
        /// whether `degrade` has anything left to give up
        can_degrade: bool,
        degraded_to: Arc<Mutex<Vec<(u8, usize)>>>,
    }

    impl WorkerExecutor for OomExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            self.executions.fetch_add(1, Ordering::SeqCst);
            let n = self.calls.entry(req.id).or_insert(0);
            *n += 1;
            if *n <= self.fails_before {
                return Err(Error::Oom(format!("allocator refused attempt #{n}")));
            }
            Ok(quick_result(req))
        }

        fn degrade(&mut self, level: u8, effective_budget: usize) -> Option<String> {
            if !self.can_degrade {
                return None;
            }
            self.degraded_to.lock().unwrap().push((level, effective_budget));
            Some(format!("rung {level}"))
        }
    }

    #[test]
    fn oom_is_retried_degraded_and_completes() {
        let gov = Arc::new(PressureGovernor::new(
            vec![1_000_000],
            crate::coordinator::pressure::PressureOptions::default(),
        ));
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 3,
            retry_backoff: Duration::from_millis(1),
            pressure: Some(Arc::clone(&gov)),
            ..Default::default()
        };
        let degraded_to = Arc::new(Mutex::new(Vec::new()));
        let d2 = Arc::clone(&degraded_to);
        let pool = WorkerPool::start_supervised(
            &classes,
            8,
            1,
            false,
            supervision,
            move |_wid, _c: usize, _n: &str| {
                Ok(OomExec {
                    fails_before: 1,
                    calls: HashMap::new(),
                    executions: Arc::new(AtomicUsize::new(0)),
                    can_degrade: true,
                    degraded_to: Arc::clone(&d2),
                })
            },
        )
        .unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let resp = rx.recv().unwrap().expect("degraded retry succeeds");
        assert_eq!(resp.id, 1);
        assert!(rx.recv().is_err(), "exactly one terminal reply");
        pool.with_metrics(|m| {
            assert_eq!(m.ooms, 1);
            assert_eq!(m.degraded_retries, 1);
            assert_eq!(m.retries, 1);
            assert_eq!(m.retries_exhausted, 0);
            assert_eq!(m.stage.requests_ok, 1);
        });
        // the governor climbed one rung and shrank the learned budget
        assert_eq!(gov.ooms(0), 1);
        assert_eq!(gov.degraded(0), 1);
        assert!(gov.effective_budget(0) < 1_000_000);
        // the executor was told the new rung and budget before the retry
        let seen = degraded_to.lock().unwrap().clone();
        assert_eq!(seen.len(), 1);
        assert_eq!(seen[0].0, 1);
        assert_eq!(seen[0].1, gov.effective_budget(0));
        let report = pool.metrics_report();
        assert!(report.contains("1 ooms, 1 degraded retries"), "{report}");
    }

    #[test]
    fn oom_without_degradation_fails_fast_never_verbatim() {
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 3,
            retry_backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let executions = Arc::new(AtomicUsize::new(0));
        let e2 = Arc::clone(&executions);
        let pool = WorkerPool::start_supervised(
            &classes,
            8,
            1,
            false,
            supervision,
            move |_wid, _c: usize, _n: &str| {
                Ok(OomExec {
                    fails_before: u32::MAX,
                    calls: HashMap::new(),
                    executions: Arc::clone(&e2),
                    can_degrade: false,
                    degraded_to: Arc::new(Mutex::new(Vec::new())),
                })
            },
        )
        .unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        let err = rx.recv().unwrap().expect_err("nothing left to degrade");
        assert!(err.to_string().contains("no degradation left"), "{err}");
        assert_eq!(
            executions.load(Ordering::SeqCst),
            1,
            "an OOM'd plan must never re-run unchanged"
        );
        pool.with_metrics(|m| {
            assert_eq!(m.ooms, 1);
            assert_eq!(m.degraded_retries, 0);
            assert_eq!(m.retries, 0, "no verbatim retry was attempted");
            assert_eq!(m.stage.requests_failed, 1);
        });
    }

    #[test]
    fn continuous_session_oom_holds_rows_back_and_retries_degraded() {
        let gov = Arc::new(PressureGovernor::new(
            vec![1_000_000],
            crate::coordinator::pressure::PressureOptions::default(),
        ));
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 3,
            retry_backoff: Duration::from_millis(1),
            pressure: Some(Arc::clone(&gov)),
            ..Default::default()
        };
        let pool = WorkerPool::start_supervised(
            &classes,
            16,
            4,
            true,
            supervision,
            move |_wid, _c: usize, _n: &str| {
                Ok(OomExec {
                    fails_before: 1,
                    calls: HashMap::new(),
                    executions: Arc::new(AtomicUsize::new(0)),
                    can_degrade: true,
                    degraded_to: Arc::new(Mutex::new(Vec::new())),
                })
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..3u64)
            .map(|i| {
                pool.submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                    .unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().expect("every row resolves after degrade");
            assert_eq!(resp.id, i as u64);
            assert!(rx.recv().is_err(), "exactly one terminal reply");
        }
        pool.with_metrics(|m| {
            assert!(m.ooms >= 1, "ooms={}", m.ooms);
            assert!(m.degraded_retries >= 1, "degraded={}", m.degraded_retries);
            assert_eq!(m.stage.requests_ok, 3);
            assert_eq!(m.stage.requests_failed, 0);
        });
        assert!(gov.ooms(0) >= 1);
    }

    /// Loses the device on the first execute ever (shared flag survives
    /// the rebuild), then serves normally.
    struct LoseOnceExec {
        tripped: Arc<AtomicBool>,
    }

    impl WorkerExecutor for LoseOnceExec {
        fn execute(&mut self, req: &GenerateRequest) -> Result<GenerateResult> {
            if !self.tripped.swap(true, Ordering::SeqCst) {
                return Err(Error::DeviceLost("injected device loss".into()));
            }
            Ok(quick_result(req))
        }
    }

    #[test]
    fn device_loss_rebuilds_the_worker_and_the_request_survives() {
        let tripped = Arc::new(AtomicBool::new(false));
        let tripped2 = Arc::clone(&tripped);
        let builds = Arc::new(AtomicUsize::new(0));
        let builds2 = Arc::clone(&builds);
        let pool = WorkerPool::start(1, 8, move |_| {
            builds2.fetch_add(1, Ordering::SeqCst);
            Ok(LoseOnceExec { tripped: Arc::clone(&tripped2) })
        })
        .unwrap();
        let rx = pool
            .submit(GenerateRequest::new(1, "p", 1), Priority::Normal, None)
            .unwrap();
        // device loss: the request is requeued (retry 1), the worker
        // rebuilds its engine, and the rerun succeeds
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(builds.load(Ordering::SeqCst), 2, "engine rebuilt after device loss");
        pool.with_metrics(|m| {
            assert_eq!(m.worker_restarts, 1);
            assert_eq!(m.retries, 1);
        });
    }

    #[test]
    fn pool_faults_trip_the_shared_breaker() {
        let breaker = Arc::new(CircuitBreaker::new(1, 2, Duration::from_secs(60)));
        let classes = [("default".to_string(), 1usize)];
        let supervision = SupervisionOptions {
            retry_limit: 0,
            breaker: Some(Arc::clone(&breaker)),
            ..Default::default()
        };
        let pool = WorkerPool::start_supervised(
            &classes,
            8,
            1,
            false,
            supervision,
            |_wid, _c: usize, _n: &str| {
                Ok(FlakyExec { fails_before: u32::MAX, calls: HashMap::new() })
            },
        )
        .unwrap();
        for i in 0..2u64 {
            let rx = pool
                .submit(GenerateRequest::new(i, "p", i), Priority::Normal, None)
                .unwrap();
            rx.recv().unwrap().expect_err("no retries: immediate failure");
        }
        assert!(!breaker.admits(0), "two consecutive faults tripped the class");
        assert!(breaker.all_degraded());
        pool.with_metrics(|m| assert_eq!(m.retries_exhausted, 2));
        let report = pool.metrics_report();
        assert!(report.contains("breaker: default=open"), "{report}");
    }
}
