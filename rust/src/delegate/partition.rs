//! Graph partitioning into GPU segments and CPU fallback islands.
//!
//! Mirrors the TFLite delegate mechanism: maximal runs of consecutive
//! delegable ops (in the graph's topological order) form GPU segments;
//! each boundary between a GPU segment and a CPU island costs a
//! synchronization + activation copy (the "expensive communication
//! between CPU and GPU" of paper Sec. 3.1).

use crate::graph::{Graph, OpId};

use super::rules::RuleSet;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    Gpu,
    Cpu,
}

#[derive(Debug, Clone)]
pub struct Segment {
    pub device: Device,
    pub ops: Vec<OpId>,
}

#[derive(Debug, Clone)]
pub struct Partition {
    pub segments: Vec<Segment>,
}

impl Partition {
    pub fn new(g: &Graph, rules: &RuleSet) -> Partition {
        let mut segments: Vec<Segment> = Vec::new();
        for op in &g.ops {
            let device = if rules.check(g, op).ok() { Device::Gpu } else { Device::Cpu };
            match segments.last_mut() {
                Some(seg) if seg.device == device => seg.ops.push(op.id),
                _ => segments.push(Segment { device, ops: vec![op.id] }),
            }
        }
        Partition { segments }
    }

    pub fn num_transitions(&self) -> usize {
        self.segments.len().saturating_sub(1)
    }

    pub fn cpu_op_count(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| s.device == Device::Cpu)
            .map(|s| s.ops.len())
            .sum()
    }

    pub fn gpu_op_count(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| s.device == Device::Gpu)
            .map(|s| s.ops.len())
            .sum()
    }

    pub fn fully_delegated(&self) -> bool {
        self.cpu_op_count() == 0
    }

    /// Bytes crossing each GPU<->CPU boundary: activations produced by the
    /// last op(s) of one segment and consumed by the next.  Conservative
    /// estimate: output bytes of the boundary-producing op.
    pub fn boundary_bytes(&self, g: &Graph) -> Vec<usize> {
        let mut out = Vec::new();
        for win in self.segments.windows(2) {
            let last_op = *win[0].ops.last().unwrap();
            let bytes: usize = g.ops[last_op]
                .outputs
                .iter()
                .map(|&t| g.tensor(t).bytes())
                .sum();
            out.push(bytes);
        }
        out
    }

    /// Every op appears in exactly one segment, in order.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        let mut seen = vec![false; g.ops.len()];
        let mut last = None;
        for seg in &self.segments {
            if seg.ops.is_empty() {
                return Err("empty segment".into());
            }
            for &op in &seg.ops {
                if op >= g.ops.len() {
                    return Err(format!("op {op} out of range"));
                }
                if seen[op] {
                    return Err(format!("op {op} in two segments"));
                }
                if let Some(l) = last {
                    if op != l + 1 {
                        return Err(format!("ops out of order at {op}"));
                    }
                }
                seen[op] = true;
                last = Some(op);
            }
        }
        if seen.iter().filter(|&&s| s).count() != g.ops.len() {
            return Err("not all ops covered".into());
        }
        // adjacent segments must alternate devices
        for win in self.segments.windows(2) {
            if win[0].device == win[1].device {
                return Err("adjacent segments on same device".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::OpType;

    #[test]
    fn all_gpu_when_clean() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 16]);
        let y = b.conv2d("c", x, 16, 3, 1);
        b.unary(OpType::Tanh, "t", y);
        let g = b.finish();
        let p = Partition::new(&g, &RuleSet::default());
        p.validate(&g).unwrap();
        assert!(p.fully_delegated());
        assert_eq!(p.num_transitions(), 0);
    }

    #[test]
    fn groupnorm_creates_cpu_island() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 16]);
        let y = b.conv2d("pre", x, 16, 3, 1);
        let z = b.group_norm_naive("gn", y, 4);
        b.conv2d("post", z, 16, 3, 1);
        let g = b.finish();
        let p = Partition::new(&g, &RuleSet::default());
        p.validate(&g).unwrap();
        assert!(!p.fully_delegated());
        assert!(p.num_transitions() >= 2, "island => at least 2 boundaries");
        assert!(p.cpu_op_count() > 0);
        assert!(!p.boundary_bytes(&g).is_empty());
    }

    #[test]
    fn property_random_graphs() {
        use crate::graph::builder::random_graph;
        use crate::util::rng::Rng;
        for seed in 0..40 {
            let mut rng = Rng::new(seed);
            let g = random_graph(&mut rng, 25);
            let p = Partition::new(&g, &RuleSet::default());
            p.validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert_eq!(p.cpu_op_count() + p.gpu_op_count(), g.ops.len());
        }
    }
}
