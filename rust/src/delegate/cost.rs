//! Analytic latency cost model for the delegate simulator.
//!
//! Per-op roofline: `t = dispatch + max(flops / throughput, bytes / bw)`,
//! with device profiles for the mobile GPU (Adreno-740-class), the CPU
//! (XNNPACK on big cores), and a Hexagon-class NPU comparator.  GPU<->CPU
//! boundaries pay a sync + copy cost.  Constants are calibrated (see
//! DESIGN.md §4) so that the paper's measured numbers are reproduced:
//! input-serialized conv ~15.5 ms, output-serialized ~40.9 ms, and the
//! Table-1 end-to-end shape (~7 s ours vs ~12 s / ~15 s comparators).

use crate::graph::{DType, Graph, Op, OpType};

use super::partition::{Device, Partition};
use super::rules::RuleSet;

/// A compute-device profile.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// sustained f16 FLOP/s
    pub flops: f64,
    /// sustained memory bandwidth, bytes/s
    pub bandwidth: f64,
    /// per-op dispatch overhead, seconds
    pub dispatch: f64,
    /// output-channel tile the conv/matmul pipelines are efficient at;
    /// thinner outputs waste lanes (the paper's 40.9 ms output
    /// serialization)
    pub cout_tile: usize,
}

/// Efficiency of the spatial (k>1) conv path relative to the matmul
/// path: the im2col/winograd transform and halo reads cost ~20%.
/// Calibrated jointly with `GPU_ADRENO740.flops` against the paper's
/// 15.5 ms input-serialized conv measurement.
pub const SPATIAL_CONV_EFF: f64 = 0.80;

/// Roofline op classes.  Each class gets its own fitted (flops,
/// bandwidth, dispatch) triple under online calibration — a conv
/// pipeline and a reduction loop saturate very different fractions of
/// a device's peak, and folding them into one effective rate is what
/// made the shipped constants drift from measured hardware.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpClass {
    /// spatial + 1x1 convolutions
    Conv,
    /// fully-connected and batched matmuls
    Matmul,
    /// softmax (decomposed islands are classified op-by-op; the fused
    /// kernel lands here)
    Softmax,
    /// mean / sum style reductions
    Reduction,
    /// pure elementwise chains
    Elementwise,
    /// reshapes, transposes, gathers — layout, not arithmetic
    DataMovement,
}

impl OpClass {
    pub const ALL: &'static [OpClass] = &[
        OpClass::Conv,
        OpClass::Matmul,
        OpClass::Softmax,
        OpClass::Reduction,
        OpClass::Elementwise,
        OpClass::DataMovement,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Conv => "conv",
            OpClass::Matmul => "matmul",
            OpClass::Softmax => "softmax",
            OpClass::Reduction => "reduction",
            OpClass::Elementwise => "elementwise",
            OpClass::DataMovement => "data-movement",
        }
    }

    /// Stable dense index (for per-class parameter tables).
    pub fn index(self) -> usize {
        match self {
            OpClass::Conv => 0,
            OpClass::Matmul => 1,
            OpClass::Softmax => 2,
            OpClass::Reduction => 3,
            OpClass::Elementwise => 4,
            OpClass::DataMovement => 5,
        }
    }

    /// Classification of an operator kind.
    pub fn of(ty: OpType) -> OpClass {
        use OpType::*;
        match ty {
            Conv2d => OpClass::Conv,
            FullyConnected | BatchMatmul => OpClass::Matmul,
            Softmax | FusedSoftmax => OpClass::Softmax,
            Mean | Sum => OpClass::Reduction,
            Reshape | BroadcastTo | Transpose | Concatenation
            | ResizeNearestNeighbor | Gather | StridedSlice | Split => {
                OpClass::DataMovement
            }
            _ => OpClass::Elementwise,
        }
    }
}

/// Classify one graph op.
pub fn op_class(op: &Op) -> OpClass {
    OpClass::of(op.ty)
}

/// The roofline triple priced for one op class.
#[derive(Debug, Clone, Copy)]
pub struct RoofParams {
    /// effective FLOP/s this class sustains
    pub flops: f64,
    /// effective bytes/s this class sustains
    pub bandwidth: f64,
    /// per-dispatch overhead, seconds
    pub dispatch: f64,
}

/// A cost model the roofline functions can price against: the shipped
/// [`DeviceProfile`] (one triple for every class) or an online
/// calibration overlay (per-class fitted triples — see
/// `planner::calibrate::CalibratedProfile`).  Structural knobs that are
/// not fitted online (`cout_tile`) always come from the base profile.
pub trait RooflineModel {
    /// The shipped profile this model is anchored to.
    fn base(&self) -> &DeviceProfile;

    /// The (possibly fitted) roofline triple for `class`.
    fn params(&self, class: OpClass) -> RoofParams;
}

impl RooflineModel for DeviceProfile {
    fn base(&self) -> &DeviceProfile {
        self
    }

    fn params(&self, _class: OpClass) -> RoofParams {
        RoofParams {
            flops: self.flops,
            bandwidth: self.bandwidth,
            dispatch: self.dispatch,
        }
    }
}

/// Adreno-740-class mobile GPU (OpenCL delegate).
pub const GPU_ADRENO740: DeviceProfile = DeviceProfile {
    name: "mobile-gpu",
    flops: 1.9e12,
    bandwidth: 50e9,
    dispatch: 6e-6,
    cout_tile: 224,
};

/// Snapdragon big-core CPU running XNNPACK fp16.
pub const CPU_BIGCORE: DeviceProfile = DeviceProfile {
    name: "cpu",
    flops: 4.0e10,
    bandwidth: 20e9,
    dispatch: 1e-6,
    cout_tile: 64,
};

/// Hexagon-class NPU (Hou & Asghar comparator): high peak, but the
/// qualcomm AI-engine path the paper compares against ran SD v1.5 in
/// ~15 s end to end — modeled as lower sustained efficiency.
pub const NPU_HEXAGON: DeviceProfile = DeviceProfile {
    name: "hexagon-npu",
    flops: 0.70e12,
    bandwidth: 50e9,
    dispatch: 10e-6,
    cout_tile: 256,
};

/// Custom OpenCL kernels (Chen et al. comparator): complete coverage by
/// construction, slightly lower sustained throughput than the tuned
/// TFLite delegate path on SD's shapes (they report ~12 s on S23 Ultra).
pub const GPU_CUSTOM_KERNELS: DeviceProfile = DeviceProfile {
    name: "custom-opencl",
    flops: 0.875e12,
    bandwidth: 50e9,
    dispatch: 12e-6,
    cout_tile: 224,
};

/// GPU<->CPU boundary: queue sync + activation copy both ways.
pub const TRANSFER_SYNC: f64 = 120e-6;
pub const TRANSFER_BW: f64 = 8e9;

/// Winograd F(2x2, 3x3) arithmetic reduction for stride-1 3x3 convs.
/// The delegate's standard conv path uses it; the serialized fallback
/// path (attr "serialized") does not — its transform workspace is
/// exactly the buffer that exceeded the arena limit in the first place,
/// which keeps the Fig.-1 calibration (15.5 / 40.9 ms) intact.
pub const WINOGRAD_REDUCTION: f64 = 2.25;

/// FLOPs of one op (multiply-add = 2 FLOPs; Winograd-reduced where the
/// delegate's conv path applies it).
pub fn op_flops(g: &Graph, op: &Op) -> f64 {
    let out = g.tensor(op.outputs[0]);
    let out_elems = out.elems() as f64;
    match op.ty {
        OpType::Conv2d => {
            let k = op.attr_i("kernel").unwrap_or(1) as f64;
            let cin = g
                .act_inputs(op)
                .next()
                .map(|t| *t.shape.last().unwrap_or(&1))
                .unwrap_or(1) as f64;
            let cout = *out.shape.last().unwrap_or(&1);
            let mut flops = 2.0 * out_elems * cin * k * k;
            let stride = op.attr_i("stride").unwrap_or(1);
            if k == 3.0
                && stride == 1
                && cin >= 32.0
                && cout >= 32
                && op.attr_i("serialized").is_none()
            {
                flops /= WINOGRAD_REDUCTION;
            }
            flops
        }
        OpType::FullyConnected => {
            let cin = g
                .act_inputs(op)
                .next()
                .map(|t| *t.shape.last().unwrap_or(&1))
                .unwrap_or(1) as f64;
            2.0 * out_elems * cin
        }
        OpType::BatchMatmul => {
            // (B, M, K) @ (B, K, N) -> (B, M, N)
            let k = g
                .act_inputs(op)
                .next()
                .map(|t| *t.shape.last().unwrap_or(&1))
                .unwrap_or(1) as f64;
            2.0 * out_elems * k
        }
        OpType::Softmax => 5.0 * out_elems,
        // the fused-softmax kernel does the same math as the exp/sum/div
        // island it replaces, but in one dispatch with the logits
        // streamed through registers: its roofline is memory-bound (the
        // 5-flops-per-element numerator never beats bytes/bandwidth on
        // any shipped profile), so the win over the island is the two
        // saved dispatches and the intermediate tensors that no longer
        // round-trip through memory
        OpType::FusedSoftmax => 5.0 * out_elems,
        OpType::Mean | OpType::SquaredDifference | OpType::Sum => {
            let in_elems: f64 = g.act_inputs(op).map(|t| t.elems() as f64).sum();
            in_elems.max(out_elems)
        }
        _ => out_elems, // elementwise / data movement
    }
}

/// Bytes moved by one op (activations + weights read + outputs written).
pub fn op_bytes(g: &Graph, op: &Op) -> f64 {
    let acts: usize = g.act_inputs(op).map(|t| t.bytes()).sum();
    // weights are streamed at their *stored* width (int8 payloads read
    // 4x less than f32 — the W8A16 bandwidth win)
    let weights: usize = g.const_inputs(op).map(|t| t.bytes()).sum();
    let outs: usize = op.outputs.iter().map(|&t| g.tensor(t).bytes()).sum();
    (acts + weights + outs) as f64
}

/// Latency of a single op on a device (shipped constants).
pub fn op_latency(g: &Graph, op: &Op, dev: &DeviceProfile) -> f64 {
    op_latency_on(g, op, dev)
}

/// Latency of a single op under an arbitrary roofline model.
pub fn op_latency_on(g: &Graph, op: &Op, model: &dyn RooflineModel) -> f64 {
    let params = model.params(op_class(op));
    let flops = op_flops(g, op);
    let bytes = op_bytes(g, op);
    // thin-output utilization penalty for the matmul/conv pipelines
    // (batched attention matmuls amortize across the batch and are
    // exempt), plus the spatial-conv transform overhead for k>1 convs
    let util = match op.ty {
        OpType::Conv2d | OpType::FullyConnected => {
            let cout = *g.tensor(op.outputs[0]).shape.last().unwrap_or(&1);
            let thin = (cout as f64 / model.base().cout_tile as f64).min(1.0);
            let spatial = if op.ty == OpType::Conv2d
                && op.attr_i("kernel").unwrap_or(1) > 1
            {
                SPATIAL_CONV_EFF
            } else {
                1.0
            };
            thin * spatial
        }
        _ => 1.0,
    };
    // reshapes are metadata-only views on the delegate
    if op.ty == OpType::Reshape {
        return params.dispatch;
    }
    let compute = flops / (params.flops * util.max(1e-3));
    let memory = bytes / params.bandwidth;
    params.dispatch + compute.max(memory)
}

#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    pub gpu_time: f64,
    pub cpu_time: f64,
    pub transfer_time: f64,
    pub transitions: usize,
    pub cpu_ops: usize,
    pub gpu_ops: usize,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.gpu_time + self.cpu_time + self.transfer_time
    }
}

/// End-to-end latency of a partitioned graph on a (gpu, cpu) pair.
pub fn partition_cost(
    g: &Graph,
    p: &Partition,
    gpu: &DeviceProfile,
    cpu: &DeviceProfile,
) -> CostBreakdown {
    partition_cost_on(g, p, gpu, cpu)
}

/// End-to-end latency of a partitioned graph under arbitrary roofline
/// models for the delegate and the fallback device.
pub fn partition_cost_on(
    g: &Graph,
    p: &Partition,
    gpu: &dyn RooflineModel,
    cpu: &dyn RooflineModel,
) -> CostBreakdown {
    let mut out = CostBreakdown {
        transitions: p.num_transitions(),
        cpu_ops: p.cpu_op_count(),
        gpu_ops: p.gpu_op_count(),
        ..Default::default()
    };
    for seg in &p.segments {
        let dev = match seg.device {
            Device::Gpu => gpu,
            Device::Cpu => cpu,
        };
        // the GPU delegate fuses chains of elementwise ops into one
        // kernel (no intermediate HBM round-trips, one dispatch)
        let fuse = seg.device == Device::Gpu;
        let t = segment_cost_on(g, &seg.ops, dev, fuse);
        match seg.device {
            Device::Gpu => out.gpu_time += t,
            Device::Cpu => out.cpu_time += t,
        }
    }
    for bytes in p.boundary_bytes(g) {
        out.transfer_time += TRANSFER_SYNC + bytes as f64 / TRANSFER_BW;
    }
    out
}

/// Cost of a run of ops on one device (shipped constants).
pub fn segment_cost(g: &Graph, ops: &[usize], dev: &DeviceProfile, fuse: bool) -> f64 {
    segment_cost_on(g, ops, dev, fuse)
}

/// Cost of a run of ops on one device, optionally fusing consecutive
/// elementwise ops (one dispatch, intermediates stay in registers; only
/// the chain's external inputs and final output touch memory).
pub fn segment_cost_on(
    g: &Graph,
    ops: &[usize],
    model: &dyn RooflineModel,
    fuse: bool,
) -> f64 {
    if !fuse {
        return ops.iter().map(|&i| op_latency_on(g, &g.ops[i], model)).sum();
    }
    let mut total = 0.0;
    let mut i = 0;
    while i < ops.len() {
        let op = &g.ops[ops[i]];
        if !op.ty.is_elementwise() {
            total += op_latency_on(g, op, model);
            i += 1;
            continue;
        }
        // extend the elementwise run
        let mut j = i;
        let mut flops = 0.0;
        let mut produced: std::collections::BTreeSet<usize> =
            std::collections::BTreeSet::new();
        let mut external_bytes = 0usize;
        while j < ops.len() && g.ops[ops[j]].ty.is_elementwise() {
            let o = &g.ops[ops[j]];
            flops += op_flops(g, o);
            for &inp in &o.inputs {
                if !produced.contains(&inp) {
                    external_bytes += g.tensor(inp).bytes();
                }
            }
            for &out in &o.outputs {
                produced.insert(out);
            }
            j += 1;
        }
        // final op's output leaves the fused kernel
        external_bytes += g.ops[ops[j - 1]]
            .outputs
            .iter()
            .map(|&t| g.tensor(t).bytes())
            .sum::<usize>();
        let params = model.params(OpClass::Elementwise);
        let compute = flops / params.flops;
        let memory = external_bytes as f64 / params.bandwidth;
        total += params.dispatch + compute.max(memory);
        i = j;
    }
    total
}

/// Convenience: partition with `rules`, then cost.
pub fn graph_cost(
    g: &Graph,
    rules: &RuleSet,
    gpu: &DeviceProfile,
    cpu: &DeviceProfile,
) -> CostBreakdown {
    graph_cost_on(g, rules, gpu, cpu)
}

/// Partition with `rules`, then cost under arbitrary roofline models.
pub fn graph_cost_on(
    g: &Graph,
    rules: &RuleSet,
    gpu: &dyn RooflineModel,
    cpu: &dyn RooflineModel,
) -> CostBreakdown {
    let p = Partition::new(g, rules);
    partition_cost_on(g, &p, gpu, cpu)
}

/// Cost of running the whole graph on one device (custom kernels / NPU
/// comparators: complete coverage by construction, elementwise fused).
pub fn single_device_cost(g: &Graph, dev: &DeviceProfile) -> f64 {
    single_device_cost_on(g, dev)
}

/// Single-device whole-graph cost under an arbitrary roofline model.
pub fn single_device_cost_on(g: &Graph, model: &dyn RooflineModel) -> f64 {
    let ops: Vec<usize> = (0..g.ops.len()).collect();
    segment_cost_on(g, &ops, model, true)
}

/// Per-op-class aggregate of one graph: op count, raw work, and modeled
/// latency under two models (shipped vs calibrated) — the payload of
/// `analyze --per-op` and of the per-dispatch observations the executor
/// emits.
#[derive(Debug, Clone, Default)]
pub struct ClassBreakdownRow {
    pub ops: usize,
    pub flops: f64,
    pub bytes: f64,
    pub modeled_s: f64,
    pub calibrated_s: f64,
}

/// Aggregate `g` per op class, pricing each op under `shipped` and
/// `calibrated` (pass the same model twice for a single-column view).
/// Rows are indexed by [`OpClass::index`]; classes absent from the
/// graph have `ops == 0`.
pub fn class_breakdown(
    g: &Graph,
    shipped: &dyn RooflineModel,
    calibrated: &dyn RooflineModel,
) -> [ClassBreakdownRow; 6] {
    let mut rows: [ClassBreakdownRow; 6] = Default::default();
    for op in &g.ops {
        let row = &mut rows[op_class(op).index()];
        row.ops += 1;
        row.flops += op_flops(g, op);
        row.bytes += op_bytes(g, op);
        row.modeled_s += op_latency_on(g, op, shipped);
        row.calibrated_s += op_latency_on(g, op, calibrated);
    }
    rows
}

/// Activation bytes an op streams (non-const inputs + outputs) — the
/// traffic W8A8 shrinks 4x; weight bytes are untouched (they are
/// already stored int8 where the paper quantized them).
fn op_act_bytes(g: &Graph, op: &Op) -> f64 {
    let acts: usize = g.act_inputs(op).map(|t| t.bytes()).sum();
    let outs: usize = op.outputs.iter().map(|&t| g.tensor(t).bytes()).sum();
    (acts + outs) as f64
}

/// Latency of `op` with its memory traffic overridden to `bytes`
/// (compute side unchanged) — the comparison point for pricing W8A8.
fn op_latency_bytes(g: &Graph, op: &Op, model: &dyn RooflineModel, bytes: f64) -> f64 {
    if op.ty == OpType::Reshape {
        return model.params(op_class(op)).dispatch;
    }
    let params = model.params(op_class(op));
    let flops = op_flops(g, op);
    let util = match op.ty {
        OpType::Conv2d | OpType::FullyConnected => {
            let cout = *g.tensor(op.outputs[0]).shape.last().unwrap_or(&1);
            let thin = (cout as f64 / model.base().cout_tile as f64).min(1.0);
            let spatial = if op.ty == OpType::Conv2d
                && op.attr_i("kernel").unwrap_or(1) > 1
            {
                SPATIAL_CONV_EFF
            } else {
                1.0
            };
            thin * spatial
        }
        _ => 1.0,
    };
    let compute = flops / (params.flops * util.max(1e-3));
    let memory = bytes.max(0.0) / params.bandwidth;
    params.dispatch + compute.max(memory)
}

/// Modeled end-to-end gain (seconds; positive = win) of running the
/// graph with W8A8 activations under `model`: every op's activation
/// traffic shrinks to 1 byte/elem (weights unchanged), minus the
/// quant/dequant passes at the graph boundary (one streaming pass over
/// the graph inputs and outputs, two extra dispatches).  Under the
/// shipped GPU constants the UNet is compute-bound and the gain is
/// negative; a calibration that lowers effective bandwidth flips it —
/// which is why this is a planner decision, not a CLI flag.
pub fn w8a8_gain(g: &Graph, model: &dyn RooflineModel) -> f64 {
    let mut gain = 0.0;
    for op in &g.ops {
        if op.ty == OpType::Reshape {
            continue;
        }
        let acts = op_act_bytes(g, op);
        let full = op_latency_on(g, op, model);
        let quant = op_latency_bytes(g, op, model, op_bytes(g, op) - 0.75 * acts);
        gain += full - quant;
    }
    // boundary quant/dequant: graph inputs quantized once, graph
    // outputs dequantized once (elementwise streaming passes)
    let producers = g.producers();
    let consumers = g.consumers();
    let mut io_bytes = 0.0;
    for t in &g.tensors {
        if t.is_const {
            continue;
        }
        let is_input = producers[t.id].is_none() && !consumers[t.id].is_empty();
        let is_output = producers[t.id].is_some() && consumers[t.id].is_empty();
        if is_input || is_output {
            io_bytes += t.bytes() as f64;
        }
    }
    let p = model.params(OpClass::Elementwise);
    gain - (2.0 * p.dispatch + 2.0 * io_bytes / p.bandwidth)
}

/// Latency of one serialized conv configuration (paper Fig. 1b study):
/// `factor` sequential calls over input- or output-channel slices.
pub fn serialized_conv_latency(
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    k: usize,
    factor: usize,
    along_input: bool,
    dev: &DeviceProfile,
) -> f64 {
    let mut g = Graph::new("serial");
    let mut total = 0.0;
    let (cin_call, cout_call) = if along_input {
        (cin / factor, cout)
    } else {
        (cin, cout / factor)
    };
    for i in 0..factor {
        let x = g.add_tensor(&format!("x{i}"), &[1, h, w, cin_call], DType::F16, false);
        let wt = g.add_tensor(
            &format!("w{i}"),
            &[k, k, cin_call, cout_call],
            DType::F16,
            true,
        );
        let y = g.add_tensor(&format!("y{i}"), &[1, h, w, cout_call], DType::F16, false);
        let mut attrs = std::collections::BTreeMap::new();
        attrs.insert("kernel".into(), k as f64);
        // per-call slices run on the delegate's fallback (non-Winograd)
        // conv path — see WINOGRAD_REDUCTION
        attrs.insert("serialized".into(), factor as f64);
        let id = g.add_op_with_attrs(
            OpType::Conv2d,
            &format!("c{i}"),
            vec![x, wt],
            vec![y],
            attrs,
        );
        total += op_latency(&g, &g.ops[id], dev);
    }
    if along_input && factor > 1 {
        // accumulate partial sums: factor-1 adds over the output
        let x = g.add_tensor("acc_a", &[1, h, w, cout], DType::F16, false);
        let yb = g.add_tensor("acc_b", &[1, h, w, cout], DType::F16, false);
        let o = g.add_tensor("acc_o", &[1, h, w, cout], DType::F16, false);
        let id = g.add_op(OpType::Add, "acc", vec![x, yb], vec![o]);
        total += (factor - 1) as f64 * op_latency(&g, &g.ops[id], dev);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn paper_serialized_conv_latencies() {
        // paper Sec 3.1: input factor 2 -> 15.5 ms, output factor 8 -> 40.9 ms
        let t_in = serialized_conv_latency(32, 32, 1920, 640, 3, 2, true, &GPU_ADRENO740);
        let t_out = serialized_conv_latency(32, 32, 1920, 640, 3, 8, false, &GPU_ADRENO740);
        assert!(
            (t_in * 1e3 - 15.5).abs() < 4.0,
            "input-serialized latency {:.1} ms, paper 15.5 ms",
            t_in * 1e3
        );
        assert!(
            (t_out * 1e3 - 40.9).abs() < 10.0,
            "output-serialized latency {:.1} ms, paper 40.9 ms",
            t_out * 1e3
        );
        assert!(t_in < t_out, "paper chose input serialization for lower latency");
    }

    #[test]
    fn transfers_dominate_when_islands_exist() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 320]);
        let y = b.conv2d("pre", x, 320, 3, 1);
        let z = b.group_norm_naive("gn", y, 32);
        b.conv2d("post", z, 320, 3, 1);
        let g = b.finish();
        let rules = RuleSet::default();
        let cost = graph_cost(&g, &rules, &GPU_ADRENO740, &CPU_BIGCORE);
        assert!(cost.cpu_time > 0.0);
        assert!(cost.transfer_time > 0.0);
        assert!(cost.transitions >= 2);
        // the same graph with everything delegable is strictly faster
        let clean = single_device_cost(&g, &GPU_ADRENO740);
        assert!(clean < cost.total());
    }

    #[test]
    fn flops_sanity() {
        // raw: 2 * 32*32*640 * 1920 * 9 = 22.65 GFLOP; the standard
        // delegate path Winograd-reduces it by 2.25x
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        b.conv2d("c", x, 640, 3, 1);
        let g = b.finish();
        let f = op_flops(&g, &g.ops[0]);
        assert!((f / 1e9 - 22.65 / WINOGRAD_REDUCTION).abs() < 0.1, "{}", f / 1e9);

        // the serialized fallback path keeps the raw count
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        let y = b.conv2d("c", x, 640, 3, 1);
        let _ = y;
        let mut g = b.finish();
        g.ops[0].attrs.insert("serialized".into(), 2.0);
        let f = op_flops(&g, &g.ops[0]);
        assert!((f / 1e9 - 22.65).abs() < 0.1, "{}", f / 1e9);
    }

    #[test]
    fn cpu_much_slower_than_gpu_on_conv() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 64, 320]);
        b.conv2d("c", x, 320, 3, 1);
        let g = b.finish();
        let tg = op_latency(&g, &g.ops[0], &GPU_ADRENO740);
        let tc = op_latency(&g, &g.ops[0], &CPU_BIGCORE);
        assert!(tc > 10.0 * tg);
    }
}
