//! TFLite GPU-delegate simulator (paper Sec. 3.1 substrate).
//!
//! Three pieces: [`rules`] decides per-op delegability, [`partition`]
//! splits the graph into GPU segments and CPU fallback islands the way
//! the real delegate does, and [`cost`] prices the result with an
//! analytic roofline model of the Galaxy-S23-class hardware.

pub mod cost;
pub mod partition;
pub mod rules;

pub use cost::{
    graph_cost, op_latency, partition_cost, segment_cost, single_device_cost,
    CostBreakdown, DeviceProfile, CPU_BIGCORE, GPU_ADRENO740,
    GPU_CUSTOM_KERNELS, NPU_HEXAGON,
};
pub use partition::{Device, Partition, Segment};
pub use rules::{RuleSet, Verdict};
