//! TFLite GPU-delegate simulator (paper Sec. 3.1 substrate).
//!
//! Three pieces: [`rules`] decides per-op delegability, [`partition`]
//! splits the graph into GPU segments and CPU fallback islands the way
//! the real delegate does, and [`cost`] prices the result with an
//! analytic roofline model of the Galaxy-S23-class hardware.

pub mod cost;
pub mod partition;
pub mod rules;

pub use cost::{
    class_breakdown, graph_cost, graph_cost_on, op_class, op_latency,
    op_latency_on, partition_cost, segment_cost, single_device_cost,
    single_device_cost_on, w8a8_gain, ClassBreakdownRow, CostBreakdown,
    DeviceProfile, OpClass, RoofParams, RooflineModel, CPU_BIGCORE,
    GPU_ADRENO740, GPU_CUSTOM_KERNELS, NPU_HEXAGON,
};
pub use partition::{Device, Partition, Segment};
pub use rules::{RuleSet, Verdict};
