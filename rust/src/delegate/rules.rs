//! TFLite-GPU-delegate support rules (paper Sec. 3.1, calibrated in
//! DESIGN.md §4).
//!
//! A rule set decides, per op, whether the GPU delegate accepts it.  The
//! defaults reproduce every observation in the paper:
//!
//!  * `BROADCAST_TO` is never delegable (the group-norm blocker);
//!  * ops touching rank-5 tensors are never delegable;
//!  * `GATHER` is not delegable (real TFLite GPU behaviour);
//!  * `FULLY_CONNECTED` fails when its flattened row count exceeds
//!    `fc_max_rows` (the 1x4096x320 failure; the 1x1-conv equivalent
//!    takes the matmul path and is exempt);
//!  * a k>1 conv fails when BOTH `C_in >= conv_max_cin` AND
//!    `in_elems + out_elems >= conv_max_elems` (the OpenCL spatial-conv
//!    buffer-arena analog; at-capacity allocations fail).  Consequences,
//!    verified against the full SD v2.1 UNet graph: **exactly one** conv
//!    fails — the 1920 -> 640 3x3 at 32x32 the paper reports (the
//!    1280 -> 1280 upsampler at the same resolution moves the same
//!    elements but stays under the C_in limit, and the 2560-C_in convs
//!    at 8x8/16x16 stay under the element limit); minimal input-
//!    serialization factor is 2 (960 C_in per call); minimal output-
//!    serialization factor is 8 (C_in stays 1920, so the element limit
//!    governs: factor 4 -> 2.13 M >= 2^21 fails, factor 5 lands exactly
//!    on 2^21 and still fails, factor 8 -> 2.05 M passes).

use crate::graph::{Graph, Op, OpType};

#[derive(Debug, Clone)]
pub struct RuleSet {
    /// FULLY_CONNECTED: max flattened rows the delegate accepts.
    pub fc_max_rows: usize,
    /// k>1 convs: input-channel threshold.
    pub conv_max_cin: usize,
    /// k>1 convs: element threshold (in_elems + out_elems).
    pub conv_max_elems: usize,
    /// max tensor rank the delegate supports.
    pub max_rank: usize,
}

impl Default for RuleSet {
    fn default() -> Self {
        RuleSet {
            fc_max_rows: 2048,
            conv_max_cin: 1536,
            conv_max_elems: 2 * 1024 * 1024,
            max_rank: 4,
        }
    }
}

/// Why an op cannot be delegated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    Delegable,
    UnsupportedOp(OpType),
    RankTooHigh(usize),
    FcTooManyRows(usize),
    ConvTooLarge { cin: usize, elems: usize },
}

impl Verdict {
    pub fn ok(&self) -> bool {
        matches!(self, Verdict::Delegable)
    }
}

impl RuleSet {
    pub fn check(&self, g: &Graph, op: &Op) -> Verdict {
        // hard unsupported ops
        if matches!(op.ty, OpType::BroadcastTo | OpType::Gather) {
            return Verdict::UnsupportedOp(op.ty);
        }
        // rank limit over all activation operands
        let max_rank = op
            .inputs
            .iter()
            .chain(op.outputs.iter())
            .map(|&t| g.tensor(t).rank())
            .max()
            .unwrap_or(0);
        if max_rank > self.max_rank {
            return Verdict::RankTooHigh(max_rank);
        }
        match op.ty {
            OpType::FullyConnected => {
                let x = g.act_inputs(op).next();
                if let Some(x) = x {
                    let rows: usize =
                        x.shape[..x.shape.len().saturating_sub(1)].iter().product();
                    if rows > self.fc_max_rows {
                        return Verdict::FcTooManyRows(rows);
                    }
                }
                Verdict::Delegable
            }
            OpType::Conv2d => {
                let k = op.attr_i("kernel").unwrap_or(1) as usize;
                if k <= 1 {
                    return Verdict::Delegable; // matmul path
                }
                let x = match g.act_inputs(op).next() {
                    Some(t) => t,
                    None => return Verdict::Delegable,
                };
                let y = g.tensor(op.outputs[0]);
                let cin = *x.shape.last().unwrap_or(&0);
                let _cout = *y.shape.last().unwrap_or(&0);
                let elems = x.elems() + y.elems();
                if cin >= self.conv_max_cin && elems >= self.conv_max_elems {
                    return Verdict::ConvTooLarge { cin, elems };
                }
                Verdict::Delegable
            }
            _ => Verdict::Delegable,
        }
    }

    /// All non-delegable ops with reasons.
    pub fn failures<'a>(&self, g: &'a Graph) -> Vec<(&'a Op, Verdict)> {
        g.ops
            .iter()
            .map(|op| (op, self.check(g, op)))
            .filter(|(_, v)| !v.ok())
            .collect()
    }

    /// Fraction of ops that delegate.
    pub fn coverage(&self, g: &Graph) -> f64 {
        if g.ops.is_empty() {
            return 1.0;
        }
        let ok = g.ops.iter().filter(|op| self.check(g, op).ok()).count();
        ok as f64 / g.ops.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    #[test]
    fn broadcast_and_rank5_blocked() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 16]);
        b.group_norm_naive("gn", x, 4);
        let g = b.finish();
        let rules = RuleSet::default();
        let fails = rules.failures(&g);
        assert!(fails.iter().any(|(_, v)| matches!(v, Verdict::UnsupportedOp(OpType::BroadcastTo))));
        assert!(fails.iter().any(|(_, v)| matches!(v, Verdict::RankTooHigh(5))));
    }

    #[test]
    fn paper_fc_failure() {
        let mut b = GraphBuilder::new("t");
        // the paper's 1x4096x320 fully-connected
        let x = b.input("x", &[1, 4096, 320]);
        b.fully_connected("fc", x, 1280);
        let g = b.finish();
        let v = RuleSet::default().check(&g, &g.ops[0]);
        assert_eq!(v, Verdict::FcTooManyRows(4096));
    }

    #[test]
    fn small_fc_ok() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 77, 1024]);
        b.fully_connected("fc", x, 4096);
        let g = b.finish();
        assert!(RuleSet::default().check(&g, &g.ops[0]).ok());
    }

    #[test]
    fn paper_conv_failure_and_exemptions() {
        let rules = RuleSet::default();
        // the failing 1920 -> 640 3x3 at 32x32
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        b.conv2d("c", x, 640, 3, 1);
        let g = b.finish();
        assert!(!rules.check(&g, &g.ops[0]).ok());

        // same shapes, 1x1 conv (matmul path): exempt
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 32, 32, 1920]);
        b.conv2d("c", x, 640, 1, 1);
        let g = b.finish();
        assert!(rules.check(&g, &g.ops[0]).ok());

        // 320 -> 320 at 64x64: same elems (2.62M) but small C_in
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 64, 64, 320]);
        b.conv2d("c", x, 320, 3, 1);
        let g = b.finish();
        assert!(rules.check(&g, &g.ops[0]).ok());

        // 2560 -> 1280 at 8x8: huge C_in, few elems
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 2560]);
        b.conv2d("c", x, 1280, 3, 1);
        let g = b.finish();
        assert!(rules.check(&g, &g.ops[0]).ok());
    }

    #[test]
    fn serialization_minimal_factors_match_paper() {
        let rules = RuleSet::default();
        // input serialization: per-call conv is (1920/f) -> 640
        let input_ok = |f: usize| {
            let mut b = GraphBuilder::new("t");
            let x = b.input("x", &[1, 32, 32, 1920 / f]);
            b.conv2d("c", x, 640, 3, 1);
            let g = b.finish();
            rules.check(&g, &g.ops[0]).ok()
        };
        assert!(!input_ok(1));
        assert!(input_ok(2)); // paper: minimal input factor 2

        // output serialization: per-call conv is 1920 -> (640/f)
        let output_ok = |f: usize| {
            let mut b = GraphBuilder::new("t");
            let x = b.input("x", &[1, 32, 32, 1920]);
            b.conv2d("c", x, 640 / f, 3, 1);
            let g = b.finish();
            rules.check(&g, &g.ops[0]).ok()
        };
        assert!(!output_ok(1));
        assert!(!output_ok(2));
        assert!(!output_ok(4));
        assert!(output_ok(8)); // paper: minimal output factor 8
    }
}
