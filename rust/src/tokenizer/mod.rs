//! Hash-vocabulary tokenizer — the Rust mirror of
//! python/compile/tokenizer.py (the build path emits golden cases into
//! the manifest; integration tests verify byte-for-byte parity).

pub const PAD_ID: i32 = 0;
pub const BOS_ID: i32 = 1;

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

pub fn words(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.to_lowercase().chars() {
        if ch.is_alphanumeric() {
            cur.push(ch);
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// BOS + hashed word ids, truncated / PAD-padded to `seq_len`.
pub fn encode(text: &str, vocab_size: usize, seq_len: usize) -> Vec<i32> {
    let mut ids = vec![BOS_ID];
    for w in words(text) {
        if ids.len() >= seq_len {
            break;
        }
        let id = 2 + (fnv1a64(w.as_bytes()) % (vocab_size as u64 - 2)) as i32;
        ids.push(id);
    }
    ids.resize(seq_len, PAD_ID);
    ids.truncate(seq_len);
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_answers() {
        // must match python/tests/test_tokenizer.py::test_fnv_golden
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
    }

    #[test]
    fn framing() {
        let ids = encode("a b", 4096, 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(ids[0], BOS_ID);
        assert!(ids[3..].iter().all(|&t| t == PAD_ID));
    }

    #[test]
    fn truncation_and_range() {
        let text = (0..100).map(|i| format!("w{i}")).collect::<Vec<_>>().join(" ");
        let ids = encode(&text, 4096, 16);
        assert_eq!(ids.len(), 16);
        assert!(ids.iter().all(|&t| (0..4096).contains(&t)));
        assert!(!ids[1..].contains(&PAD_ID));
    }

    #[test]
    fn case_and_punct_insensitive_split() {
        assert_eq!(words("Hello, WORLD!"), vec!["hello", "world"]);
        assert_eq!(encode("HELLO", 4096, 16), encode("hello", 4096, 16));
    }

    #[test]
    fn empty_prompt_is_bos_plus_pads() {
        let ids = encode("", 4096, 16);
        assert_eq!(ids[0], BOS_ID);
        assert!(ids[1..].iter().all(|&t| t == PAD_ID));
    }
}
