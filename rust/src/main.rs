//! mobile-diffusion CLI — the leader entrypoint.
//!
//! Subcommands:
//!   generate   one text-to-image generation (writes a PNG)
//!   serve      read prompts from stdin, one generation per line
//!   analyze    delegate-simulator report over a .graph.json
//!   passes     run the Sec. 3.1/3.2 pass pipeline on a graph and report
//!   info       artifact manifest summary

use std::io::BufRead;
use std::path::Path;

use mobile_diffusion::config::AppConfig;
use mobile_diffusion::coordinator::{GenerateResponse, ResponseReceiver, Server};
use mobile_diffusion::delegate::{graph_cost, single_device_cost, RuleSet};
use mobile_diffusion::graph::Graph;
use mobile_diffusion::passes;
use mobile_diffusion::planner::{self, DeviceSpec};
use mobile_diffusion::runtime::Manifest;
use mobile_diffusion::util::image;

const USAGE: &str = "\
mobile-diffusion — Mobile Stable Diffusion reproduction (Choi et al. 2023)

USAGE: mobile-diffusion <COMMAND> [FLAGS]

COMMANDS:
  generate   generate one image        [--prompt S] [--seed N] [--steps N]
             [--variant base|mobile] [--weights fp32|int8|int8_pruned]
             [--sampler ddim|dpm2m|distilled4|distilled8]
             [--budget-mb X] [--no-pipeline] [--out FILE.png]
             [--artifacts DIR] [--guidance X] [--config FILE.json]
  serve      prompts from stdin, metrics on EOF (same flags, plus
             [--workers N] [--queue-depth N] [--max-batch N] for the
             worker pool, [--device-mem MB] for a capacity-accounted
             device memory cap (OOM arises organically and workers
             recover through the degradation ladder), and
             [--fleet SPEC] for a heterogeneous fleet,
             e.g. adreno740:2,bigcore:1 — plan-predicted service times
             drive admission and per-class routing; compatible
             concurrent requests share one CFG-batched UNet dispatch
             per denoise step, joining and leaving the in-flight batch
             at step boundaries (continuous batching; [--no-continuous]
             restores run-to-completion).  All workers load through one shared
             host-artifact store, and [--warm-slots N] sets how many
             compiled executables each worker keeps across evictions
             for upload-only warm reloads; 0 disables)
  analyze    delegate report           <graph.json> [--device NAME]
             (also prints the planner's cost-gated pass schedule for
              the device class, and a per-sampler service-time table —
              what every device class is predicted to take for a
              50-step DDIM request vs the few-step solver family
              (dpm2m@8, distilled4/8), i.e. the headroom step-aware
              admission prices deadlines against;
              [--per-op] adds a per-op-class table of
              modeled vs calibrated latency, flops and bytes, with the
              calibrated column priced by a self-fit round-trip of the
              online roofline calibrator, plus the memory-pressure
              degradation ladder: effective vs shipped budget per rung)
  passes     pass-pipeline report      <graph.json> [--device NAME]
             [--only name,name,...] runs a registry subset;
             [--list] prints the registered passes and exits
             (NAME from the planner registry: adreno740, bigcore,
              hexagon, custom; default adreno740)
  info       manifest summary          [--artifacts DIR]
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("generate") => cmd_generate(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("passes") => cmd_passes(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        _ => {
            eprint!("{USAGE}");
            Ok(())
        }
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

type R = mobile_diffusion::Result<()>;

fn cmd_generate(args: &[String]) -> R {
    let mut cfg = AppConfig::default();
    cfg.apply_args(args)?;
    let mut server = Server::start(&cfg)?;
    println!("generating: \"{}\" (seed {}, {} steps, variant {}, weights {})",
             cfg.prompt, cfg.seed, cfg.num_steps, cfg.variant, cfg.unet_weights);
    let resp = server.generate(&cfg.prompt, cfg.seed)?;
    let t = &resp.timings;
    println!(
        "done in {:.2}s  (text {:.2}s, denoise {:.2}s / {} steps, decode {:.2}s)",
        t.total_s, t.text_load_s + t.text_encode_s, t.denoise_s,
        t.denoise_steps, t.decoder_load_s + t.decode_s
    );
    println!("peak memory: {:.1} MB", resp.peak_memory as f64 / 1e6);
    let out = cfg.out.clone().unwrap_or_else(|| "generated.png".into());
    let px = image::float_to_rgb8(&resp.image);
    image::write_png(&out, resp.image_size, resp.image_size, &px)?;
    println!("image written to {}", out.display());
    Ok(())
}

fn cmd_serve(args: &[String]) -> R {
    let mut cfg = AppConfig::default();
    cfg.apply_args(args)?;
    let mut server = Server::start(&cfg)?;
    eprintln!(
        "ready: one prompt per line on stdin ({} workers, queue depth {})",
        server.num_workers(),
        cfg.queue_depth
    );
    let stdin = std::io::stdin();
    let mut seed = cfg.seed;
    // submit without blocking so the pool runs prompts concurrently;
    // admission control sheds load when the queue is full, and
    // completed responses are printed (and dropped) as they land
    let mut pending: Vec<ResponseReceiver> = Vec::new();
    let print_response = |r: mobile_diffusion::Result<GenerateResponse>| match r {
        Ok(resp) => println!(
            "#{} ok: {:.2}s total, {:.2}s queued, worker {}, peak {:.1} MB",
            resp.id, resp.timings.total_s, resp.queue_s, resp.worker_id,
            resp.peak_memory as f64 / 1e6
        ),
        Err(e) => println!("error: {e}"),
    };
    for line in stdin.lock().lines() {
        let prompt = line.map_err(mobile_diffusion::Error::from)?;
        if prompt.trim().is_empty() {
            continue;
        }
        seed += 1;
        match server.submit(&prompt, seed) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("rejected: {e}"),
        }
        // drain whatever has finished so far, keeping memory bounded
        pending.retain(|rx| match rx.try_recv() {
            Ok(r) => {
                print_response(r);
                false
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => true,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                println!("error: worker dropped request");
                false
            }
        });
    }
    for rx in pending {
        match rx.recv() {
            Ok(r) => print_response(r),
            Err(_) => println!("error: worker dropped request"),
        }
    }
    println!("{}", server.metrics_report()?);
    Ok(())
}

/// Shared front half of `analyze`/`passes`: parse `<graph.json>
/// [--device NAME]`, load the graph, resolve the device class against
/// the planner registry (default adreno740).
fn load_graph_cmd(cmd: &str, args: &[String]) -> mobile_diffusion::Result<(Graph, DeviceSpec)> {
    let mut path: Option<String> = None;
    let mut device = "adreno740".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--device" => {
                i += 1;
                device = args.get(i).cloned().ok_or_else(|| {
                    mobile_diffusion::Error::Config("--device needs a value".into())
                })?;
            }
            other if path.is_none() && !other.starts_with("--") => {
                path = Some(other.to_string());
            }
            other => {
                return Err(mobile_diffusion::Error::Config(format!(
                    "{cmd}: unexpected argument {other}"
                )));
            }
        }
        i += 1;
    }
    let path = path.ok_or_else(|| {
        mobile_diffusion::Error::Config(format!("{cmd} needs a graph.json"))
    })?;
    let spec = planner::device_spec(&device).ok_or_else(|| {
        mobile_diffusion::Error::Config(format!(
            "unknown device '{device}' (known: {})",
            planner::device_names().join(", ")
        ))
    })?;
    let g = mobile_diffusion::graph::load(Path::new(&path))?;
    Ok((g, spec))
}

/// Shared cost line: delegate-partitioned for paired device classes,
/// single-device for complete-coverage classes.
fn modeled_cost_line(g: &Graph, rules: &RuleSet, spec: &DeviceSpec) -> String {
    match &spec.fallback {
        Some(cpu) => {
            let cost = graph_cost(g, rules, &spec.delegate, cpu);
            format!(
                "modeled latency on {}: {:.1} ms (gpu {:.1}, cpu {:.1}, transfer {:.1}; {} transitions)",
                spec.name,
                cost.total() * 1e3,
                cost.gpu_time * 1e3,
                cost.cpu_time * 1e3,
                cost.transfer_time * 1e3,
                cost.transitions
            )
        }
        None => format!(
            "modeled latency on {}: {:.1} ms (single device, complete coverage)",
            spec.name,
            single_device_cost(g, &spec.delegate) * 1e3
        ),
    }
}

fn cmd_analyze(args: &[String]) -> R {
    // peel --per-op off before the shared graph loader
    let mut per_op = false;
    let rest: Vec<String> = args
        .iter()
        .filter(|a| {
            if a.as_str() == "--per-op" {
                per_op = true;
                false
            } else {
                true
            }
        })
        .cloned()
        .collect();
    let (g, spec) = load_graph_cmd("analyze", &rest)?;
    let rules = RuleSet::default();
    println!("{g}");
    let failures = rules.failures(&g);
    println!("delegation coverage: {:.2}%", rules.coverage(&g) * 100.0);
    println!("failing ops: {}", failures.len());
    for (op, v) in failures.iter().take(25) {
        println!("  {:<40} {:?}", op.name, v);
    }
    if failures.len() > 25 {
        println!("  ... and {} more", failures.len() - 25);
    }
    println!("{}", modeled_cost_line(&g, &rules, &spec));
    // what the cost-gated planner would actually run on this class
    let planned = planner::plan_graph(&g, &rules, &spec);
    println!(
        "planner schedule on {}: {} ({} rewrites, modeled {:.1} ms)",
        spec.name,
        planner::schedule_display(&planned.passes_used),
        planned.rewrites,
        planned.cost_s * 1e3
    );
    print_sampler_service_times();
    if per_op {
        print_per_op_breakdown(&g, &spec);
        print_pressure_ladder(&g, &spec);
    }
    Ok(())
}

/// The `analyze` sampler table: plan-predicted service time per device
/// class for the nominal 50-step DDIM request against each few-step
/// operating point (dpm2m at 8 requested steps; the distilled
/// schedules pin their own effective counts regardless of the request).
/// This is exactly what step-aware admission prices deadlines with, so
/// the table shows which deadlines each class can only meet few-step.
fn print_sampler_service_times() {
    use mobile_diffusion::planner::PlanRegistry;
    use mobile_diffusion::scheduler::Sampler;

    // (sampler, requested steps): the baseline request is 50 steps;
    // dpm2m is shown at its few-step operating point
    let points = [
        (Sampler::Ddim, 50usize),
        (Sampler::Dpm2m, 8),
        (Sampler::Distilled4, 50),
        (Sampler::Distilled8, 50),
    ];
    let plans = PlanRegistry::new();
    println!("predicted service time per sampler (variant mobile):");
    println!(
        "  {:<12} {:>9} {:>9}  per-class predicted ms",
        "sampler", "requested", "effective"
    );
    for (sampler, requested) in points {
        let steps = sampler.effective_steps(requested);
        let mut cells: Vec<String> = Vec::new();
        for name in planner::device_names() {
            let Some(spec) = planner::device_spec(name) else { continue };
            let Ok(plan) = plans.plan(&spec, "mobile") else { continue };
            cells.push(format!(
                "{name} {:.1}",
                plan.predict_service_s(steps) * 1e3
            ));
        }
        println!(
            "  {:<12} {:>9} {:>9}  {}",
            sampler.name(),
            requested,
            steps,
            cells.join("   ")
        );
    }
}

/// The `analyze --per-op` table: per-op-class work and latency, with
/// the calibrated column priced by a *self-fit round-trip* — the online
/// calibrator is fed roofline-exact synthetic dispatches of this
/// graph's own per-class work under the shipped constants, so the two
/// columns differ only by fit error (a smoke test of the calibration
/// layer with no serving traffic required).
fn print_per_op_breakdown(g: &Graph, spec: &DeviceSpec) {
    use mobile_diffusion::delegate::{class_breakdown, w8a8_gain, OpClass, RooflineModel};
    use mobile_diffusion::planner::{Calibrator, Observation, MIN_CLASS_SAMPLES};

    let shipped = &spec.delegate;
    let base_rows = class_breakdown(g, shipped, shipped);
    let mut cal = Calibrator::new(shipped.clone());
    for (i, row) in base_rows.iter().enumerate() {
        if row.ops == 0 {
            continue;
        }
        let class = OpClass::ALL[i];
        let p = shipped.params(class);
        let (f0, b0) = (row.flops / row.ops as f64, row.bytes / row.ops as f64);
        for k in 1..=(3 * MIN_CLASS_SAMPLES) {
            // vary the compute/memory mix so rate, bandwidth and the
            // dispatch floor are all identifiable from the stream
            let (f, b) = match k % 3 {
                0 => (f0 * k as f64, b0),
                1 => (f0, b0 * k as f64),
                _ => (f0 * 1e-3, b0 * 1e-3),
            };
            let seconds = p.dispatch + (f / p.flops).max(b / p.bandwidth);
            cal.record(Observation { class, flops: f, bytes: b, seconds });
        }
    }
    let prof = cal.fit();
    let rows = class_breakdown(g, shipped, &prof);
    println!("per-op-class breakdown on {} (calibrated = self-fit round-trip):", spec.name);
    println!(
        "  {:<14} {:>5} {:>12} {:>12} {:>12} {:>12}",
        "class", "ops", "gflops", "mb-moved", "modeled-ms", "calib-ms"
    );
    for (i, row) in rows.iter().enumerate() {
        if row.ops == 0 {
            continue;
        }
        println!(
            "  {:<14} {:>5} {:>12.3} {:>12.2} {:>12.3} {:>12.3}",
            OpClass::ALL[i].name(),
            row.ops,
            row.flops / 1e9,
            row.bytes / 1e6,
            row.modeled_s * 1e3,
            row.calibrated_s * 1e3,
        );
    }
    let gain = w8a8_gain(g, shipped);
    println!(
        "  w8a8 activation gain: {:+.3} ms ({})",
        gain * 1e3,
        if gain > 0.0 { "planner enables" } else { "planner declines" }
    );
}

/// The `analyze --per-op` ladder table: what the memory-pressure
/// governor would grant this class at each degradation rung, against a
/// shipped budget proxied by the graph's own working set on the device
/// (bytes moved under the shipped profile).  At serve time the same
/// governor learns the effective budget from real OOMs instead — this
/// table is the static schedule it walks.
fn print_pressure_ladder(g: &Graph, spec: &DeviceSpec) {
    use mobile_diffusion::coordinator::pressure::{PressureGovernor, PressureOptions, MAX_LEVEL};
    use mobile_diffusion::delegate::class_breakdown;

    let rows = class_breakdown(g, &spec.delegate, &spec.delegate);
    let shipped = rows.iter().map(|r| r.bytes).sum::<f64>() as usize;
    if shipped == 0 {
        return;
    }
    let opts = PressureOptions::default();
    let floor = opts.floor;
    let gov = PressureGovernor::new(vec![shipped], opts);
    println!(
        "degradation ladder on {} (shipped budget {:.2} MB, floor {:.0}%):",
        spec.name,
        shipped as f64 / 1e6,
        floor * 100.0
    );
    println!("  {:<6} {:>14} {:>12}  {}", "rung", "effective-mb", "of-shipped", "action");
    for rung in 1..=MAX_LEVEL {
        gov.on_oom(0);
        let action = match rung {
            1 => "shrink continuous seat cap",
            2 => "+ shed warm tier & non-pinned residency",
            _ => "+ force W8A8, re-plan under learned budget",
        };
        let eff = gov.effective_budget(0);
        println!(
            "  {:<6} {:>14.2} {:>11.0}%  {}",
            rung,
            eff as f64 / 1e6,
            eff as f64 / shipped as f64 * 100.0,
            action
        );
    }
}

fn cmd_passes(args: &[String]) -> R {
    // peel the registry-driven flags off before the shared graph loader
    let registry = passes::PassRegistry::standard();
    let mut rest: Vec<String> = Vec::new();
    let mut only: Option<Vec<String>> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--list" => {
                println!("registered passes (pipeline order):");
                for spec in registry.specs() {
                    println!("  {:<24} {}", spec.name, spec.summary);
                }
                return Ok(());
            }
            "--only" => {
                i += 1;
                let v = args.get(i).cloned().ok_or_else(|| {
                    mobile_diffusion::Error::Config("--only needs a value".into())
                })?;
                let names: Vec<String> = v
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                if names.is_empty() {
                    return Err(mobile_diffusion::Error::Config(
                        "--only needs at least one pass name (see --list)".into(),
                    ));
                }
                only = Some(names);
            }
            other => rest.push(other.to_string()),
        }
        i += 1;
    }
    let reg = match &only {
        Some(names) => {
            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            registry.subset(&refs)?
        }
        None => registry,
    };
    let (mut g, spec) = load_graph_cmd("passes", &rest)?;
    let rules = RuleSet::default();
    let before = modeled_cost_line(&g, &rules, &spec);
    let report = passes::run_registry(&mut g, &rules, &spec.delegate, &reg);
    println!(
        "pass pipeline on {} (device {}, {} pass(es)):",
        g.name,
        spec.name,
        reg.len()
    );
    for (name, n) in &report.applied {
        println!("  {:<28} {} site(s)", name, n);
    }
    println!(
        "coverage: {:.2}% -> {:.2}%",
        report.coverage_before * 100.0,
        report.coverage_after * 100.0
    );
    println!("before: {before}");
    println!("after:  {}", modeled_cost_line(&g, &rules, &spec));
    Ok(())
}

fn cmd_info(args: &[String]) -> R {
    let mut cfg = AppConfig::default();
    cfg.apply_args(args)?;
    let m = Manifest::load(&cfg.artifacts_dir)?;
    println!(
        "model: latent {s}x{s}x{c} -> image {i}x{i}, CFG batch {b}",
        s = m.latent_size,
        c = m.latent_channels,
        i = m.image_size,
        b = m.cfg_batch
    );
    println!(
        "scheduler: {} train steps, {} inference steps, guidance {}",
        m.scheduler.params.num_train_timesteps,
        m.scheduler.params.num_inference_steps,
        m.scheduler.params.guidance_scale
    );
    for (name, comp) in &m.components {
        let weights: Vec<String> = comp
            .weights
            .iter()
            .map(|(tag, w)| format!("{tag} {:.1} MB", w.bytes as f64 / 1e6))
            .collect();
        println!(
            "  {:<14} {:>3} params, {:>2} activations [{}]",
            name,
            comp.params.len(),
            comp.activations.len(),
            weights.join(", ")
        );
    }
    Ok(())
}
