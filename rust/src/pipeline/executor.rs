//! The pipelined executor — paper Sec. 3.3.
//!
//! Text-to-image under a device memory budget:
//!
//! 1. load the denoising UNet (resident for the whole request);
//! 2. load the text encoder, encode cond + uncond prompts, **evict it**;
//! 3. start the decoder prefetch on a child thread and run the DDIM
//!    denoise loop, polling the prefetch between steps;
//! 4. finalize the decoder (device compile + upload), decode, evict.
//!
//! Peak memory ~= unet + max(text_encoder, decoder) instead of the sum
//! of all three (the non-pipelined baseline, also implemented here for
//! the Fig. 4 / ablation comparison).

use std::time::Instant;

use crate::error::{Error, Result};
use crate::pipeline::loader::Prefetcher;
use crate::pipeline::memory::MemoryLedger;
use crate::runtime::{ActInput, Component, Engine, Manifest};
use crate::scheduler::{guide, Ddim};
use crate::tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// device memory budget in bytes (ledger-enforced)
    pub memory_budget: usize,
    /// pipelined (paper) vs load-everything-up-front baseline
    pub pipelined: bool,
    /// weight precision tag for the UNet ("fp32" | "int8" | "int8_pruned")
    pub unet_weights: String,
    pub num_steps: usize,
    pub guidance_scale: f64,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            memory_budget: usize::MAX,
            pipelined: true,
            unet_weights: "fp32".into(),
            num_steps: 20,
            guidance_scale: 7.5,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub text_load_s: f64,
    pub text_encode_s: f64,
    pub unet_load_s: f64,
    pub denoise_s: f64,
    pub denoise_steps: usize,
    pub decoder_load_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
}

pub struct GenerateResult {
    /// HWC RGB f32 image in roughly [-1, 1]
    pub image: Vec<f32>,
    pub image_size: usize,
    /// final latent (for numeric comparisons across variants)
    pub latent: Vec<f32>,
    pub timings: StageTimings,
    pub peak_memory: usize,
}

pub struct PipelinedExecutor {
    pub engine: Engine,
    pub manifest: Manifest,
    pub ledger: MemoryLedger,
    pub options: ExecOptions,
    /// resident UNet (kept across requests, like the paper's app)
    unet: Option<Component>,
    unet_key: String,
}

impl PipelinedExecutor {
    pub fn new(manifest: Manifest, options: ExecOptions) -> Result<PipelinedExecutor> {
        let engine = Engine::new()?;
        let ledger = MemoryLedger::new(options.memory_budget);
        Ok(PipelinedExecutor {
            engine,
            manifest,
            ledger,
            options,
            unet: None,
            unet_key: String::new(),
        })
    }

    /// Resident-bytes of a component at a weights tag, from the manifest
    /// (ledger numbers must be known *before* loading).
    fn stored_bytes(&self, comp: &str, tag: &str) -> Result<usize> {
        let c = self.manifest.component(comp)?;
        c.weights
            .get(tag)
            .map(|w| w.bytes)
            .ok_or_else(|| Error::Manifest(format!("{comp}: no weights {tag}")))
    }

    fn load_component(&self, name: &str, tag: &str) -> Result<Component> {
        let comp = self.manifest.component(name)?;
        Component::load(&self.engine, &self.manifest, comp, tag)
    }

    /// Ensure the UNet is loaded (variant per options), charging the ledger.
    pub fn ensure_unet(&mut self, variant: &str) -> Result<()> {
        let key = format!("unet_{variant}:{}", self.options.unet_weights);
        if self.unet.is_some() && self.unet_key == key {
            return Ok(());
        }
        if self.unet.take().is_some() {
            self.ledger.free("unet")?;
        }
        let name = format!("unet_{variant}");
        let bytes = self.stored_bytes(&name, &self.options.unet_weights)?;
        self.ledger.alloc("unet", bytes)?;
        match self.load_component(&name, &self.options.unet_weights) {
            Ok(c) => {
                self.unet = Some(c);
                self.unet_key = key;
                Ok(())
            }
            Err(e) => {
                let _ = self.ledger.free("unet");
                Err(e)
            }
        }
    }

    /// Full text-to-image generation.
    pub fn generate(
        &mut self,
        prompt: &str,
        seed: u64,
        variant: &str,
    ) -> Result<GenerateResult> {
        let t_start = Instant::now();
        let mut tm = StageTimings::default();

        // ---- UNet resident -------------------------------------------------
        let t0 = Instant::now();
        self.ensure_unet(variant)?;
        tm.unet_load_s = t0.elapsed().as_secs_f64();

        // ---- non-pipelined baseline: everything resident up front ----------
        let decoder_bytes = self.stored_bytes("decoder", "fp32")?;
        let decoder_manifest = self.manifest.component("decoder")?.clone();
        let mut decoder: Option<Component> = None;
        if !self.options.pipelined {
            let t0 = Instant::now();
            self.ledger.alloc("decoder", decoder_bytes)?;
            decoder = Some(match self.load_component("decoder", "fp32") {
                Ok(c) => c,
                Err(e) => {
                    let _ = self.ledger.free("decoder");
                    return Err(e);
                }
            });
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }

        // ---- text encode (load -> encode -> evict) -------------------------
        let t0 = Instant::now();
        let te_bytes = self.stored_bytes("text_encoder", "fp32")?;
        self.ledger.alloc("text_encoder", te_bytes)?;
        let text = match self.load_component("text_encoder", "fp32") {
            Ok(c) => c,
            Err(e) => {
                let _ = self.ledger.free("text_encoder");
                return Err(e);
            }
        };
        tm.text_load_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let seq = self.manifest.tokenizer.seq_len;
        let vocab = self.manifest.tokenizer.vocab_size;
        let cond_ids = tokenizer::encode(prompt, vocab, seq);
        let uncond_ids = tokenizer::encode("", vocab, seq);
        let cond_ctx = text.run(&self.engine, &[ActInput::i32(cond_ids)])?;
        let uncond_ctx = text.run(&self.engine, &[ActInput::i32(uncond_ids)])?;
        tm.text_encode_s = t0.elapsed().as_secs_f64();

        drop(text);
        self.ledger.free("text_encoder")?;
        self.ledger.mark("text-encoder-evicted");

        // context2: uncond then cond halves, (2, S, D)
        let mut context2 = uncond_ctx[0].clone();
        context2.extend_from_slice(&cond_ctx[0]);

        // ---- denoise loop with decoder prefetch overlap --------------------
        let mut prefetch = if self.options.pipelined {
            Some(Prefetcher::spawn(&self.manifest, &decoder_manifest, "fp32")?)
        } else {
            None // baseline: decoder already resident
        };
        let mut prefetch_charged = false;

        let t0 = Instant::now();
        let ddim = Ddim::from_alphas(
            {
                let mut p = self.manifest.scheduler.params.clone();
                p.guidance_scale = self.options.guidance_scale;
                p
            },
            self.manifest.scheduler.alphas_cumprod.clone(),
        );
        let ts = ddim.timesteps(self.options.num_steps);

        let s = self.manifest.latent_size;
        let c = self.manifest.latent_channels;
        let n_latent = s * s * c;
        let mut rng = Rng::new(seed);
        let mut latent: Vec<f32> = rng.normal_f32_vec(n_latent);

        let unet = self.unet.as_ref().expect("unet loaded");
        let mut eps = vec![0f32; n_latent];
        let mut latent2 = vec![0f32; 2 * n_latent];
        // the context is constant across the whole denoise loop: upload
        // it once and keep the device buffer resident (perf: saves one
        // host->device copy per step; see EXPERIMENTS.md §Perf)
        let ctx_buf = unet.upload(&self.engine, 2, &ActInput::F32(context2.clone()))?;
        for (i, &t) in ts.iter().enumerate() {
            latent2[..n_latent].copy_from_slice(&latent);
            latent2[n_latent..].copy_from_slice(&latent);
            let lat_buf = unet.upload(&self.engine, 0, &ActInput::F32(latent2.clone()))?;
            let t_buf = unet.upload(&self.engine, 1, &ActInput::F32(vec![t as f32]))?;
            let out = unet.run_buffers(&[&lat_buf, &t_buf, &ctx_buf])?;
            let eps2 = &out[0];
            guide(
                &eps2[..n_latent],
                &eps2[n_latent..],
                self.options.guidance_scale,
                &mut eps,
            );
            let t_prev = ts.get(i + 1).copied();
            ddim.step(&mut latent, &eps, t, t_prev);

            // consume the decoder prefetch as soon as it lands
            if let Some(p) = prefetch.as_mut() {
                if !prefetch_charged && p.poll() {
                    self.ledger.alloc("decoder", decoder_bytes)?;
                    self.ledger.mark(&format!("decoder-prefetched@step{i}"));
                    prefetch_charged = true;
                }
            }
        }
        tm.denoise_s = t0.elapsed().as_secs_f64();
        tm.denoise_steps = ts.len();
        self.ledger.mark("denoise-done");

        // ---- decode ---------------------------------------------------------
        if let Some(p) = prefetch.take() {
            let t0 = Instant::now();
            let pf = p.join()?;
            if !prefetch_charged {
                self.ledger.alloc("decoder", decoder_bytes)?;
            }
            decoder = Some(Component::load_from_parts(
                &self.engine,
                &pf.hlo_text_path,
                &decoder_manifest,
                &pf.weights,
            )?);
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }
        let dec = decoder.expect("decoder loaded");
        let t0 = Instant::now();
        let img = dec.run(&self.engine, &[ActInput::F32(latent.clone())])?;
        tm.decode_s = t0.elapsed().as_secs_f64();
        drop(dec);
        self.ledger.free("decoder")?;
        self.ledger.mark("decoder-evicted");

        tm.total_s = t_start.elapsed().as_secs_f64();
        Ok(GenerateResult {
            image: img.into_iter().next().unwrap_or_default(),
            image_size: self.manifest.image_size,
            latent,
            timings: tm,
            peak_memory: self.ledger.peak(),
        })
    }

    /// Drop the resident UNet (frees its ledger entry).
    pub fn evict_unet(&mut self) -> Result<()> {
        if self.unet.take().is_some() {
            self.ledger.free("unet")?;
        }
        self.unet_key.clear();
        Ok(())
    }
}
