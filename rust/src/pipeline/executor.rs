//! The pipelined executor — paper Sec. 3.3.
//!
//! Text-to-image under a device memory budget:
//!
//! 1. acquire the denoising UNet (cached across requests);
//! 2. acquire the text encoder, encode cond + uncond prompts, evict it;
//! 3. start the decoder prefetch on a child thread and run the DDIM
//!    denoise loop, polling the prefetch between steps;
//! 4. finalize the decoder (device compile + upload), decode, evict.
//!
//! Peak memory ~= unet + max(text_encoder, decoder) instead of the sum
//! of all three (the non-pipelined baseline, also implemented here for
//! the Fig. 4 / ablation comparison).
//!
//! All load/evict/ledger policy lives in
//! [`crate::pipeline::residency::ResidencyManager`]; this module is
//! pure stage orchestration.  Per-request overrides (step count,
//! variant, guidance) arrive via [`ExecOverrides`] so a serving layer
//! can honor them end-to-end without rebuilding the executor.

use std::rc::Rc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::pipeline::loader::Prefetcher;
use crate::pipeline::residency::{ResidencyManager, Retention};
use crate::pipeline::trace::MemoryTrace;
use crate::runtime::{ActInput, Component, Engine, Manifest};
use crate::scheduler::{guide, Ddim};
use crate::tokenizer;
use crate::util::rng::Rng;

/// A cached component handle (reference-counted: the residency cache
/// and in-flight stages share ownership within a worker thread).
pub type ResidentComponent = Rc<Component>;

#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// device memory budget in bytes (ledger-enforced)
    pub memory_budget: usize,
    /// pipelined (paper) vs load-everything-up-front baseline
    pub pipelined: bool,
    /// weight precision tag for the UNet ("fp32" | "int8" | "int8_pruned")
    pub unet_weights: String,
    pub num_steps: usize,
    pub guidance_scale: f64,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            memory_budget: usize::MAX,
            pipelined: true,
            unet_weights: "fp32".into(),
            num_steps: 20,
            guidance_scale: 7.5,
        }
    }
}

/// Per-request overrides of the configured [`ExecOptions`] defaults —
/// a request on a distilled schedule can run 4 steps while the server
/// default stays 20.
#[derive(Debug, Clone, Default)]
pub struct ExecOverrides {
    pub num_steps: Option<usize>,
    pub variant: Option<String>,
    pub guidance_scale: Option<f64>,
}

#[derive(Debug, Clone, Default)]
pub struct StageTimings {
    pub text_load_s: f64,
    pub text_encode_s: f64,
    pub unet_load_s: f64,
    pub denoise_s: f64,
    pub denoise_steps: usize,
    pub decoder_load_s: f64,
    pub decode_s: f64,
    pub total_s: f64,
}

pub struct GenerateResult {
    /// HWC RGB f32 image in roughly [-1, 1]
    pub image: Vec<f32>,
    pub image_size: usize,
    /// final latent (for numeric comparisons across variants)
    pub latent: Vec<f32>,
    pub timings: StageTimings,
    pub peak_memory: usize,
}

pub struct PipelinedExecutor {
    pub engine: Engine,
    pub manifest: Manifest,
    pub residency: ResidencyManager<ResidentComponent>,
    pub options: ExecOptions,
}

impl PipelinedExecutor {
    pub fn new(manifest: Manifest, options: ExecOptions) -> Result<PipelinedExecutor> {
        let engine = Engine::new()?;
        let residency = ResidencyManager::new(options.memory_budget);
        Ok(PipelinedExecutor { engine, manifest, residency, options })
    }

    /// Resident-bytes of a component at a weights tag, from the manifest
    /// (ledger numbers must be known *before* loading).
    fn stored_bytes(&self, comp: &str, tag: &str) -> Result<usize> {
        let c = self.manifest.component(comp)?;
        c.weights
            .get(tag)
            .map(|w| w.bytes)
            .ok_or_else(|| Error::Manifest(format!("{comp}: no weights {tag}")))
    }

    /// Pin `(name, tag)` through the residency layer, loading on miss.
    fn acquire_component(&mut self, name: &str, tag: &str) -> Result<ResidentComponent> {
        let bytes = self.stored_bytes(name, tag)?;
        let PipelinedExecutor { engine, manifest, residency, .. } = self;
        residency.acquire(name, tag, bytes, || {
            let comp = manifest.component(name)?;
            Component::load(engine, manifest, comp, tag).map(Rc::new)
        })
    }

    /// Warm the UNet cache (variant per options) without holding a pin.
    pub fn ensure_unet(&mut self, variant: &str) -> Result<()> {
        let name = format!("unet_{variant}");
        let tag = self.options.unet_weights.clone();
        self.acquire_component(&name, &tag)?;
        self.residency.release(&name, &tag, Retention::Cache)
    }

    /// Drop every component no request is using (e.g. between traffic
    /// bursts); returns the bytes freed.
    pub fn evict_idle(&mut self) -> usize {
        self.residency.evict_idle()
    }

    /// The Fig. 4 occupancy trace.
    pub fn memory_trace(&self) -> &MemoryTrace {
        self.residency.trace()
    }

    /// Full text-to-image generation with the configured defaults.
    pub fn generate(
        &mut self,
        prompt: &str,
        seed: u64,
        variant: &str,
    ) -> Result<GenerateResult> {
        self.generate_with(prompt, seed, variant, &ExecOverrides::default())
    }

    /// Full text-to-image generation with per-request overrides.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        seed: u64,
        variant: &str,
        overrides: &ExecOverrides,
    ) -> Result<GenerateResult> {
        let t_start = Instant::now();
        let mut tm = StageTimings::default();
        let variant = overrides.variant.as_deref().unwrap_or(variant).to_string();
        let num_steps = overrides.num_steps.unwrap_or(self.options.num_steps);
        let guidance = overrides.guidance_scale.unwrap_or(self.options.guidance_scale);

        // ---- UNet resident (cached across requests) ------------------------
        let unet_name = format!("unet_{variant}");
        let unet_tag = self.options.unet_weights.clone();
        let t0 = Instant::now();
        let unet = self.acquire_component(&unet_name, &unet_tag)?;
        tm.unet_load_s = t0.elapsed().as_secs_f64();

        let result = self.run_stages(prompt, seed, num_steps, guidance, unet, &mut tm);
        if result.is_err() {
            // a failed request must not leak pins into the next one
            self.residency.purge("text_encoder", "fp32");
            self.residency.purge("decoder", "fp32");
        }
        // unpin the UNet but keep it cached — the paper's app behaviour
        let _ = self.residency.release(&unet_name, &unet_tag, Retention::Cache);

        let stages = result?;
        tm.total_s = t_start.elapsed().as_secs_f64();
        Ok(GenerateResult {
            image: stages.image,
            image_size: self.manifest.image_size,
            latent: stages.latent,
            timings: tm,
            peak_memory: self.residency.peak(),
        })
    }

    /// Everything between UNet acquisition and the final image: text
    /// encode, denoise with decoder prefetch overlap, decode.
    fn run_stages(
        &mut self,
        prompt: &str,
        seed: u64,
        num_steps: usize,
        guidance: f64,
        unet: ResidentComponent,
        tm: &mut StageTimings,
    ) -> Result<StageOutput> {
        // ---- non-pipelined baseline: everything resident up front ----------
        let decoder_bytes = self.stored_bytes("decoder", "fp32")?;
        let decoder_manifest = self.manifest.component("decoder")?.clone();
        let mut decoder: Option<ResidentComponent> = None;
        if !self.options.pipelined {
            let t0 = Instant::now();
            decoder = Some(self.acquire_component("decoder", "fp32")?);
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }

        // ---- text encode (acquire -> encode -> evict) ----------------------
        let t0 = Instant::now();
        let text = self.acquire_component("text_encoder", "fp32")?;
        tm.text_load_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let seq = self.manifest.tokenizer.seq_len;
        let vocab = self.manifest.tokenizer.vocab_size;
        let cond_ids = tokenizer::encode(prompt, vocab, seq);
        let uncond_ids = tokenizer::encode("", vocab, seq);
        let cond_ctx = text.run(&self.engine, &[ActInput::i32(cond_ids)])?;
        let uncond_ctx = text.run(&self.engine, &[ActInput::i32(uncond_ids)])?;
        tm.text_encode_s = t0.elapsed().as_secs_f64();

        drop(text);
        self.residency.release("text_encoder", "fp32", Retention::Evict)?;
        self.residency.mark("text-encoder-evicted");

        // context2: uncond then cond halves, (2, S, D)
        let mut context2 = uncond_ctx[0].clone();
        context2.extend_from_slice(&cond_ctx[0]);

        // ---- denoise loop with decoder prefetch overlap --------------------
        let mut prefetch = if self.options.pipelined {
            Some(Prefetcher::spawn(&self.manifest, &decoder_manifest, "fp32")?)
        } else {
            None // baseline: decoder already resident
        };
        let mut prefetch_charged = false;

        let t0 = Instant::now();
        let ddim = Ddim::from_alphas(
            {
                let mut p = self.manifest.scheduler.params.clone();
                p.guidance_scale = guidance;
                p
            },
            self.manifest.scheduler.alphas_cumprod.clone(),
        );
        let ts = ddim.timesteps(num_steps);

        let s = self.manifest.latent_size;
        let c = self.manifest.latent_channels;
        let n_latent = s * s * c;
        let mut rng = Rng::new(seed);
        let mut latent: Vec<f32> = rng.normal_f32_vec(n_latent);

        let mut eps = vec![0f32; n_latent];
        let mut latent2 = vec![0f32; 2 * n_latent];
        // the context is constant across the whole denoise loop: upload
        // it once and keep the device buffer resident (saves one
        // host->device copy per step)
        let ctx_buf = unet.upload(&self.engine, 2, &ActInput::F32(context2.clone()))?;
        for (i, &t) in ts.iter().enumerate() {
            latent2[..n_latent].copy_from_slice(&latent);
            latent2[n_latent..].copy_from_slice(&latent);
            let lat_buf = unet.upload(&self.engine, 0, &ActInput::F32(latent2.clone()))?;
            let t_buf = unet.upload(&self.engine, 1, &ActInput::F32(vec![t as f32]))?;
            let out = unet.run_buffers(&[&lat_buf, &t_buf, &ctx_buf])?;
            let eps2 = &out[0];
            guide(&eps2[..n_latent], &eps2[n_latent..], guidance, &mut eps);
            let t_prev = ts.get(i + 1).copied();
            ddim.step(&mut latent, &eps, t, t_prev);

            // charge the decoder prefetch as soon as its bytes land
            if let Some(p) = prefetch.as_mut() {
                if !prefetch_charged && p.poll() {
                    self.residency.reserve("decoder", "fp32", decoder_bytes)?;
                    self.residency.mark(&format!("decoder-prefetched@step{i}"));
                    prefetch_charged = true;
                }
            }
        }
        tm.denoise_s = t0.elapsed().as_secs_f64();
        tm.denoise_steps = ts.len();
        self.residency.mark("denoise-done");

        // ---- decode ---------------------------------------------------------
        if let Some(p) = prefetch.take() {
            let t0 = Instant::now();
            let pf = p.join()?;
            if !prefetch_charged {
                self.residency.reserve("decoder", "fp32", decoder_bytes)?;
            }
            let loaded = Component::load_from_parts(
                &self.engine,
                &pf.hlo_text_path,
                &decoder_manifest,
                &pf.weights,
            );
            match loaded {
                Ok(c) => {
                    decoder = Some(self.residency.fulfill("decoder", "fp32", Rc::new(c))?);
                }
                Err(e) => {
                    let _ = self.residency.cancel("decoder", "fp32");
                    return Err(e);
                }
            }
            tm.decoder_load_s = t0.elapsed().as_secs_f64();
        }
        let dec = decoder.expect("decoder loaded");
        let t0 = Instant::now();
        let img = dec.run(&self.engine, &[ActInput::F32(latent.clone())])?;
        tm.decode_s = t0.elapsed().as_secs_f64();
        drop(dec);
        self.residency.release("decoder", "fp32", Retention::Evict)?;
        self.residency.mark("decoder-evicted");

        Ok(StageOutput { image: img.into_iter().next().unwrap_or_default(), latent })
    }
}

struct StageOutput {
    image: Vec<f32>,
    latent: Vec<f32>,
}
